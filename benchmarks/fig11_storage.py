"""Fig. 11 (extension): the materialized chunk-granular KV store —
TTFT and bytes-transferred vs prefix-dedup ratio and tier hit rate.

Real-mode (reduced model, on-host): N requests restore through a
``ChunkStore``; a ``dedup`` fraction of them share an identical prefix, so
their chunks hash to one stored copy and — once the first referent pulls
them into the HBM tier — later referents' transfers are skipped entirely
(engine-core residency hits).  Reported per dedup ratio: mean engine-clock
TTFT, real bytes moved out of host/disk tiers, and the tier hit rate
(hits / chunk reads).  A second sweep shows int8 quantization halving the
bytes on the wire at a documented restore tolerance.

CLI: ``python benchmarks/fig11_storage.py [--smoke]``.
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import row  # noqa: E402

_MODEL = {}


def _model():
    if not _MODEL:
        import jax
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("qwen3-8b").reduced()
        m = build_model(cfg)
        _MODEL.update(cfg=cfg, model=m, params=m.init(jax.random.PRNGKey(0)))
    return _MODEL


def _serve(dedup: float, *, n=6, quant="none", shared_len=48, decode_len=2):
    from repro.serving import ChunkStore, RealServingEngine, Request
    mm = _model()
    store = ChunkStore(chunk_size=8, quant=quant, default_tier="host")
    eng = RealServingEngine(mm["model"], mm["params"], system="cacheflow",
                            stages=2, chunk_size=8, kvstore=store)
    # identical prefix_len => identical tokens (engine rng reuse) => the
    # chunk chains collide; unique requests get distinct lengths
    n_shared = max(1, int(round(n * dedup)))
    reqs = [Request(f"s{i}", 0.05 * i, shared_len, 8, decode_len=decode_len)
            for i in range(n_shared)]
    reqs += [Request(f"u{i}", 0.05 * (n_shared + i), shared_len + 8 * (i + 1),
                     8, decode_len=decode_len) for i in range(n - n_shared)]
    rep = eng.serve(reqs, verify=(quant == "none"))
    reads = store.io_hits + store.fetches
    return {
        "ttft_mean": float(np.mean(list(rep.ttfts.values()))),
        "bytes": store.bytes_transferred,
        "bytes_put": store.bytes_put,
        "dedup_hits": store.dedup_hits,
        "skipped": store.skipped_transfers,
        "hit_rate": store.io_hits / reads if reads else 0.0,
        "tol": store.quant_tolerance(),
    }


def run(smoke: bool = False):
    rows = []
    ratios = (0.0, 1.0) if smoke else (0.0, 0.5, 1.0)
    n = 4 if smoke else 6
    base = last = None
    for dedup in ratios:
        last = _serve(dedup, n=n)
        if base is None:
            base = last
        rows.append(row(
            f"fig11/real/dedup={dedup:.1f}", last["ttft_mean"],
            f"bytes={last['bytes']} hit_rate={last['hit_rate']:.2f} "
            f"dedup_hits={last['dedup_hits']} skipped={last['skipped']} "
            f"bytes_vs_unique={last['bytes'] / max(1, base['bytes']):.2f}x"))
    # dedup must reduce real bytes moved (acceptance criterion)
    assert last["bytes"] < base["bytes"], \
        (last["bytes"], base["bytes"], "dedup did not reduce bytes moved")
    # int8: ~half the stored bytes travel, within the documented tolerance
    q = _serve(0.0, n=n, quant="int8")
    rows.append(row(
        "fig11/real/int8", q["ttft_mean"],
        f"bytes={q['bytes']} bytes_vs_fp={q['bytes'] / base['bytes']:.2f}x "
        f"tol={q['tol']:.3g} hit_rate={q['hit_rate']:.2f}"))
    assert q["bytes"] < base["bytes"]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (2 ratios, 4 requests)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in run(smoke=args.smoke):
        print(line)


if __name__ == "__main__":
    main()
