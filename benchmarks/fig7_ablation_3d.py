"""Paper Fig. 7: 3D ablation — disable stage-parallel restoration (stages
restore sequentially) vs full 3D; paper reports 0.21s → 0.29s (+38%) and 2D
still beating vLLM by 24%."""
from benchmarks.common import row, sim_ttft


def run():
    rows = []
    r3 = sim_ttft("cacheflow", workload="swe_bench", stages=2)
    r2 = sim_ttft("cacheflow_2d", workload="swe_bench", stages=2)
    rv = sim_ttft("vllm", workload="swe_bench", stages=2)
    inc = r2.stats["mean"] / r3.stats["mean"] - 1
    rows.append(row("fig7/3d", r3.stats["mean"], "full 3D"))
    rows.append(row("fig7/2d-only", r2.stats["mean"],
                    f"latency_increase={inc:.0%} (paper: +38%)"))
    rows.append(row("fig7/2d-vs-vllm", r2.stats["mean"],
                    f"still_beats_vllm={(rv.stats['mean'] / r2.stats['mean']):.2f}x "
                    f"(paper: 1.24x)"))
    return rows
