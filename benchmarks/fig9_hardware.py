"""Paper Fig. 9: hardware ablation (2×L40S, A100 — paper: 1.6×/1.5×), plus
the TPU v5e target this framework is built for."""
from benchmarks.common import row, sim_ttft


def run():
    rows = []
    for hw, stages in (("l40s", 2), ("a100", 1), ("h100", 1), ("tpu_v5e", 1)):
        classic = None
        for base in ("vllm", "lmcache", "sglang"):
            r = sim_ttft(base, workload="swe_bench", hw=hw, stages=stages,
                         arch="qwen3-30b-a3b", bw="10Gbps")
            classic = min(classic, r.stats["mean"]) if classic else r.stats["mean"]
        cake = sim_ttft("cake", workload="swe_bench", hw=hw, stages=stages,
                        arch="qwen3-30b-a3b", bw="10Gbps").stats
        rc = sim_ttft("cacheflow", workload="swe_bench", hw=hw, stages=stages,
                      arch="qwen3-30b-a3b", bw="10Gbps")
        rows.append(row(
            f"fig9/{hw}", rc.stats["mean"],
            f"speedup_vs_classic={classic / rc.stats['mean']:.2f}x "
            f"(paper 1.5-1.6x) vs_cake={cake['mean'] / rc.stats['mean']:.2f}x "
            f"tail_vs_cake={cake['p99'] / rc.stats['p99']:.2f}x"))
    return rows
