"""Paper Fig. 8: I/O bandwidth impact (10/40/80 Gbps on H100) — CacheFlow
improves TTFT at every bandwidth (paper: 1.7×/1.5× at 40/80 Gbps)."""
from benchmarks.common import row, sim_ttft


def run():
    rows = []
    for bw in ("10Gbps", "40Gbps", "80Gbps"):
        best = None
        for base in ("vllm", "lmcache", "cake"):
            r = sim_ttft(base, workload="swe_bench", bw=bw, hw="h100")
            best = min(best, r.stats["mean"]) if best else r.stats["mean"]
        rc = sim_ttft("cacheflow", workload="swe_bench", bw=bw, hw="h100")
        rows.append(row(f"fig8/{bw}", rc.stats["mean"],
                        f"speedup_vs_best={best / rc.stats['mean']:.2f}x"))
    return rows
