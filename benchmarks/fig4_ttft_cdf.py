"""Paper Fig. 4 (+ §4.2 headline): TTFT distribution across the three
workloads for every system; CacheFlow's reduction vs the best baseline
should land in the paper's 10–62% band.

TTFT is measured on the full lifecycle loop — suffix prefill contends with
other requests' restoration chunks — and each row also reports end-to-end
request latency and generation throughput (tokens/sec)."""
import json
import os

from benchmarks.common import RESULTS, row, sim_ttft

SYSTEMS = ("vllm", "sglang", "lmcache", "cake", "cacheflow")


def run():
    rows = []
    dump = {}
    for workload in ("wildchat", "lmsys_chat", "swe_bench"):
        stats = {}
        for system in SYSTEMS:
            rep = sim_ttft(system, workload=workload)
            stats[system] = rep.stats
            rows.append(row(f"fig4/{workload}/{system}", rep.stats["mean"],
                            f"p50={rep.stats['p50']:.3f}s p90={rep.stats['p90']:.3f}s "
                            f"p99={rep.stats['p99']:.3f}s "
                            f"e2e={rep.stats['e2e_mean']:.3f}s "
                            f"tok/s={rep.stats['tokens_per_sec']:.1f}"))
        best = min(stats[s]["mean"] for s in SYSTEMS if s != "cacheflow")
        red = 1 - stats["cacheflow"]["mean"] / best
        tail = min(stats[s]["p99"] for s in SYSTEMS if s != "cacheflow")
        tail_red = 1 - stats["cacheflow"]["p99"] / tail
        rows.append(row(f"fig4/{workload}/reduction", stats["cacheflow"]["mean"],
                        f"mean_reduction={red:.1%} p99_reduction={tail_red:.1%} "
                        f"paper_band=10-62%"))
        dump[workload] = stats
    with open(os.path.join(RESULTS, "fig4_ttft.json"), "w") as f:
        json.dump(dump, f, indent=1)
    return rows
