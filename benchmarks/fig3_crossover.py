"""Paper Fig. 3: token-wise vs layer-wise crossover L_Δ — analytic on the
paper hardware + the v5e target, and MEASURED on a real reduced model."""
import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core.cost_model import CostModel
from repro.core.executor import RestorationExecutor
from repro.core.profiler import profile_analytic, profile_measured
from repro.models import build_model


def run():
    rows = []
    for hw in ("h100", "tpu_v5e"):
        for bw in ("10Gbps", "40Gbps"):
            cost = CostModel(get_config("qwen3-8b"), HARDWARE[hw],
                             IO_BANDWIDTHS[bw], mfu=0.45)
            prof = profile_analytic(cost)
            rows.append(row(f"fig3/analytic/{hw}/{bw}",
                            prof.t_token[-1], f"L_delta={prof.l_delta}"))
    # measured on a real model (CPU): crossover exists and is content-agnostic
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    ex = RestorationExecutor(m, params, chunk_size=8)

    def make_inputs(n, seed=0):
        return jax.random.randint(jax.random.PRNGKey(seed), (1, n), 0,
                                  cfg.vocab_size)

    prof = profile_measured(ex, make_inputs, lengths=[16, 64, 160], repeats=1)
    rows.append(row("fig3/measured/reduced-qwen3", prof.t_token[-1],
                    f"L_delta={prof.l_delta}"))
    # content-agnostic: different token content, same ordering of strategies
    prof2 = profile_measured(ex, lambda n: make_inputs(n, seed=9),
                             lengths=[16, 160], repeats=1)
    agree = (prof.t_token[0] > prof.t_layer[0]) == (prof2.t_token[0] > prof2.t_layer[0])
    rows.append(row("fig3/content-agnostic", prof2.t_token[-1],
                    f"ordering_agrees={agree}"))
    return rows
