"""Sustained throughput under continuous batching (DESIGN.md §11).

The headline serving metric is CAPACITY, not makespan: the highest offered
arrival rate at which the system still meets its first-token SLO, reported
as sustained completed requests/s at p99 TTFT <= SLO.  Method: sweep the
``multi_tenant`` workload's peak arrival rate, run the same stream through
both admission modes —

  * ``gang``        — run-to-completion baseline: the next batch is admitted
                      only when the whole current batch retires, so arrivals
                      queue behind the slowest request of the batch and
                      restoration only ever runs against an idle device;
  * ``continuous``  — a freed decode slot is refilled mid-flight, so queued
                      requests restore AGAINST the live decode batch
                      (decode<->restoration overlap is the mechanism; the
                      benefit gate prices recompute under decode
                      interference) —

and take each mode's best sustained rate among the sweep points whose p99
TTFT meets the SLO (the knee of the latency-throughput curve).  Completion
rates come from per-request finish events over a warmup/drain-trimmed
steady-state window, never from makespan (benchmarks/common.py).

Acceptance (asserted, also under --smoke): continuous batching sustains a
strictly higher req/s at the SLO than gang admission on the same workload,
with nonzero decode<->restoration overlap at the knee.
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import DEFAULTS, emit_bench, row, sim_ttft  # noqa: E402
from repro.config import IO_BANDWIDTHS  # noqa: E402
from repro.serving import TieredKVStore  # noqa: E402
from repro.serving.metrics import sustained_throughput  # noqa: E402
from repro.serving.workloads import multi_tenant  # noqa: E402

SLO_P99_TTFT = 2.0        # interactive-class first-token SLO (seconds)
RATES = (2.0, 4.0, 8.0, 16.0)
N_REQUESTS = 96
SMOKE_RATES = (4.0, 8.0)
SMOKE_REQUESTS = 36


def _serve(admission: str, rate: float, n: int, seed: int = 3):
    # fresh store per run: reuse hits must come from THIS stream's Zipf
    # repeats, not a previous sweep point's residue; "remote" start tier
    # makes restoration (and hence the decode overlap) real
    reqs = multi_tenant(n, seed=seed, arrival_rate=rate)
    store = TieredKVStore(remote_bw=IO_BANDWIDTHS[DEFAULTS["bw"]])
    return reqs, sim_ttft(
        "cacheflow", requests=reqs, kvstore=store, kv_tier="remote",
        max_batch=4, admission=admission,
        prefetch=(admission == "continuous"),
        decode_interference=0.3 if admission == "continuous" else 0.0)


def _sweep(admission: str, rates, n):
    """One latency-throughput curve: per-rate p99 TTFT + sustained rps."""
    points = []
    for rate in rates:
        reqs, rep = _serve(admission, rate, n)
        horizon = max(r.arrival for r in reqs)
        st = sustained_throughput(rep.arrivals, rep.finishes,
                                  warmup=0.1 * horizon, drain=0.1 * horizon)
        p99 = float(np.percentile(sorted(rep.ttfts.values()), 99)) \
            if rep.ttfts else float("inf")
        points.append({
            "rate": rate, "p99_ttft": p99,
            "sustained_rps": st["sustained_rps"],
            "completed": len(rep.finishes), "offered": len(reqs),
            "overlap": rep.overlap_decode_restore,
            "meets_slo": p99 <= SLO_P99_TTFT})
    return points


def _capacity(points):
    """Sustained rps at the SLO knee (best point that still meets it)."""
    ok = [p for p in points if p["meets_slo"]]
    if not ok:
        return 0.0, None
    best = max(ok, key=lambda p: p["sustained_rps"])
    return best["sustained_rps"], best


def run(smoke: bool = False):
    rates = SMOKE_RATES if smoke else RATES
    n = SMOKE_REQUESTS if smoke else N_REQUESTS
    curves, rows = {}, []
    for admission in ("gang", "continuous"):
        points = _sweep(admission, rates, n)
        cap, knee = _capacity(points)
        curves[admission] = {"points": points, "capacity_rps": cap,
                             "knee": knee}
        for p in points:
            rows.append(row(
                f"throughput/{admission}@{p['rate']:g}rps", p["p99_ttft"],
                f"sustained={p['sustained_rps']:.3f}rps "
                f"p99_ttft={p['p99_ttft']:.3f}s "
                f"overlap={p['overlap']:.2f}s "
                f"slo={'ok' if p['meets_slo'] else 'MISS'}"))
    gang, cont = curves["gang"], curves["continuous"]
    speedup = cont["capacity_rps"] / max(gang["capacity_rps"], 1e-9)
    rows.append(row(
        "throughput/capacity", cont["capacity_rps"],
        f"continuous={cont['capacity_rps']:.3f}rps "
        f"gang={gang['capacity_rps']:.3f}rps "
        f"gain={speedup:.2f}x at p99_ttft<={SLO_P99_TTFT:g}s"))
    emit_bench("throughput", {"slo_p99_ttft": SLO_P99_TTFT, **curves})
    # acceptance: continuous batching sustains strictly more load at the
    # SLO, and the mechanism — restoration overlapping live decode — is
    # actually engaged at the steady-state knee
    assert cont["capacity_rps"] > gang["capacity_rps"], \
        f"continuous {cont['capacity_rps']} <= gang {gang['capacity_rps']}"
    assert cont["knee"] is not None and cont["knee"]["overlap"] > 0.0, \
        "no decode<->restoration overlap at the continuous knee"
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2-rate sweep on a short stream (CI); same "
                         "acceptance assertions")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in run(smoke=args.smoke):
        print(line)


if __name__ == "__main__":
    main()
