"""Paper Fig. 5: resource utilization during restoration — vLLM is
compute-bound with idle I/O, LMCache saturates I/O with idle compute,
CacheFlow keeps both busy (paper: 88% GPU / 78% I/O)."""
from benchmarks.common import row, sim_ttft


def run():
    rows = []
    for system in ("vllm", "lmcache", "cacheflow"):
        rep = sim_ttft(system, workload="swe_bench")
        rows.append(row(f"fig5/{system}", rep.stats["mean"],
                        f"compute_busy={rep.compute_busy:.0%} "
                        f"io_busy={rep.io_busy:.0%}"))
    return rows
