"""Paper Fig. 10: batch-size ablation (L40S, Llama-3.1-8B).

CacheFlow's batch-aware I/O prioritisation is a *contention* mechanism: the
paper notes the improvement "widens in the tail (P90–P99), where straggler
effects dominate".  We therefore report tail latency under bursty
heterogeneous batches vs the strongest per-request hybrid (cake) — and mean
TTFT vs the classic baselines (vllm/lmcache), where the 1.6–2.6× band lives.
"""
import numpy as np

from benchmarks.common import row, sim_ttft
from repro.serving.request import Request


def _burst(n, seed):
    rng = np.random.default_rng(seed)
    lens = rng.integers(2000, 30000, n)
    return [Request(f"b{i}", 0.0, int(lens[i]), 128) for i in range(n)]


def run():
    rows = []
    tail_gains = []
    for bs in (2, 4, 8):
        classic = min(
            sim_ttft(s, requests=_burst(24, 3), hw="l40s", arch="llama3.1-8b",
                     max_batch=bs, stages=1).stats["mean"]
            for s in ("vllm", "lmcache"))
        cake = sim_ttft("cake", requests=_burst(24, 3), hw="l40s",
                        arch="llama3.1-8b", max_batch=bs, stages=1).stats
        cf = sim_ttft("cacheflow", requests=_burst(24, 3), hw="l40s",
                      arch="llama3.1-8b", max_batch=bs, stages=1).stats
        tail_gains.append(cake["p99"] / cf["p99"])
        rows.append(row(
            f"fig10/batch={bs}", cf["mean"],
            f"vs_classic={classic / cf['mean']:.2f}x (paper band 1.6-2.6x) "
            f"tail_vs_cake={cake['p99'] / cf['p99']:.3f}x "
            f"e2e={cf['e2e_mean']:.3f}s tok/s={cf['tokens_per_sec']:.1f}"))
    rows.append(row("fig10/batch-awareness", 0.0,
                    f"p99_gain_vs_cake@2={tail_gains[0]:.3f}x "
                    f"@8={tail_gains[-1]:.3f}x grows={tail_gains[-1] >= tail_gains[0]}"))
    return rows
