"""Preemption under admission pressure (DESIGN.md §9): on the bursty
two-priority workload with a tight ``max_active`` cap, ``preempt="priority"``
must cut the high-priority mean TTFT vs FCFS-only admission while the total
makespan regresses < 10% — and preempted requests must lose zero completed
restoration units (resume, not restart).

CLI: ``python benchmarks/preemption.py [--smoke]``.  Emits
``BENCH_preemption.json`` (repo root + ``benchmarks/results/``).
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit_bench, row  # noqa: E402
from repro.config import HARDWARE, IO_BANDWIDTHS  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.serving import Request, SimServingEngine  # noqa: E402
from repro.serving.workloads import bursty_priority  # noqa: E402

POLICIES = ("none", "priority", "deadline")


def _run(policy, reqs):
    cfg = get_config("qwen3-8b")
    eng = SimServingEngine(cfg, HARDWARE["h100"],
                           io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                           stages=2, max_batch=2, preempt=policy)
    return eng.run([Request(r.request_id, r.arrival, r.prefix_len, r.new_len,
                            decode_len=r.decode_len, priority=r.priority,
                            deadline=r.deadline) for r in reqs])


def run(smoke: bool = False):
    # the sweep is pure simulation and already CI-cheap, so --smoke keeps
    # the exact workload (and hence the acceptance assertions) intact
    reqs = bursty_priority(36, seed=2, burst_every=1.0, burst_size=3)
    hi = [r.request_id for r in reqs if r.priority > 0]
    rows, dump = [], {}
    base_hi = base_end = None
    for policy in POLICIES:
        rep = _run(policy, reqs)
        hi_mean = float(np.mean([rep.ttfts[h] for h in hi]))
        end = max(rep.e2e[r.request_id] + r.arrival for r in reqs)
        n_pre = sum(rep.preemptions.values())
        if policy == "none":
            base_hi, base_end = hi_mean, end
        dump[policy] = {"hi_ttft_mean": hi_mean, "makespan": end,
                        "preemptions": n_pre,
                        "hi_ttft_p99": float(np.percentile(
                            [rep.ttfts[h] for h in hi], 99))}
        rows.append(row(f"preempt/{policy}", hi_mean,
                        f"hi_ttft={hi_mean:.3f}s "
                        f"vs_none={hi_mean / base_hi:.2f}x "
                        f"makespan={end:.3f}s "
                        f"makespan_vs_none={end / base_end:.3f}x "
                        f"preemptions={n_pre}"))
    emit_bench("preemption", dump)
    # acceptance: priority preemption pays off and costs < 10% makespan
    assert dump["priority"]["preemptions"] > 0
    assert dump["priority"]["hi_ttft_mean"] < dump["none"]["hi_ttft_mean"]
    assert dump["priority"]["makespan"] < dump["none"]["makespan"] * 1.10
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI alias — the sim sweep is already tiny, so "
                         "this runs the same workload and assertions")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in run(smoke=args.smoke):
        print(line)


if __name__ == "__main__":
    main()
