"""Restoration data-path throughput: fused pipeline vs legacy `.at[].set()`.

Real-mode micro-benchmark of the thing PR-level scheduling wins ultimately
cash out through — how fast KV bytes actually move from the store's tiers
into the live cache.  A request's prefix is materialized in the host tier,
then restored through load-only plans (pure I/O: every byte on the wire
is a restoration transfer and both paths move EXACTLY the same chunks):

  * ``legacy``  — per-chunk ``fetch`` (host-side dequant) + one
    ``.at[].set()`` per chunk × layer × field;
  * ``fused``   — ``fetch_range_packed`` staging through a double-buffered
    ``TransferStream`` + ONE ``kv_restore`` dequant-scatter launch per op
    (``core/datapath.py``).

Swept over store chunk size and quant mode.  Reported: restoration GB/s
(restored cache bytes / restore wall), dispatched copy ops, wire bytes,
and engine-level TTFT through each path.  Acceptance (asserted):

  * fused issues STRICTLY fewer copy dispatches and ≥1.5× the measured
    restoration throughput of legacy on every swept config;
  * int8 moves ~half the fp16-equivalent bytes end-to-end;
  * fused restoration is bit-identical to the full-prefill reference for
    ``quant="none"`` and within ``quant_tolerance()`` for int8.

Emits ``BENCH_restore.json`` (repo root + ``benchmarks/results/``, the
perf trajectory seed).  CLI: ``python benchmarks/restore_datapath.py
[--smoke]``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit_bench, row  # noqa: E402

_MODEL = {}

_EXEC_CHUNK = 16


def _model():
    if not _MODEL:
        import jax
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("qwen3-8b").reduced()
        m = build_model(cfg)
        _MODEL.update(cfg=cfg, model=m, params=m.init(jax.random.PRNGKey(0)),
                      itemsize=np.dtype(m.compute_dtype).itemsize)
    return _MODEL


def _executor(*, fused: bool, quant: str, store_chunk: int):
    from repro.core.datapath import RestoreDatapath
    from repro.core.executor import RestorationExecutor
    from repro.serving import ChunkStore
    mm = _model()
    store = ChunkStore(chunk_size=store_chunk, quant=quant,
                      default_tier="host")
    dp = RestoreDatapath.for_channels(1) if fused else None
    ex = RestorationExecutor(mm["model"], mm["params"],
                             chunk_size=_EXEC_CHUNK, stages=1,
                             chunk_store=store, datapath=dp)
    return ex, store


def _plans(n):
    from repro.core.baselines import make_baseline_plans
    # load-only: restoration is pure I/O, so fused and legacy move the
    # same chunks deterministically (byte accounting is exact)
    return make_baseline_plans("lmcache", "r", n, chunk_size=_EXEC_CHUNK,
                               l_delta=0,
                               num_layers=_model()["cfg"].num_layers)


def _restore_once(ex, store, n):
    """One cold restoration: demote everything off-device, restore through
    the engine core in measured mode, return (wall, wire bytes, dispatches,
    cache)."""
    if ex.is_live("r"):
        ex.drop_restore("r")
    for k in store.requests["r"]:
        if store.core.tier_of(k) == "hbm":
            store.core.put(k, "host")
    b0, d0 = store.bytes_transferred, ex.load_dispatches
    t0 = time.perf_counter()
    cache = ex.restore("r", plans=_plans(n), op_order="measured")
    wall = time.perf_counter() - t0
    return (wall, store.bytes_transferred - b0, ex.load_dispatches - d0,
            cache)


def _measure(fused: bool, quant: str, store_chunk: int, n: int,
             iters: int) -> dict:
    import jax
    ex, store = _executor(fused=fused, quant=quant, store_chunk=store_chunk)
    inputs = jax.random.randint(jax.random.PRNGKey(1), (1, n), 0,
                                _model()["cfg"].vocab_size)
    ex.remember("r", inputs)
    cache_bytes = sum(np.asarray(a).nbytes
                      for f, a in ex.store.get("r").kv_reference.items())
    best, wire, disp, cache = None, None, None, None
    for _ in range(iters):
        wall, wire, disp, cache = _restore_once(ex, store, n)
        best = wall if best is None else min(best, wall)
    # correctness rides along as an acceptance criterion
    if quant == "none":
        ref = ex.store.get("r").kv_reference
        for f in ref:
            assert np.array_equal(np.asarray(ref[f]), np.asarray(cache[f])), f
    else:
        ex.verify("r", atol=2e-2 + store.quant_tolerance())
    store.audit()
    return dict(wall=best, gbps=cache_bytes / best / 1e9, wire=wire,
                dispatches=disp, cache_bytes=cache_bytes)


def _engine_ttft(datapath: str, quant: str, n_reqs: int) -> float:
    from repro.serving import ChunkStore, RealServingEngine, Request
    mm = _model()
    store = ChunkStore(chunk_size=8, quant=quant, default_tier="host")
    eng = RealServingEngine(mm["model"], mm["params"], system="lmcache",
                            stages=1, chunk_size=_EXEC_CHUNK, kvstore=store,
                            datapath=datapath)
    reqs = [Request(f"r{i}", 0.0, 48 + 16 * i, 8, decode_len=2)
            for i in range(n_reqs)]
    rep = eng.serve(reqs)
    return float(np.mean(list(rep.ttfts.values())))


def run(smoke: bool = False):
    rows = []
    n = 96 if smoke else 192
    iters = 2 if smoke else 3
    chunks = (8,) if smoke else (4, 8)
    quants = ("none", "int8")
    results = {"prefix_tokens": n, "exec_chunk": _EXEC_CHUNK, "configs": []}
    wire = {}
    for store_chunk in chunks:
        for quant in quants:
            fused = _measure(True, quant, store_chunk, n, iters)
            legacy = _measure(False, quant, store_chunk, n, iters)
            speedup = fused["gbps"] / legacy["gbps"]
            wire[(store_chunk, quant)] = fused["wire"]
            rows.append(row(
                f"restore/real/chunk={store_chunk}/quant={quant}/fused",
                fused["wall"],
                f"gbps={fused['gbps']:.3f} dispatches={fused['dispatches']} "
                f"wire={fused['wire']} speedup={speedup:.2f}x"))
            rows.append(row(
                f"restore/real/chunk={store_chunk}/quant={quant}/legacy",
                legacy["wall"],
                f"gbps={legacy['gbps']:.3f} "
                f"dispatches={legacy['dispatches']}"))
            results["configs"].append(dict(
                store_chunk=store_chunk, quant=quant,
                fused_gbps=round(fused["gbps"], 5),
                legacy_gbps=round(legacy["gbps"], 5),
                speedup=round(speedup, 3),
                fused_dispatches=fused["dispatches"],
                legacy_dispatches=legacy["dispatches"],
                wire_bytes=fused["wire"],
                cache_bytes=fused["cache_bytes"]))
            # tentpole acceptance: strictly fewer copy dispatches AND
            # >=1.5x measured restoration throughput, identical wire bytes
            assert fused["dispatches"] < legacy["dispatches"], \
                (fused["dispatches"], legacy["dispatches"])
            assert speedup >= 1.5, (store_chunk, quant, speedup)
            assert fused["wire"] == legacy["wire"], \
                (fused["wire"], legacy["wire"])
    # int8 moves ~half the fp16-equivalent bytes end-to-end
    itemsize = _model()["itemsize"]
    for store_chunk in chunks:
        fp16_equiv = wire[(store_chunk, "none")] * 2 / itemsize
        ratio = wire[(store_chunk, "int8")] / fp16_equiv
        rows.append(row(f"restore/real/chunk={store_chunk}/int8_bytes", 0.0,
                        f"ratio_vs_fp16={ratio:.3f}"))
        assert 0.4 < ratio < 0.75, (store_chunk, ratio)
    # engine-level TTFT through each datapath (two serves per mode, best
    # taken: the first pays one-off jit compilation, not transfer cost)
    nr = 2 if smoke else 4
    ttft_f = min(_engine_ttft("fused", "none", nr) for _ in range(2))
    ttft_l = min(_engine_ttft("legacy", "none", nr) for _ in range(2))
    rows.append(row("restore/real/ttft/fused", ttft_f,
                    f"legacy={ttft_l * 1e6:.1f}us "
                    f"speedup={ttft_l / ttft_f:.2f}x"))
    results["ttft_fused_s"] = round(ttft_f, 6)
    results["ttft_legacy_s"] = round(ttft_l, 6)
    emit_bench("restore", results)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (1 chunk size, short prefix)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in run(smoke=args.smoke):
        print(line)


if __name__ == "__main__":
    main()
