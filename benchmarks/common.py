"""Shared helpers for the paper-figure benchmarks.

Every benchmark returns rows ``(name, us_per_call, derived)`` where
``us_per_call`` is the mean simulated/measured TTFT (µs) of the subject
system and ``derived`` a figure-specific headline (speedup, crossover, ...).
Simulation benches use the paper's hardware profiles; "real:" benches run
reduced models on this host.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.config import HARDWARE, IO_BANDWIDTHS  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.serving import SimServingEngine, generate  # noqa: E402
from repro.serving.metrics import dumps_report  # noqa: E402

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RESULTS = os.path.join(os.path.dirname(__file__), "results")
os.makedirs(RESULTS, exist_ok=True)

DEFAULTS = dict(hw="h100", bw="10Gbps", arch="qwen3-8b", stages=2,
                max_batch=8, n_requests=32)


def sim_ttft(system: str, *, workload="swe_bench", arch=None, hw=None, bw=None,
             stages=None, max_batch=None, n_requests=None, seed=1,
             requests=None, io_channels=1, admission="continuous",
             prefetch=False, kvstore=None, kv_tier="host", **engine_kw):
    """One simulated serving run; returns the (stream-safe) ServingReport.

    Per-request finish events live in ``report.finishes`` and every rate in
    ``report.stats`` divides by the active serving span — NOT the engine
    makespan — so the helper is safe for continuous-batching sweeps where
    the offered stream outlives the measured window (the old makespan
    denominator silently assumed every request retired at batch close)."""
    cfg = get_config(arch or DEFAULTS["arch"])
    reqs = requests if requests is not None else \
        generate(workload, n_requests or DEFAULTS["n_requests"], seed=seed)
    eng = SimServingEngine(
        cfg, HARDWARE[hw or DEFAULTS["hw"]],
        io_bandwidth=IO_BANDWIDTHS[bw or DEFAULTS["bw"]],
        system=system, stages=stages if stages is not None else DEFAULTS["stages"],
        max_batch=max_batch if max_batch is not None else DEFAULTS["max_batch"],
        io_channels=io_channels, admission=admission, prefetch=prefetch,
        kvstore=kvstore, kv_tier=kv_tier, **engine_kw)
    return eng.run(reqs)


def row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def emit_bench(name: str, payload: dict, root: str = REPO_ROOT) -> str:
    """Write a benchmark result as ``BENCH_<name>.json`` in two places:
    the repo root (where CI and the driver look for machine-readable
    results) and ``benchmarks/results/`` (kept with the figure CSVs).
    Serializes via :func:`dumps_report` so the files are strict JSON —
    non-finite floats become ``null`` instead of bare ``NaN`` tokens.
    Returns the repo-root path."""
    text = dumps_report(payload)
    out = os.path.join(root, f"BENCH_{name}.json")
    for path in (out, os.path.join(RESULTS, f"BENCH_{name}.json")):
        with open(path, "w") as f:
            f.write(text)
            f.write("\n")
    return out
