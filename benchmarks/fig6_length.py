"""Paper Fig. 6: TTFT by input length 6K→30K — vLLM grows superlinearly,
CacheFlow's gap widens from ~1.1× to ~1.7×."""
from benchmarks.common import row, sim_ttft
from repro.serving.workloads import fixed_length


def run():
    rows = []
    gaps = []
    for n in (6000, 12000, 20000, 30000):
        reqs_v = fixed_length(8, n, seed=0)
        reqs_c = fixed_length(8, n, seed=0)
        tv = sim_ttft("vllm", requests=reqs_v).stats["mean"]
        tc = sim_ttft("cacheflow", requests=reqs_c).stats["mean"]
        gaps.append(tv / tc)
        rows.append(row(f"fig6/n={n}", tc, f"vllm={tv:.3f}s gap={tv / tc:.2f}x"))
    rows.append(row("fig6/gap-widening", 0.0,
                    f"gap@6k={gaps[0]:.2f}x gap@30k={gaps[-1]:.2f}x "
                    f"widens={gaps[-1] > gaps[0]}"))
    return rows
