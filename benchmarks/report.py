"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSONs.  Run after (re-)sweeping:

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import os

from benchmarks.roofline import DRYRUN_DIR, analyze_cell, load_cells
from repro.config import SHAPES
from repro.configs import ASSIGNED_ARCHS


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(mesh: str) -> str:
    lines = [
        f"#### Mesh {mesh}",
        "",
        "| arch | shape | mode | args GB/dev | temp GB/dev | peak GB/dev | "
        "GFLOP/dev | coll MB/dev (AG/AR/RS/A2A) | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(path):
                continue
            with open(path) as f:
                d = json.load(f)
            if "skipped" in d:
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                             f"skip ({d['skipped']}) | — |")
                continue
            m = d["memory"]
            cb = d["collectives"]["bytes"]
            coll = (f"{cb['all-gather'] / 2**20:.0f}/{cb['all-reduce'] / 2**20:.0f}/"
                    f"{cb['reduce-scatter'] / 2**20:.0f}/{cb['all-to-all'] / 2**20:.0f}")
            lines.append(
                f"| {arch} | {shape} | {d['mode']} | {_fmt_bytes(m['argument_bytes'])} "
                f"| {_fmt_bytes(m['temp_bytes'])} | {_fmt_bytes(m['peak_estimate_bytes'])} "
                f"| {d['flops_per_device'] / 1e9:.0f} | {coll} | {d['compile_s']:.1f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    cells = load_cells("16x16")
    lines = [
        "| arch | shape | comp s | mem s | coll s | dominant | useful | "
        "roofline frac | peak GB | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if "skipped" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                         f"skip({c['skipped']}) | — | — | — | — |")
            continue
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_s']:.2e} | "
            f"{c['memory_s']:.2e} | {c['collective_s']:.2e} | **{c['dominant']}** | "
            f"{c['useful_ratio']:.2f} | {c['roofline_fraction']:.1%} | "
            f"{c['peak_gb_per_device']:.1f} | {'✓' if c['fits_16gb'] else '✗'} |")
    return "\n".join(lines)


def summarize() -> str:
    cells = [c for c in load_cells("16x16") if "skipped" not in c]
    if not cells:
        return "(no cells analyzed yet)"
    by_dom = {}
    for c in cells:
        by_dom.setdefault(c["dominant"], []).append(c)
    out = [f"Cells analyzed: {len(cells)}. Dominant terms: " +
           ", ".join(f"{k}: {len(v)}" for k, v in sorted(by_dom.items()))]
    worst = sorted(cells, key=lambda c: c["roofline_fraction"])[:5]
    out.append("Worst roofline fractions: " +
               ", ".join(f"{c['arch']}×{c['shape']}={c['roofline_fraction']:.1%}"
                         for c in worst))
    coll = sorted(cells, key=lambda c: -c["collective_s"])[:3]
    out.append("Most collective-bound: " +
               ", ".join(f"{c['arch']}×{c['shape']}={c['collective_s']:.2e}s"
                         for c in coll))
    nofit = [c for c in cells if not c["fits_16gb"]]
    out.append("Over 16 GB/device: " +
               (", ".join(f"{c['arch']}×{c['shape']}({c['peak_gb_per_device']:.0f}GB)"
                          for c in nofit) or "none"))
    return "\n".join(out)


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table("16x16"))
    print()
    print(dryrun_table("2x16x16"))
    print("\n## Roofline (single-pod 16x16, v5e constants)\n")
    print(roofline_table())
    print("\n### Summary\n")
    print(summarize())
