"""Paper Fig. 1c: recomputation is superlinear in length; I/O restoration is
linear but bandwidth-bound — neither wins everywhere."""
from benchmarks.common import row
from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core.cost_model import CostModel


def run():
    cfg = get_config("qwen3-8b")
    rows = []
    for bw_name in ("10Gbps", "80Gbps"):
        cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS[bw_name], mfu=0.45)
        for n in (500, 2000, 8000, 20000, 32000):
            tc = cost.t_comp(n)
            tio = cost.t_io_tokens(n)
            rows.append(row(f"fig1c/recompute/n={n}", tc, f"bw={bw_name}"))
            rows.append(row(f"fig1c/io/{bw_name}/n={n}", tio,
                            f"io_beats_compute={tio < tc}"))
    # headline: superlinearity factor of recompute 500 -> 32000 tokens
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    superlin = (cost.t_comp(32000) / cost.t_comp(500)) / (32000 / 500)
    rows.append(row("fig1c/superlinearity", cost.t_comp(32000),
                    f"superlinear_factor={superlin:.2f}x"))
    return rows
