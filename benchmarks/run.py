"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (skeleton contract)."""
from __future__ import annotations

import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MODULES = [
    "benchmarks.fig1c_restore_latency",
    "benchmarks.fig3_crossover",
    "benchmarks.fig4_ttft_cdf",
    "benchmarks.fig5_utilization",
    "benchmarks.fig6_length",
    "benchmarks.fig7_ablation_3d",
    "benchmarks.fig8_bandwidth",
    "benchmarks.fig9_hardware",
    "benchmarks.fig10_batch",
    "benchmarks.fig11_storage",
    "benchmarks.fork",
    "benchmarks.restore_datapath",
    "benchmarks.preemption",
    "benchmarks.throughput",
    "benchmarks.roofline",
]


def main() -> None:
    import importlib

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            for line in mod.run():
                print(line)
            print(f"{mod_name.split('.')[-1]}/bench_wall,"
                  f"{(time.time() - t0) * 1e6:.0f},ok")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{mod_name},0,FAILED")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
