"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape × mesh), from the compiled per-device SPMD module:

  compute term    = HLO_FLOPs_per_device / (peak_FLOP/s)
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

(cost_analysis is already per-device post-partitioning, so per-device values
divided by per-chip rates ARE the "global / (chips × rate)" terms.)

Also reports MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (inference forward)
vs HLO_FLOPs — the useful-compute ratio that exposes remat/dispatch waste —
and whether the per-device memory estimate fits v5e's 16 GB HBM.
"""
from __future__ import annotations

import csv
import json
import os
from typing import Optional

from repro.config import HARDWARE, SHAPES
from repro.configs import ASSIGNED_ARCHS, get_config

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
HW = HARDWARE["tpu_v5e"]


def model_flops(arch: str, shape_name: str) -> float:
    """Useful math per step (global): train backward multiplier 3×."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pc = cfg.param_counts()
    n = pc["active"] - pc["embedding"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens + 3.0 * _attn_flops(cfg, shape.seq_len, tokens)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens + _attn_flops(cfg, shape.seq_len, tokens)
    # decode: one token per sequence, attention over the cache
    tokens = shape.global_batch
    ctx = shape.seq_len
    return 2.0 * n * tokens + _attn_flops(cfg, ctx, tokens, decode=True)


def _attn_flops(cfg, ctx, tokens, decode=False):
    n_attn = len(cfg.attention_layers)
    if n_attn == 0:
        return 0.0
    eff = min(ctx, cfg.attn_window) if cfg.attn_window else ctx
    avg = eff if decode else eff / 2
    return 2.0 * 2.0 * n_attn * cfg.num_heads * cfg.qk_head_dim * tokens * avg


def min_memory_bytes(arch: str, shape_name: str, chips: int) -> float:
    """Analytic per-device lower bound on HBM traffic for one step: weights
    must be read once (twice + optimizer state for training), the KV cache
    read (decode) or written (prefill), and activations touched once.
    cost_analysis' byte counts share the while-body undercount, so the
    memory roofline term uses max(HLO bytes, this floor)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pc = cfg.param_counts()
    if shape.kind == "train":
        # fp32 master + m + v read/write + bf16 cast read ≈ 26 B/param
        w = pc["total"] * 26.0
        act = shape.global_batch * shape.seq_len * cfg.d_model * 2 * 4
        return (w + act) / chips
    w = pc["active" if shape.kind == "decode" else "total"] * 2.0
    if shape.kind == "prefill":
        cache = cfg.kv_bytes_per_token() * shape.global_batch * shape.seq_len
        act = shape.global_batch * shape.seq_len * cfg.d_model * 2 * 2
        return (pc["total"] * 2.0 + cache + act) / chips
    # decode: read whole cache + all (active) weights
    eff = min(shape.seq_len, cfg.attn_window) if cfg.attn_window else shape.seq_len
    cache = cfg.kv_bytes_per_token() * shape.global_batch * eff
    cache += cfg.state_bytes(shape.global_batch)
    return (w + cache) / chips


def analyze_cell(data: dict) -> Optional[dict]:
    if "skipped" in data:
        return None
    chips = 512 if data["mesh"] == "2x16x16" else 256
    corr = data.get("corrected", {})
    flops_dev = corr.get("dot_flops_per_device") or data["flops_per_device"]
    coll_dev = corr.get("collective_total_bytes",
                        data["collectives"]["total_bytes"])
    mem_floor = min_memory_bytes(data["arch"], data["shape"], chips)
    mem_dev = max(data["bytes_per_device"], mem_floor)
    t_comp = flops_dev / HW.peak_flops
    t_mem = mem_dev / HW.hbm_bw
    t_coll = coll_dev / HW.link_bw
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(data["arch"], data["shape"])
    hlo_global = flops_dev * chips
    useful = mf / hlo_global if hlo_global > 0 else float("nan")
    peak_gb = data["memory"]["peak_estimate_bytes"] / 2**30
    # roofline fraction: the step's own ideal (useful flops / memory floor)
    # over its actual dominant term
    ideal = max(mf / chips / HW.peak_flops, mem_floor / HW.hbm_bw)
    step_time = max(terms.values())
    frac = ideal / step_time if step_time > 0 else 0.0
    return {
        "arch": data["arch"], "shape": data["shape"], "mesh": data["mesh"],
        "mode": data.get("mode", "?"),
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "roofline_fraction": min(frac, 1.0),
        "peak_gb_per_device": peak_gb,
        "fits_16gb": peak_gb <= 16.0,
    }


def load_cells(mesh: str = "16x16"):
    out = []
    if not os.path.isdir(DRYRUN_DIR):
        return out
    for fn in sorted(os.listdir(DRYRUN_DIR)):
        if not fn.endswith(f"__{mesh}.json"):
            continue
        with open(os.path.join(DRYRUN_DIR, fn)) as f:
            data = json.load(f)
        cell = analyze_cell(data)
        if cell:
            out.append(cell)
        else:
            out.append({"arch": data["arch"], "shape": data["shape"],
                        "mesh": data.get("mesh", mesh), "skipped": data["skipped"]})
    return out


def write_csv(cells, path):
    keys = ["arch", "shape", "mesh", "mode", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_ratio", "roofline_fraction",
            "peak_gb_per_device", "fits_16gb"]
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys, extrasaction="ignore")
        w.writeheader()
        for c in cells:
            if "skipped" not in c:
                w.writerow(c)


def run():
    """Benchmark-harness entry: emits one row per dry-run cell."""
    from benchmarks.common import row
    cells = load_cells("16x16")
    rows = []
    done = {(c["arch"], c["shape"]) for c in cells if "skipped" not in c}
    for c in cells:
        if "skipped" in c:
            rows.append(row(f"roofline/{c['arch']}/{c['shape']}", 0.0,
                            f"skipped:{c['skipped']}"))
            continue
        step = max(c["compute_s"], c["memory_s"], c["collective_s"])
        rows.append(row(
            f"roofline/{c['arch']}/{c['shape']}", step,
            f"dom={c['dominant']} comp={c['compute_s']:.2e}s "
            f"mem={c['memory_s']:.2e}s coll={c['collective_s']:.2e}s "
            f"useful={c['useful_ratio']:.2f} mfu={c['roofline_fraction']:.2%} "
            f"fits16GB={c['fits_16gb']}"))
    if done:
        write_csv(cells, os.path.join(os.path.dirname(__file__), "results",
                                      "roofline.csv"))
        rows.append(row("roofline/cells-analyzed", 0.0,
                        f"count={len(done)} (expected {_expected_cells()})"))
    return rows


def _expected_cells() -> int:
    n = 0
    from repro.config import supports_shape
    for a in ASSIGNED_ARCHS:
        for s in SHAPES.values():
            n += supports_shape(get_config(a), s)
    return n


if __name__ == "__main__":
    for r in run():
        print(r)
