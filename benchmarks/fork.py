"""Session forking on the paged-block KV cache: O(1) branch restoration.

Real-mode (reduced model, on-host): a parent request is served through the
materialized ``ChunkStore``, leaving its prefix resident as refcounted
device blocks in the shared ``BlockPool``.  K branch requests carrying
``meta={"fork_of": parent}`` then fork the session — block tables alias
the parent's physical blocks (refcount bumps, zero bytes) and each branch
reaches its first token with ~zero restoration traffic.  The baseline is
a full re-restore: the same branch after every parent chunk was demoted
off-device, which must move the whole prefix back over the interconnect.

Also pinned here, as acceptance criteria:

  * copy-on-write is O(1) per fork — a branch appending into a shared
    (non-block-aligned) tail block copies exactly ONE block, independent
    of prefix length;
  * partial eviction is block-granular — demoting HALF the parent's
    chunks and re-serving a branch transfers EXACTLY the demoted bytes,
    not the whole prefix from token 0.

CLI: ``python benchmarks/fork.py [--smoke]``.
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit_bench, row  # noqa: E402

_MODEL = {}

_CHUNK = 8


def _model():
    if not _MODEL:
        import jax
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("qwen3-8b").reduced()
        m = build_model(cfg)
        _MODEL.update(cfg=cfg, model=m, params=m.init(jax.random.PRNGKey(0)))
    return _MODEL


def _engine():
    from repro.serving import ChunkStore, RealServingEngine
    mm = _model()
    store = ChunkStore(chunk_size=_CHUNK, quant="none", default_tier="host")
    # the load-only baseline makes restoration pure I/O, so every byte on
    # the wire is a restoration transfer and the fork-vs-rerestore byte
    # accounting below is exact (cacheflow's two-pointer race lets compute
    # claim chunks dynamically — WHICH chunks load becomes schedule-
    # dependent, the wrong substrate for byte assertions)
    eng = RealServingEngine(mm["model"], mm["params"], system="lmcache",
                            stages=2, chunk_size=_CHUNK, kvstore=store)
    return eng, store


def _branch(i, prefix_len, *, decode_len):
    from repro.serving import Request
    return Request(f"b{i}", 0.05 * i, prefix_len, 8, decode_len=decode_len,
                   meta={"fork_of": "parent"})


def _fork_tree(prefix_len: int, branches: int, *, decode_len=2):
    """Serve parent, fork K branches, then measure the three regimes:
    resident fork, half-demoted (partial) refetch, full re-restore."""
    from repro.serving import Request
    eng, store = _engine()

    eng.serve([Request("parent", 0.0, prefix_len, 8, decode_len=decode_len)],
              verify=True)
    parent_bytes = store.bytes_transferred
    cow0 = store.pool.bytes_copied

    # K forked branches against the fully-resident parent: zero transfers
    b0 = store.bytes_transferred
    rep = eng.serve([_branch(i, prefix_len, decode_len=decode_len)
                     for i in range(branches)], verify=True)
    fork_bytes = store.bytes_transferred - b0
    fork_ttft = float(np.mean(list(rep.ttfts.values())))
    cow_per_branch = (store.pool.bytes_copied - cow0) / branches
    store.audit()

    # partial eviction: demote HALF the chunks, one more branch — the
    # refetch must move exactly the demoted bytes (block granularity)
    keys = store.requests["parent"]
    demoted = 0
    for k in keys[len(keys) // 2:]:
        store.core.put(k, "host")
        demoted += store._size(k, "host")
    b1 = store.bytes_transferred
    eng.serve([_branch(branches, prefix_len, decode_len=decode_len)],
              verify=True)
    partial_bytes = store.bytes_transferred - b1
    store.audit()

    # full re-restore baseline: every chunk demoted, whole prefix on the wire
    for k in keys:
        store.core.put(k, "host")
    b2 = store.bytes_transferred
    rep = eng.serve([_branch(branches + 1, prefix_len, decode_len=decode_len)],
                    verify=True)
    full_bytes = store.bytes_transferred - b2
    full_ttft = float(np.mean(list(rep.ttfts.values())))
    store.audit()

    return dict(parent_bytes=parent_bytes, fork_bytes=fork_bytes,
                fork_ttft=fork_ttft, cow_per_branch=cow_per_branch,
                demoted=demoted, partial_bytes=partial_bytes,
                full_bytes=full_bytes, full_ttft=full_ttft,
                forks=store.forks, block_nbytes=store.pool.block_nbytes)


def run(smoke: bool = False):
    rows = []
    # non-block-aligned prefixes so every branch's append lands in a SHARED
    # tail block and exercises copy-on-write (aligned appends open a fresh
    # block — legal, but then there is nothing to copy)
    prefixes = (36,) if smoke else (36, 68)
    branches = 2 if smoke else 3
    per_prefix = []
    for pl in prefixes:
        m = _fork_tree(pl, branches)
        per_prefix.append(m)
        rows.append(row(
            f"fork/real/prefix={pl}/fork", m["fork_ttft"],
            f"bytes={m['fork_bytes']} vs_full={m['full_bytes']} "
            f"cow_bytes_per_branch={m['cow_per_branch']:.0f} "
            f"forks={m['forks']}"))
        rows.append(row(
            f"fork/real/prefix={pl}/full_rerestore", m["full_ttft"],
            f"bytes={m['full_bytes']} "
            f"fork_vs_full={m['fork_bytes'] / max(1, m['full_bytes']):.3f}x"))
        rows.append(row(
            f"fork/real/prefix={pl}/partial_evict", 0.0,
            f"bytes={m['partial_bytes']} demoted={m['demoted']} "
            f"full={m['full_bytes']}"))
        # forked branches reach first token with ~zero restoration bytes
        assert m["fork_bytes"] <= 0.05 * m["full_bytes"], \
            (m["fork_bytes"], m["full_bytes"], "fork was not ~zero-transfer")
        # block-granular partial eviction: exactly the missing bytes move
        assert m["partial_bytes"] == m["demoted"], \
            (m["partial_bytes"], m["demoted"])
        assert m["partial_bytes"] < m["full_bytes"], \
            (m["partial_bytes"], m["full_bytes"])
        # CoW per branch is bounded by one physical block
        assert 0 < m["cow_per_branch"] <= m["block_nbytes"], \
            (m["cow_per_branch"], m["block_nbytes"])
    if len(per_prefix) > 1:
        # O(1) claim: copied bytes per fork do NOT grow with prefix length
        a, b = per_prefix[0], per_prefix[-1]
        assert a["cow_per_branch"] == b["cow_per_branch"], \
            (a["cow_per_branch"], b["cow_per_branch"],
             "CoW bytes grew with prefix length")
    emit_bench("fork", {
        "branches": branches,
        "per_prefix": [dict(prefix_len=pl, **m)
                       for pl, m in zip(prefixes, per_prefix)]})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (1 prefix length, 2 branches)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in run(smoke=args.smoke):
        print(line)


if __name__ == "__main__":
    main()
