"""Paged-block KV cache (DESIGN.md §12): block pool refcounting,
copy-on-write session forking, block-granular restoration residency, and
the placement-core accounting fixes that rode along.

Covers: pool alloc/free/refcount invariants (incl. double-free detection
and free-list reuse), O(1)-copied-bytes ``clone()``, CoW isolation (a
branch's append never mutates the parent's or the store's bytes), refcount
conservation under randomized fork/append/free interleavings, end-to-end
fork serving with ~zero restoration transfers, block-granular partial
eviction (re-restoration moves only the missing blocks), bit-identical
trace replay of forked schedules, and the PlacementCore regressions:
no-op promote leaves promotions/LRU untouched, integer-exact byte
accounting, and victim ties broken in LRU order."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.trace import TraceRecorder, replay_trace
from repro.models import build_model
from repro.models.kvcache import BlockPool, PagedKVCache
from repro.serving import ChunkStore, RealServingEngine, Request
from repro.storage import PlacementCore, Tier

RNG = jax.random.PRNGKey(0)

BS = 4          # block size (tokens) for pure pool/table tests


def _payload(n_tokens, *, seed=0, layers=2, heads=2, dh=3):
    """A small attention-KV payload covering ``n_tokens`` tokens."""
    r = np.random.default_rng(seed)
    return {
        "k": jnp.asarray(r.normal(size=(layers, 1, n_tokens, heads, dh)),
                         jnp.float32),
        "v": jnp.asarray(r.normal(size=(layers, 1, n_tokens, heads, dh)),
                         jnp.float32),
        "kpos": jnp.arange(n_tokens, dtype=jnp.int32)[None].repeat(layers, 0),
    }


# ---------------------------------------------------------------------------
# BlockPool: refcount lifecycle
# ---------------------------------------------------------------------------


def test_pool_alloc_read_roundtrip_and_tail_padding():
    pool = BlockPool(BS, capacity=2)
    full = _payload(BS)
    bid = pool.alloc(full)
    got = pool.read(bid)
    np.testing.assert_array_equal(got["k"], full["k"])
    np.testing.assert_array_equal(got["kpos"], full["kpos"])
    # a short (tail) payload pads to one block: zeros for KV, -1 for kpos
    tail = pool.alloc(_payload(BS - 2, seed=1))
    got = pool.read(tail)
    assert got["k"].shape[2] == BS
    np.testing.assert_array_equal(np.asarray(got["k"])[:, :, BS - 2:], 0.0)
    assert (np.asarray(got["kpos"])[:, BS - 2:] == -1).all()
    pool.audit()


def test_pool_refcount_free_and_reuse():
    pool = BlockPool(BS, capacity=2)
    a = pool.alloc(_payload(BS))
    pool.incref(a)
    pool.decref(a)
    assert pool.live_blocks() == 1       # still one ref
    pool.decref(a)
    assert pool.live_blocks() == 0 and pool.frees == 1
    b = pool.alloc(_payload(BS, seed=2))
    assert b == a                        # freed slot is reused
    pool.audit()


def test_pool_double_free_raises():
    pool = BlockPool(BS)
    a = pool.alloc(_payload(BS))
    pool.decref(a)
    with pytest.raises(AssertionError, match="double free"):
        pool.decref(a)
    with pytest.raises(AssertionError, match="incref of free"):
        pool.incref(a)


def test_pool_write_to_shared_block_refused():
    """write_slice is the sole-owner primitive: callers must CoW first."""
    pool = BlockPool(BS)
    a = pool.alloc(_payload(BS))
    pool.incref(a)
    with pytest.raises(AssertionError, match="shared block"):
        pool.write_slice(a, 0, 1, _payload(1))


def test_pool_grows_past_initial_capacity():
    pool = BlockPool(BS, capacity=1)
    bids = [pool.alloc(_payload(BS, seed=i)) for i in range(5)]
    assert len(set(bids)) == 5 and pool.capacity >= 5
    for i, bid in enumerate(bids):       # slab growth preserved the bytes
        np.testing.assert_array_equal(pool.read(bid)["k"],
                                      _payload(BS, seed=i)["k"])
    pool.audit()


# ---------------------------------------------------------------------------
# PagedKVCache: O(1) fork + copy-on-write
# ---------------------------------------------------------------------------


def test_clone_is_zero_copy_and_aliases_blocks():
    pool = BlockPool(BS)
    parent = PagedKVCache(pool)
    parent.write_span(0, 2 * BS + 1, _payload(2 * BS + 1))
    child = parent.clone()
    assert pool.bytes_copied == 0        # the O(1) fork claim, in bytes
    assert child.blocks == parent.blocks
    assert all(pool.refcounts[b] == 2 for b in child.blocks)
    child.free()
    assert all(pool.refcounts[b] == 1 for b in parent.blocks)
    pool.audit()


def test_cow_isolates_parent_from_child_append():
    """A forked branch appending into the SHARED tail block pays exactly
    one block copy and the parent's bytes stay bit-identical."""
    n = 2 * BS + 1                       # non-block-aligned => shared tail
    pool = BlockPool(BS)
    parent = PagedKVCache(pool)
    parent.write_span(0, n, _payload(n))
    before = {f: np.asarray(a).copy()
              for f, a in parent.read_block(2).items()}
    child = parent.clone()
    child.write_span(n, n + 2, _payload(2, seed=9))
    assert pool.cow_copies == 1
    assert pool.bytes_copied == pool.block_nbytes
    assert child.blocks[2] != parent.blocks[2]   # diverged tail
    assert child.blocks[:2] == parent.blocks[:2]  # full blocks still shared
    after = parent.read_block(2)
    for f in before:
        np.testing.assert_array_equal(before[f], np.asarray(after[f]))
    pool.audit()


def test_aligned_append_opens_fresh_block_no_copy():
    n = 2 * BS                           # block-aligned: nothing shared
    pool = BlockPool(BS)
    parent = PagedKVCache(pool)
    parent.write_span(0, n, _payload(n))
    child = parent.clone()
    child.write_span(n, n + 1, _payload(1, seed=9))
    assert pool.cow_copies == 0 and pool.bytes_copied == 0
    pool.audit()


def test_truncate_drops_tail_refs():
    pool = BlockPool(BS)
    c = PagedKVCache(pool)
    c.write_span(0, 3 * BS, _payload(3 * BS))
    clone = c.clone()
    clone.truncate(BS)                   # keep only the first block
    assert len(clone.blocks) == 1
    assert pool.refcounts[c.blocks[0]] == 2
    assert all(pool.refcounts[b] == 1 for b in c.blocks[1:])
    assert clone.missing_blocks(0, 3 * BS) == [1, 2]
    pool.audit()


@pytest.mark.property
@settings(max_examples=30)
@given(ops=st.lists(st.integers(0, 2), min_size=1, max_size=24),
       n0=st.integers(1, 3 * BS))
def test_refcount_conservation_under_fork_append_free(ops, n0):
    """Random fork/append/free interleavings: every block's refcount equals
    the number of tables mapping it, live+free partitions the pool, and
    freeing every table returns the pool to empty."""
    pool = BlockPool(BS)
    root = PagedKVCache(pool)
    root.write_span(0, n0, _payload(n0))
    tables = [root]
    for i, op in enumerate(ops):
        t = tables[i % len(tables)]
        if op == 0:
            tables.append(t.clone())
        elif op == 1:
            t.write_span(t.n_tokens, t.n_tokens + 3,
                         _payload(3, seed=i))
        elif len(tables) > 1:
            tables.remove(t)
            t.free()
        held = {}
        for tb in tables:
            for b in tb.blocks:
                if b is not None:
                    held[b] = held.get(b, 0) + 1
        assert all(pool.refcounts[b] == n for b, n in held.items())
        assert pool.live_blocks() == len(held)
        pool.audit()
    for t in tables:
        t.free()
    assert pool.live_blocks() == 0
    assert pool.allocs == pool.frees
    pool.audit()


# ---------------------------------------------------------------------------
# End-to-end: fork serving on the materialized store
# ---------------------------------------------------------------------------


def _real_engine(store, **kw):
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    return RealServingEngine(m, params, system=kw.pop("system", "lmcache"),
                             stages=kw.pop("stages", 2), chunk_size=8,
                             kvstore=store, **kw)


def test_forked_branches_restore_with_zero_transfers():
    """Branches carrying meta={'fork_of': parent} alias the parent's
    device blocks: first token with ZERO restoration bytes, verified
    against full-prefill ground truth."""
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _real_engine(store)
    eng.serve([Request("parent", 0.0, 20, 8, decode_len=2)], verify=True)
    assert store.bytes_transferred > 0   # parent did restore over the wire
    b0, cow0 = store.bytes_transferred, store.pool.bytes_copied
    branches = [Request(f"b{i}", 0.05 * i, 20, 8, decode_len=2,
                        meta={"fork_of": "parent"}) for i in range(2)]
    eng.serve(branches, verify=True)
    assert store.bytes_transferred == b0         # forks moved NOTHING
    assert store.forks == 2
    # each branch's append CoWs exactly its shared tail block, nothing more
    assert store.pool.bytes_copied - cow0 == 2 * store.pool.block_nbytes
    for r in branches:
        assert eng.executor.outputs(r.request_id)["tokens"], r.request_id
    store.audit()


def test_partial_eviction_refetches_only_missing_blocks():
    """Demote HALF the parent's chunks off-device: a new branch's
    restoration transfers EXACTLY the demoted bytes — block-granular
    residency, not a restart from token 0."""
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _real_engine(store)
    eng.serve([Request("parent", 0.0, 32, 8, decode_len=2)], verify=True)
    full = store.bytes_transferred
    keys = store.requests["parent"]
    demoted = 0
    for k in keys[len(keys) // 2:]:
        store.core.put(k, "host")
        demoted += store._size(k, "host")
    b0 = store.bytes_transferred
    eng.serve([Request("b0", 0.0, 32, 8, decode_len=2,
                       meta={"fork_of": "parent"})], verify=True)
    moved = store.bytes_transferred - b0
    assert moved == demoted, (moved, demoted)
    assert 0 < moved < full
    store.audit()


def test_fork_prefix_len_mismatch_rejected():
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _real_engine(store)
    eng.serve([Request("parent", 0.0, 16, 8, decode_len=2)], verify=True)
    with pytest.raises(ValueError, match="fork"):
        eng.serve([Request("bad", 0.0, 24, 8, decode_len=2,
                           meta={"fork_of": "parent"})])


def test_forked_schedule_replays_bit_identically():
    """Block-granular residency (missing_fraction partial pricing) keeps
    the trace contract: a captured fork schedule replays analytically to
    the exact same EngineResult."""
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _real_engine(store)
    eng.serve([Request("parent", 0.0, 24, 8, decode_len=2)], verify=True)
    keys = store.requests["parent"]
    store.core.put(keys[-1], "host")     # partially-resident fork source
    rec = TraceRecorder()
    eng.serve([Request(f"b{i}", 0.05 * i, 24, 8, decode_len=2,
                       meta={"fork_of": "parent"}) for i in range(2)],
              verify=True, trace=rec)
    assert replay_trace(rec.trace) == rec.trace.captured_result()


def test_agentic_tree_workload_shape():
    from repro.serving.workloads import generate
    reqs = generate("agentic_tree", 13, seed=3)
    assert len(reqs) == 13
    assert [r.arrival for r in reqs] == sorted(r.arrival for r in reqs)
    roots = {r.request_id for r in reqs if not r.meta}
    for r in reqs:
        if r.meta:
            parent = r.meta["fork_of"]
            assert parent in roots
            parent_req = next(p for p in reqs if p.request_id == parent)
            assert r.prefix_len == parent_req.prefix_len
            assert r.arrival > parent_req.arrival   # branch after its root


# ---------------------------------------------------------------------------
# PlacementCore regressions (satellite fixes)
# ---------------------------------------------------------------------------


def test_promote_that_cannot_move_up_is_pure_noop():
    """An entry too big for every tier in [to, src) must not count a
    promotion or reset its LRU position."""
    core = PlacementCore([Tier("hot", 1e9, 100), Tier("cold", 1e6, 1000)])
    core.put("old", "cold", nbytes=300)      # > hot capacity
    core.put("young", "cold", nbytes=10)
    assert core.promote("old", "hot") == "cold"
    assert core.promotions == 0
    # LRU order untouched: "old" is still the eviction-order head
    assert next(iter(core.tiers["cold"].lru)) == "old"
    core.audit()


def test_promote_that_lands_counts_once():
    core = PlacementCore([Tier("hot", 1e9, 100), Tier("cold", 1e6, 1000)])
    core.put("x", "cold", nbytes=60)
    assert core.promote("x", "hot") == "hot"
    assert core.promotions == 1
    assert core.promote("x", "hot") == "hot"     # already there: no-op
    assert core.promotions == 1
    core.audit()


def test_tier_accounting_is_integer_exact():
    """Byte accounting is exact integers — audit tolerates zero drift even
    after many puts/demotions/removals of odd sizes."""
    core = PlacementCore([Tier("hot", 1e9, 10_001), Tier("cold", 1e6, 10**7)])
    for i in range(64):
        core.put(f"k{i}", "hot", nbytes=333 + i)
    for i in range(0, 64, 3):
        core.remove(f"k{i}")
    core.audit()
    for t in core.tiers.values():
        assert isinstance(t.used, int) and isinstance(t.capacity, int)
        assert t.used == sum(t.lru.values())     # exact, no tolerance


def test_victim_ties_break_in_lru_order():
    """With a constant victim_fn the benefit tie must fall back to true
    LRU recency (the incremental stamps) — a touched entry survives."""
    core = PlacementCore([Tier("hot", 1e9, 200), Tier("cold", 1e6, 1000)],
                         victim_fn=lambda k: 0.0)
    core.put("a", "hot", nbytes=90)
    core.put("b", "hot", nbytes=90)
    core.touch("a")                      # "b" is now least-recent
    core.put("c", "hot", nbytes=90)      # someone must go
    assert core.tier_of("b") == "cold"
    assert core.tier_of("a") == "hot"
    assert core.tier_of("c") == "hot"
    core.audit()


def test_chunkstore_missing_fraction_is_bytes_weighted():
    """missing_fraction reflects per-chunk residency: 0 when everything is
    on device, 1 for unknown requests, exact byte ratio in between."""
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _real_engine(store)
    eng.serve([Request("p", 0.0, 32, 8, decode_len=2)], verify=True)
    span, layers = (0, 32), (0, eng.model.cfg.num_layers)
    assert store.missing_fraction("p", span, layers) == 0.0
    assert store.missing_fraction("ghost", span, layers) == 1.0
    keys = store.requests["p"]
    store.core.put(keys[1], "host")      # 1 of 4 chunks off-device
    frac = store.missing_fraction("p", span, layers)
    assert frac == pytest.approx(0.25)
    assert store.missing_fraction("p", (8, 16), layers) == 1.0
    assert store.missing_fraction("p", (16, 32), layers) == 0.0
