"""CacheFlow observability layer (DESIGN.md §15).

Four layers of self-test:

  * **Golden timeline**: the committed preemption trace exports to valid
    Chrome trace-event JSON with stable event counts — through the library
    call AND the ``python -m repro.obs.timeline`` CLI the CI artifact step
    uses.  Strict-JSON is asserted (Perfetto rejects bare NaN tokens).
  * **Bit-identity**: a telemetry-enabled engine run is IDENTICAL to a
    disabled one on ``EngineResult`` and ``ops_log``, property-tested over
    randomized mixed interleavings (hooks are pure observers).
  * **Registry invariants**: catalog enforcement (unknown name / wrong
    type / label-schema drift all raise), counter monotonicity, the
    histogram ``count == sum(bucket_counts)`` conservation law.
  * **Mutation**: the codelint ``metric-catalog`` rule fires on an
    unregistered metric literal and on a deleted catalog, and stays silent
    on registered names (a checker that can't fail its mutant is dead
    code).  Plus the strict-JSON report plumbing (``percentiles`` of an
    empty set, ``emit_bench``).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from _engine_helpers import RngBackend
from _hypothesis_compat import given, settings, st

from repro.analysis.codelint import check_metric_catalog
from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core import EngineCore, EngineRequest
from repro.core.baselines import make_baseline_plans
from repro.core.trace import ScheduleTrace, result_to_dict
from repro.obs import (METRIC_CATALOG, MetricsRegistry, Telemetry,
                       trace_to_chrome)
from repro.serving import Request, SimServingEngine, TieredKVStore
from repro.serving.metrics import dumps_report, percentiles, sanitize_json


def _repo_root():
    import repro.analysis
    from pathlib import Path
    return Path(repro.analysis.__file__).resolve().parents[3]


GOLDEN = _repo_root() / "tests" / "data" / "golden_trace_preempt.json"


def _strict_loads(text: str):
    """json.loads that REJECTS the NaN/Infinity extensions — what an
    external consumer (Perfetto, jq) actually accepts."""
    def _no_const(tok):
        raise ValueError(f"non-standard JSON token {tok!r}")
    return json.loads(text, parse_constant=_no_const)


# ---------------------------------------------------------------------------
# Golden timeline export (library + CLI)
# ---------------------------------------------------------------------------


def _golden_doc():
    trace = ScheduleTrace.load(GOLDEN)
    return trace, trace_to_chrome(trace)


def test_golden_timeline_stable_counts_and_schema():
    trace, doc = _golden_doc()
    evs = doc["traceEvents"]
    ops = trace.result["ops_log"]
    aborted = sum(1 for e in ops if e[3].endswith(":aborted"))
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
    # one duration slice per non-aborted op, one instant per aborted op
    assert len(by_ph["X"]) == len(ops) - aborted
    assert len(by_ph["i"]) == aborted
    # every request with >= 2 lifecycle anchors gets exactly one flow
    # start and one flow finish; the golden trace covers all 8 requests
    assert len(by_ph["s"]) == len(by_ph["f"]) == len(trace.requests)
    # metadata: process_name + (thread_name, thread_sort_index) per track
    resources = doc["otherData"]["resources"]
    assert len(by_ph["M"]) == 1 + 2 * len(resources)
    assert "decode" in resources
    # counter tracks derived from trace events are present
    names = {e["name"] for e in by_ph["C"]}
    assert {"queue_depth", "active_requests"} <= names
    # schema: required keys per phase type
    for e in by_ph["X"]:
        assert {"ts", "dur", "pid", "tid", "name", "cat"} <= e.keys()
        assert e["dur"] >= 0
    for e in by_ph["i"]:
        assert e["s"] == "t" and e["name"].endswith(":aborted")
    for e in by_ph["s"] + by_ph["f"] + by_ph.get("t", []):
        assert "id" in e and e["cat"] == "lifecycle"
    assert all(e["bp"] == "e" for e in by_ph["f"])
    assert doc["displayTimeUnit"] == "ms"


def test_golden_timeline_is_strict_json():
    _, doc = _golden_doc()
    text = json.dumps(doc, allow_nan=False)   # raises on any NaN/Inf
    assert _strict_loads(text) == doc


def test_timeline_cli_offline_export(tmp_path):
    out = tmp_path / "golden.timeline.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_repo_root() / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.timeline", str(GOLDEN),
         "-o", str(out)],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr
    doc = _strict_loads(out.read_text())
    _, lib_doc = _golden_doc()
    assert len(doc["traceEvents"]) == len(lib_doc["traceEvents"])
    # default output path: <trace stem>.timeline.json next to the input
    assert "timeline" in proc.stderr


def test_timeline_reconstructs_ops_from_stripped_trace():
    """Traces without a captured result still render: slices come from the
    pinned dispatch/decode_step durations."""
    trace = ScheduleTrace.load(GOLDEN)
    trace.result = None
    doc = trace_to_chrome(trace)
    slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert slices
    cats = {e["cat"] for e in slices}
    assert "decode" in cats and ("restore-io" in cats or "prefill" in cats)


# ---------------------------------------------------------------------------
# Bit-identity: telemetry on == telemetry off
# ---------------------------------------------------------------------------


class _FuzzBackend(RngBackend):
    def prefetch_secs(self, op, req, bandwidth):
        return float(self.rng.uniform(0.05, 1.0))

    def prefetch_gate(self, req):
        return True


def _fuzz_requests(rng, kvstore, stages):
    bounds = [(0, 2), (2, 4)] if stages == 2 else None
    reqs = []
    for i in range(int(rng.integers(3, 8))):
        n = int(rng.integers(16, 160))
        plans = make_baseline_plans("cacheflow", f"r{i}", n, chunk_size=8,
                                    l_delta=0, num_layers=4,
                                    stage_bounds=bounds)
        reqs.append(EngineRequest(
            f"r{i}", n, arrival=float(rng.uniform(0, 3.0)), plans=plans,
            new_len=int(rng.integers(0, 3)) * 16,
            decode_len=int(rng.integers(0, 5)),
            priority=int(rng.integers(0, 3)),
            deadline=float(rng.uniform(0.5, 20.0))))
        if kvstore is not None:
            kvstore.put(f"r{i}", n * 1024, tier="remote")
    return reqs


def _run_once(seed, *, telemetry):
    rng = np.random.default_rng(seed)
    stages = int(rng.integers(1, 3))
    policy = ["none", "priority", "deadline"][int(rng.integers(0, 3))]
    evict = policy != "none" and bool(rng.integers(0, 2))
    io_channels = int(rng.integers(1, 3))
    use_store = bool(rng.integers(0, 2))
    kvstore = TieredKVStore() if use_store else None
    fail = ({int(rng.integers(0, io_channels)): float(rng.uniform(0.5, 3.0))}
            if int(rng.integers(0, 3)) == 0 else None)
    reqs = _fuzz_requests(rng, kvstore, stages)
    core = EngineCore(_FuzzBackend(seed), stages=stages,
                      io_channels=io_channels,
                      max_active=int(rng.integers(1, 4)),
                      preempt=policy, evict=evict,
                      prefetch=use_store and bool(rng.integers(0, 2)),
                      kvstore=kvstore, channel_fail_at=fail,
                      telemetry=telemetry)
    res = core.run(reqs)
    return res, core


@pytest.mark.property
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzz_telemetry_is_bit_identical(seed):
    """The whole point of the hook design: enabling telemetry changes
    NOTHING about the schedule.  Same seed, same config, telemetry
    off vs on — EngineResult (ops_log included) must match exactly."""
    res_off, core_off = _run_once(seed, telemetry=False)
    res_on, core_on = _run_once(seed, telemetry=True)
    assert result_to_dict(res_off) == result_to_dict(res_on)
    assert res_off.ops_log == res_on.ops_log
    assert core_off.last_telemetry is None
    tel = core_on.last_telemetry
    assert tel is not None
    snap = tel.snapshot()
    cs = snap["metrics"]["counters"]
    # sanity: the collection actually observed the run
    assert cs["engine.admissions_total"] >= len(res_on.finish)
    assert set(snap["phases"]) == set(res_on.finish)
    # the snapshot itself is strict JSON
    _strict_loads(json.dumps(snap, allow_nan=False))


def test_telemetry_collects_lifecycle_and_busy(tmp_path):
    res, core = _run_once(7, telemetry=True)
    snap = core.last_telemetry.snapshot()
    m = snap["metrics"]
    # per-resource busy seconds equal the summed non-aborted slice widths
    for key, g in m["gauges"].items():
        if not key.startswith("engine.resource_busy_seconds"):
            continue
        resource = key.split("resource=", 1)[-1].rstrip("}")
        expect = sum(t1 - t0 for t0, t1, r, d in res.ops_log
                     if r == resource and not d.endswith(":aborted"))
        assert g["value"] == pytest.approx(expect)
    # every finished request walked arrive -> admit -> ... -> finish
    for rid, edges in snap["phases"].items():
        names = [p for _, p in edges]
        assert names[0] == "arrive" and names[-1] == "finish"
        assert "admit" in names
        ts = [t for t, _ in edges]
        assert ts == sorted(ts)
    # histograms conserve their observations
    for h in m["histograms"].values():
        assert h["count"] == sum(h["bucket_counts"])


def test_engine_env_var_opt_in(monkeypatch):
    monkeypatch.setenv("CACHEFLOW_TELEMETRY", "1")
    core = EngineCore(RngBackend(3), stages=1, io_channels=1)
    assert core.telemetry
    n = 32
    plans = make_baseline_plans("cacheflow", "r0", n, chunk_size=8,
                                l_delta=0, num_layers=4)
    core.run([EngineRequest("r0", n, 0.0, plans)])
    assert core.last_telemetry is not None
    monkeypatch.setenv("CACHEFLOW_TELEMETRY", "0")
    assert not EngineCore(RngBackend(3), stages=1, io_channels=1).telemetry


def test_serving_report_carries_telemetry(monkeypatch):
    monkeypatch.delenv("CACHEFLOW_TELEMETRY", raising=False)
    cfg = get_config("qwen3-8b")
    reqs = [Request(f"r{i}", 0.2 * i, prefix_len=4096, new_len=128,
                    decode_len=2) for i in range(3)]
    eng = SimServingEngine(cfg, HARDWARE["h100"],
                           io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                           stages=2, max_batch=2, telemetry=True)
    rep = eng.run(reqs)
    assert rep.telemetry is not None
    assert rep.telemetry["metrics"]["counters"]["engine.admissions_total"] == 3
    assert len(rep.telemetry["phases"]) == 3
    # off by default: no snapshot attached, no registry constructed
    rep2 = SimServingEngine(cfg, HARDWARE["h100"],
                            io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                            stages=2, max_batch=2).run(
        [Request("s0", 0.0, prefix_len=4096, new_len=128, decode_len=2)])
    assert rep2.telemetry is None


# ---------------------------------------------------------------------------
# Registry invariants
# ---------------------------------------------------------------------------


def test_registry_enforces_catalog():
    reg = MetricsRegistry()
    with pytest.raises(KeyError):
        reg.counter("engine.warp_core_breaches")
    with pytest.raises(TypeError):
        reg.gauge("engine.admissions_total")       # declared a counter
    with pytest.raises(ValueError):
        reg.counter("engine.dispatches_total")     # missing the kind label
    with pytest.raises(ValueError):
        reg.counter("engine.admissions_total", kind="x")  # extra label
    # same (name, labels) cell -> same live instance
    a = reg.counter("engine.dispatches_total", kind="load")
    b = reg.counter("engine.dispatches_total", kind="load")
    assert a is b
    assert a is not reg.counter("engine.dispatches_total", kind="compute")


def test_counter_rejects_negative_and_gauge_series():
    reg = MetricsRegistry()
    c = reg.counter("engine.admissions_total")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1.0)
    g = reg.gauge("engine.queue_depth")
    g.set(3)                 # sample without timestamp: no series entry
    g.set(5, t=1.5)
    g.set(2, t=2.0)
    assert g.value == 2.0
    assert g.series == [(1.5, 5.0), (2.0, 2.0)]


@pytest.mark.property
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzz_histogram_conservation(seed):
    rng = np.random.default_rng(seed)
    reg = MetricsRegistry()
    h = reg.histogram("engine.ttft_seconds")
    values = rng.uniform(0.0, 200.0, size=int(rng.integers(1, 100)))
    for v in values:
        h.observe(float(v))
    assert h.count == len(values) == sum(h.bucket_counts)
    assert h.sum == pytest.approx(float(values.sum()))
    # bucket placement: first bound >= value (or the overflow slot)
    for v in values:
        idx = next((i for i, b in enumerate(h.bounds) if v <= b),
                   len(h.bounds))
        assert h.bucket_counts[idx] > 0


def test_catalog_is_well_formed():
    for name, spec in METRIC_CATALOG.items():
        assert spec["type"] in ("counter", "gauge", "histogram"), name
        assert isinstance(spec["labels"], tuple), name
        assert "layer" in spec, name
        if spec["type"] == "histogram":
            assert list(spec["buckets"]) == sorted(spec["buckets"]), name


# ---------------------------------------------------------------------------
# codelint metric-catalog rule: one mutant each way
# ---------------------------------------------------------------------------


def test_codelint_mutation_metric_catalog(tmp_path):
    reg = tmp_path / "registry.py"
    reg.write_text('METRIC_CATALOG = {"engine.x_total": {"type": "counter"}}\n')
    mod = tmp_path / "mod.py"
    mod.write_text("def f(self):\n"
                   "    self.registry.counter('engine.x_total').inc()\n"
                   "    self.registry.gauge('engine.ghost').set(1)\n")
    findings = check_metric_catalog(reg, [mod])
    assert [f.rule for f in findings] == ["metric-catalog"]
    assert "engine.ghost" in findings[0].message
    # registered-only file is clean; non-literal first args are skipped
    ok = tmp_path / "ok.py"
    ok.write_text("def f(self, name):\n"
                  "    self.registry.counter('engine.x_total').inc()\n"
                  "    self.registry.counter(name).inc()\n")
    assert check_metric_catalog(reg, [ok]) == []
    # a deleted catalog is itself a finding
    reg.write_text("METRIC_CATALOG = build()\n")
    assert [f.rule for f in check_metric_catalog(reg, [ok])] \
        == ["metric-catalog"]


def test_codelint_repo_metric_literals_all_registered():
    from repro.analysis.codelint import run_all
    findings = [f for f in run_all(_repo_root())
                if f.rule == "metric-catalog"]
    assert findings == [], [str(f) for f in findings]


# ---------------------------------------------------------------------------
# Strict-JSON report plumbing (percentiles / emit_bench satellites)
# ---------------------------------------------------------------------------


def test_percentiles_empty_is_null_not_nan():
    out = percentiles([])
    assert set(out) == {"p50", "p90", "p99", "mean"}
    assert all(v is None for v in out.values())
    # and it round-trips as strict JSON
    assert _strict_loads(dumps_report(out)) == {k: None for k in out}


def test_dumps_report_scrubs_non_finite():
    doc = {"a": float("nan"), "b": [1.0, float("inf")],
           "c": {"d": float("-inf"), "e": 2.0}, "f": "NaN-as-string"}
    text = dumps_report(doc)
    assert _strict_loads(text) == {"a": None, "b": [1.0, None],
                                   "c": {"d": None, "e": 2.0},
                                   "f": "NaN-as-string"}
    assert sanitize_json((1.0, float("nan"))) == [1.0, None]


def test_emit_bench_writes_repo_root_and_results(tmp_path):
    sys.path.insert(0, str(_repo_root()))
    try:
        from benchmarks.common import RESULTS, emit_bench
    finally:
        sys.path.pop(0)
    path = emit_bench("obs_selftest", {"v": float("nan"), "n": 3},
                      root=str(tmp_path))
    try:
        assert path == str(tmp_path / "BENCH_obs_selftest.json")
        doc = _strict_loads(open(path).read())
        assert doc == {"v": None, "n": 3}
        mirror = os.path.join(RESULTS, "BENCH_obs_selftest.json")
        assert _strict_loads(open(mirror).read()) == doc
    finally:
        os.unlink(os.path.join(RESULTS, "BENCH_obs_selftest.json"))
