"""Full request-lifecycle engine core: RESTORING -> PREFILL -> DECODE -> DONE.

  * TTFT contention: suffix prefill is a *scheduled* op — under load it
    queues behind other requests' restoration chunks, so TTFT exceeds the
    old bolt-on (restore + isolated prefill) estimate.
  * Phase monotonicity: restore_start <= restore_end <= first_token <=
    finish under randomized interleavings (property test).
  * Real-mode parity (tentpole acceptance): >= 3 concurrent requests with
    decode_len > 0 produce first-token logits and greedy decode outputs
    that match a no-restoration full-prefill+decode reference.
  * Lifecycle traces: capture covers prefill + decode_step events and
    replays bit-identically; v1 (pre-lifecycle) traces load by upgrade and
    unknown versions are rejected (no KeyError).
  * Admission: continuous-batching slots are freed at DECODE completion,
    not restore completion.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from _engine_helpers import RngBackend

from repro.core import (CostModel, EngineCore, EngineRequest,
                        RestorationExecutor, ScheduleTrace, SimBackend,
                        TraceRecorder, TraceVersionError, capture,
                        replay_trace)
from repro.core.baselines import make_baseline_plans
from repro.core.plans import make_request_plans
from repro.core.trace import TRACE_VERSION
from repro.models import build_model
from repro.models.kvcache import grow_cache
from repro.serving import RealServingEngine, Request

RNG = jax.random.PRNGKey(0)


def _cost(arch="qwen3-8b", hw="h100", bw="10Gbps"):
    return CostModel(get_config(arch), HARDWARE[hw], IO_BANDWIDTHS[bw], mfu=0.45)


# ---------------------------------------------------------------------------
# TTFT under load: contended prefill > bolt-on estimate
# ---------------------------------------------------------------------------


def test_ttft_under_load_exceeds_bolt_on_estimate():
    """r0 grinds a long compute-only restoration; r1 restores quickly over
    I/O but its suffix prefill must then queue FCFS behind r0's chunks —
    the old post-loop bolt-on (restore_finish + isolated prefill) strictly
    underestimates its TTFT."""
    cost = _cost()
    cfg = cost.cfg
    r0_plans = make_baseline_plans("vllm", "r0", 30_000, chunk_size=512,
                                   l_delta=0, num_layers=cfg.num_layers)
    r1_plans = make_baseline_plans("lmcache", "r1", 4_000, chunk_size=512,
                                   l_delta=0, num_layers=cfg.num_layers)
    reqs = [EngineRequest("r0", 30_000, 0.0, r0_plans),
            EngineRequest("r1", 4_000, 0.0, r1_plans, new_len=256)]
    core = EngineCore(SimBackend(cost), stages=1, io_channels=1, strict=True)
    res = core.run(reqs)
    bolt_on = res.restore_finish["r1"] + cost.t_comp_range(4_000, 4_256, chunks=1)
    # the prefill waited for r0's restoration to drain off the stage compute
    assert res.first_token["r1"] > bolt_on * 1.5
    assert res.first_token["r1"] >= res.restore_finish["r0"]
    # and the op actually ran as a scheduled unit on the stage resource
    assert any(desc == "r1:p0" for *_, desc in res.ops_log)


def test_restoration_only_requests_collapse_to_old_behavior():
    cost = _cost()
    plans = make_baseline_plans("cacheflow", "r", 8_000, chunk_size=512,
                                l_delta=0, num_layers=cost.cfg.num_layers)
    res = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                     strict=True).run([EngineRequest("r", 8_000, 0.0, plans)])
    assert res.finish == res.restore_finish      # lifecycle collapsed
    assert res.first_token == {}                 # no token was produced
    assert res.decode_steps == 0


def test_admission_slot_held_through_decode():
    """Continuous batching frees capacity at DECODE completion: with
    max_active=1, r1 cannot even start restoring until r0 finishes
    decoding — previously the slot freed at restore completion."""
    cost = _cost()

    def mk(rid):
        plans = make_baseline_plans("cacheflow", rid, 6_000, chunk_size=512,
                                    l_delta=0, num_layers=cost.cfg.num_layers)
        return EngineRequest(rid, 6_000, 0.0, plans, new_len=128, decode_len=16)

    res = EngineCore(SimBackend(cost), stages=1, io_channels=1, max_active=1,
                     strict=True).run([mk("r0"), mk("r1")])
    assert res.finish["r0"] > res.restore_finish["r0"]      # decode tail exists
    assert res.restore_start["r1"] >= res.finish["r0"]


# ---------------------------------------------------------------------------
# Phase monotonicity under randomized interleavings (property)
# ---------------------------------------------------------------------------


@pytest.mark.property
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_phase_transitions_monotone(seed):
    rng = np.random.default_rng(seed)
    stages = int(rng.integers(1, 3))
    bounds = [(0, 2), (2, 4)][:stages]
    if stages == 1:
        bounds = [(0, 4)]
    reqs = []
    for i in range(int(rng.integers(3, 7))):
        n = int(rng.integers(16, 120))
        plans = make_request_plans(f"r{i}", n, chunk_size=8,
                                   l_delta=0, num_layers=4,
                                   stage_bounds=bounds, strategy="token")
        reqs.append(EngineRequest(
            f"r{i}", n, arrival=float(rng.uniform(0, 2.0)), plans=plans,
            new_len=int(rng.integers(0, 3)) * 16,
            decode_len=int(rng.integers(0, 6))))
    core = EngineCore(RngBackend(seed), stages=stages,
                      io_channels=int(rng.integers(1, 3)),
                      max_active=int(rng.integers(0, 4)), strict=True)
    res = core.run(reqs)
    for r in reqs:
        rid = r.request_id
        assert rid in res.restore_finish and rid in res.finish
        assert res.restore_start[rid] <= res.restore_finish[rid]
        if r.new_len > 0 or r.decode_len > 0:
            assert rid in res.first_token
            assert res.restore_finish[rid] <= res.first_token[rid]
            assert res.first_token[rid] <= res.finish[rid]
            if r.decode_len > 1:
                assert res.finish[rid] > res.first_token[rid]
        else:
            assert rid not in res.first_token
            assert res.finish[rid] == res.restore_finish[rid]


# ---------------------------------------------------------------------------
# Real-mode lifecycle parity (tentpole acceptance)
# ---------------------------------------------------------------------------


def _real_engine():
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    eng = RealServingEngine(m, params, system="cacheflow", stages=2,
                            chunk_size=8, max_batch=2)
    reqs = [Request("a", 0.0, 40, 8, decode_len=4),
            Request("b", 0.0, 24, 8, decode_len=3),
            Request("c", 0.0, 32, 8, decode_len=4)]
    return cfg, m, params, eng, reqs


def test_real_lifecycle_parity_vs_full_prefill_reference():
    """>= 3 concurrent requests through the engine core: per-request
    first-token logits and greedy decode outputs must match a
    no-restoration full-prefill + decode reference."""
    cfg, m, params, eng, reqs = _real_engine()
    rep = eng.serve(reqs, verify=True)        # verify raises on KV mismatch
    assert set(rep.ttfts) == {"a", "b", "c"}
    assert all(v > 0 for v in rep.ttfts.values())
    assert all(rep.e2e[rid] >= rep.ttfts[rid] for rid in rep.ttfts)
    ex = eng.executor
    for r in reqs:
        out = ex.outputs(r.request_id)
        full = jnp.concatenate([ex.store.get(r.request_id).inputs,
                                ex.suffix_inputs(r.request_id)], axis=1)
        ref_logits, cache = m.prefill(params, full)
        np.testing.assert_allclose(np.asarray(out["first_logits"]),
                                   np.asarray(ref_logits), atol=1e-4)
        # greedy decode reference on the un-restored cache
        cache = grow_cache(cfg, cache, full.shape[1] + r.decode_len)
        logits, pos = ref_logits, full.shape[1]
        toks = [int(jnp.argmax(logits[0]))]
        for _ in range(r.decode_len - 1):
            inp = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            logits, cache = m.decode_step(params, inp, cache, pos)
            pos += 1
            toks.append(int(jnp.argmax(logits[0])))
        assert out["tokens"] == toks, r.request_id
        assert len(out["step_logits"]) == r.decode_len - 1


def test_real_lifecycle_capture_replays_bit_identical():
    """A captured lifecycle schedule (incl. prefill + decode_step events)
    replays bit-identically through the sim side and survives JSON."""
    *_, eng, reqs = _real_engine()
    rec = TraceRecorder()
    res = eng.serve(reqs, op_order="random",
                    rng=np.random.default_rng(5), trace=rec)
    trace = rec.trace
    assert trace.prefills(), "no prefill events captured"
    assert trace.decode_steps(), "no decode_step events captured"
    rep = replay_trace(trace)
    assert rep == trace.captured_result()
    loaded = ScheduleTrace.from_json(trace.to_json())
    assert loaded == trace
    assert replay_trace(loaded) == trace.captured_result()
    assert set(res.ttfts) == set(rep.first_token)


def test_sim_lifecycle_capture_replays_bit_identical():
    """Sim capture of the same workload shape: the whole-lifecycle schedule
    (prefill ops contending with restoration, batched decode steps) is a
    replayable artifact."""
    cfg = get_config("qwen3-8b").reduced()
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    bounds = [(0, cfg.num_layers // 2), (cfg.num_layers // 2, cfg.num_layers)]
    reqs = [EngineRequest(rid, n, 0.0,
                          make_baseline_plans("cacheflow", rid, n,
                                              chunk_size=8, l_delta=16,
                                              num_layers=cfg.num_layers,
                                              stage_bounds=bounds),
                          new_len=8, decode_len=d)
            for rid, n, d in (("a", 40, 4), ("b", 24, 3), ("c", 32, 4))]
    core = EngineCore(SimBackend(cost, benefit_gate=False), stages=2,
                      io_channels=2, strict=True)
    res, trace = capture(core, reqs)
    assert len(trace.prefills()) == 2 * 3          # one per stage per request
    assert trace.decode_steps()
    assert set(res.first_token) == {"a", "b", "c"}
    rep = replay_trace(trace)
    assert rep == res
    assert rep.ops_log == res.ops_log
    assert replay_trace(ScheduleTrace.from_json(trace.to_json())) == res


# ---------------------------------------------------------------------------
# Trace schema versioning (satellite)
# ---------------------------------------------------------------------------


def _restoration_only_trace():
    cost = _cost()
    plans = make_baseline_plans("cacheflow", "r", 4_000, chunk_size=512,
                                l_delta=0, num_layers=cost.cfg.num_layers)
    core = EngineCore(SimBackend(cost), stages=1, io_channels=1, strict=True)
    return capture(core, [EngineRequest("r", 4_000, 0.0, plans)])


def test_trace_v1_loads_by_upgrade():
    """A pre-lifecycle (v1) trace — no new_len/decode_len, no lifecycle
    result fields — loads cleanly and replays to the captured result."""
    res, trace = _restoration_only_trace()
    d = trace.to_dict()
    d["version"] = 1
    for r in d["requests"]:
        del r["new_len"], r["decode_len"]
    for f in ("first_token", "finish", "decode_busy", "decode_steps"):
        del d["result"][f]
    up = ScheduleTrace.from_dict(d)
    assert up.version == TRACE_VERSION
    assert replay_trace(up) == res               # incl. upgraded result fields


def test_trace_version_gate_rejects_unknown_and_missing():
    _, trace = _restoration_only_trace()
    d = trace.to_dict()
    d["version"] = 99
    with pytest.raises(TraceVersionError, match="unsupported"):
        ScheduleTrace.from_dict(d)
    del d["version"]
    with pytest.raises(TraceVersionError, match="no schema version"):
        ScheduleTrace.from_dict(d)
