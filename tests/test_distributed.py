"""Distribution layer tests. Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test process
keeps seeing 1 device."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import sharding as shr
from repro.models import build_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_param_pspecs_cover_every_leaf():
    for arch in ("qwen3-8b", "deepseek-v2-236b", "recurrentgemma-2b", "rwkv6-7b"):
        model = build_model(get_config(arch))
        specs = model.param_specs()
        pspecs = shr.param_pspecs(model, "train")
        n_leaves = len(jax.tree.leaves(specs))
        n_specs = len(jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
        assert n_specs == n_leaves


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "deepseek-v2-236b",
                                  "recurrentgemma-2b", "rwkv6-7b"])
@pytest.mark.parametrize("mode", ["train", "serve_tp", "serve_2d"])
def test_pspec_divisibility_on_production_mesh(arch, mode):
    """Every sharded dim divides the 16×16 production mesh axes (jit would
    reject uneven input shardings)."""
    model = build_model(get_config(arch), param_dtype=jax.numpy.bfloat16)
    specs = model.param_specs()
    pspecs = shr.param_pspecs(model, mode)
    axis_size = {"pod": 2, "data": 16, "model": 16}

    def check(path, sds, spec):
        for d, entry in enumerate(tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([axis_size[a] for a in axes]))
            assert sds.shape[d] % size == 0, \
                (jax.tree_util.keystr(path), sds.shape, tuple(spec))

    jax.tree_util.tree_map_with_path(
        check, specs, pspecs)


def test_sharded_train_and_decode_match_single_device():
    """On an 8-device mesh, one sharded train step and one sharded decode
    step produce the same numbers as the unsharded run."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.distributed import sharding as shr
        from repro.training import AdamWConfig, DataConfig, batch_at, \\
            init_opt_state, make_train_step

        cfg = get_config('qwen1.5-0.5b').reduced(num_heads=4, num_kv_heads=4,
                                                 d_model=128, d_ff=256)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
        batch = batch_at(dc, 0)
        step = make_train_step(m, AdamWConfig(total_steps=10))

        # single device reference
        p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        pspecs = shr.to_named(mesh, shr.param_pspecs(m, 'train'))
        ospecs = shr.to_named(mesh, shr.opt_pspecs(m, 'train'))
        bspecs = shr.to_named(mesh, shr.data_pspecs(cfg, mesh, 'train', 8))
        with mesh:
            p_sh, o_sh, m_sh = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                                       out_shardings=(pspecs, ospecs, None))(
                params, opt, batch)
        l_ref = np.asarray(jax.tree.leaves(p_ref)[0], np.float32)
        l_sh = np.asarray(jax.tree.leaves(p_sh)[0], np.float32)
        err = float(np.max(np.abs(l_ref - l_sh)))
        loss_diff = abs(float(m_ref['loss']) - float(m_sh['loss']))

        # decode parity
        last, cache = m.prefill(params, batch['tokens'][:, :16])
        tok = jnp.argmax(last, -1).astype(jnp.int32)
        log_ref, _ = m.decode_step(params, tok, cache, 16)
        cspec = shr.to_named(mesh, shr.cache_pspecs(m, mesh, 8, 16))
        with mesh:
            dstep = jax.jit(m.decode_step,
                            in_shardings=(pspecs, shr.to_named(mesh,
                                shr.data_pspecs(cfg, mesh, 'decode', 8)), cspec, None),
                            out_shardings=(None, cspec))
            log_sh, _ = dstep(params, tok, cache, 16)
        derr = float(np.max(np.abs(np.asarray(log_ref, np.float32)
                                   - np.asarray(log_sh, np.float32))))
        print(json.dumps({'err': err, 'loss_diff': loss_diff, 'decode_err': derr}))
    """)
    out = _run_subprocess(code)
    assert out["err"] < 2e-4, out
    assert out["loss_diff"] < 1e-4, out
    assert out["decode_err"] < 2e-3, out


def test_elastic_reshard_roundtrip():
    """Checkpoint on a 4x2 mesh, resume on 2x4 — values identical."""
    code = textwrap.dedent("""
        import json, tempfile
        import jax, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.distributed import sharding as shr
        from repro.distributed.elastic import replace_on_mesh, validate_divisibility
        from repro.training import CheckpointManager

        cfg = get_config('qwen1.5-0.5b').reduced(num_heads=4, num_kv_heads=4,
                                                 d_model=128, d_ff=256)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        pspec = shr.param_pspecs(m, 'train')
        mesh_a = jax.make_mesh((4, 2), ('data', 'model'))
        mesh_b = jax.make_mesh((2, 4), ('data', 'model'))
        placed = replace_on_mesh(params, pspec, mesh_a)
        with tempfile.TemporaryDirectory() as d:
            ck = CheckpointManager(d)
            ck.save(0, placed)
            _, restored = ck.restore(placed)
            assert validate_divisibility(restored, pspec, mesh_b) == []
            placed_b = replace_on_mesh(restored, pspec, mesh_b)
            a = np.asarray(jax.tree.leaves(params)[0], np.float32)
            b = np.asarray(jax.tree.leaves(placed_b)[0], np.float32)
            print(json.dumps({'equal': bool(np.array_equal(a, b))}))
    """)
    assert _run_subprocess(code)["equal"] is True


def test_compressed_psum_under_shard_map():
    """int8 error-feedback mean over a mesh axis ≈ exact mean."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        try:
            from jax import shard_map
        except ImportError:              # moved out of experimental in jax 0.5
            from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.training.compression import error_feedback_psum

        mesh = jax.make_mesh((8,), ('pod',))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 4096), jnp.float32)

        def f(xl):
            mean, res = error_feedback_psum(xl[0], 'pod')
            return mean[None], res[None]

        mean, res = jax.jit(shard_map(f, mesh=mesh, in_specs=P('pod', None),
                                      out_specs=P('pod', None)))(x)
        exact = x.mean(axis=0)
        rel = float(jnp.linalg.norm(mean[0] - exact) / jnp.linalg.norm(exact))
        print(json.dumps({'rel': rel}))
    """)
    assert _run_subprocess(code)["rel"] < 0.02
