"""Continuous batching (DESIGN.md §11): streaming admission, decode overlap.

  * No starvation: under randomized arrivals/durations with a tight
    ``max_active`` cap, every queued request is admitted no later than the
    moment enough earlier work retired to free its slot (bounded wait —
    property test).
  * Slot accounting: a mid-flight retire frees a decode slot exactly once —
    the live-admission count never exceeds ``max_active`` and refills
    happen mid-flight (continuous) vs only at batch close (gang).
  * Trace schema v5: a captured continuous-batching run — prefetch
    dispatches, prefetch gates, decode-load-annotated benefit gates,
    admission meta — replays bit-identically in sim mode and in real mode
    with per-request cache verification.
  * Queued-request prefetch: idle channel time promotes a queued request's
    KV up a storage tier before admission.
  * Priority-aware I/O dispatch: an urgent request's transfers jump the
    channel queue; default SLO classes reproduce the classic ordering.
  * Decode-aware benefit gate: a transfer that loses to recompute on an
    idle device can win against a live decode batch.
"""
import numpy as np
import pytest

from _engine_helpers import RngBackend
from _hypothesis_compat import given, settings, st

from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core import (CostModel, EngineCore, EngineRequest, ScheduleTrace,
                        SimBackend, TraceRecorder, capture, replay_trace)
from repro.core.baselines import make_baseline_plans
from repro.core.plans import make_request_plans
from repro.core.scheduler import BatchScheduler
from repro.core.trace import TRACE_VERSION
from repro.serving import Request, SimServingEngine, TieredKVStore
from repro.serving.workloads import multi_tenant


def _cost(arch="qwen3-8b", hw="h100", bw="10Gbps", **kw):
    return CostModel(get_config(arch), HARDWARE[hw], IO_BANDWIDTHS[bw],
                     mfu=0.45, **kw)


def _rng_requests(rng, n, *, spacing=0.25):
    """Randomized lifecycle requests with strictly increasing arrivals (so
    FCFS rank is unambiguous)."""
    reqs = []
    t = 0.0
    for i in range(n):
        t += float(rng.uniform(0.01, spacing))
        tokens = int(rng.integers(16, 120))
        plans = make_request_plans(f"r{i}", tokens, chunk_size=8, l_delta=0,
                                   num_layers=4, stage_bounds=[(0, 4)],
                                   strategy="token")
        reqs.append(EngineRequest(f"r{i}", tokens, arrival=t, plans=plans,
                                  new_len=16, decode_len=int(rng.integers(1, 6))))
    return reqs


def _admission_timeline(trace):
    """(admits, finishes) as rid -> engine time from a captured trace."""
    admits, finishes = {}, {}
    for e in trace.events:
        if e.kind == "admit":
            admits[e.request_id] = e.t
        elif e.kind == "finish":
            finishes[e.request_id] = e.t
    return admits, finishes


# ---------------------------------------------------------------------------
# No starvation: bounded wait under randomized arrivals (property)
# ---------------------------------------------------------------------------


@pytest.mark.property
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_continuous_admission_bounded_wait(seed):
    """FCFS continuous admission never starves: with cap K, the i-th
    arrival (0-based, arrival order) is admitted no later than
    max(its arrival, the (i-K+1)-th finish overall) — the instant enough
    earlier work retired that a slot must have been free for it."""
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(1, 4))
    reqs = _rng_requests(rng, int(rng.integers(4, 9)))
    core = EngineCore(RngBackend(seed), stages=1,
                      io_channels=int(rng.integers(1, 3)),
                      max_active=cap, strict=True)
    res, trace = capture(core, reqs)
    admits, _ = _admission_timeline(trace)
    assert set(admits) == {r.request_id for r in reqs}   # no one starved
    assert set(res.finish) == set(admits)
    finish_order = sorted(res.finish.values())
    for i, r in enumerate(reqs):                         # arrival order
        bound = r.arrival if i < cap else \
            max(r.arrival, finish_order[i - cap])
        assert admits[r.request_id] <= bound + 1e-9, \
            (r.request_id, admits[r.request_id], bound)


# ---------------------------------------------------------------------------
# Slot accounting: mid-flight retire frees exactly one slot
# ---------------------------------------------------------------------------


def _slot_walk(trace, cap):
    """Replay admit/finish events; return (peak_active, admit_times_when_full)
    — admissions that happened while other requests were still live."""
    active, peak, midflight = set(), 0, []
    for e in trace.events:
        if e.kind == "admit":
            assert e.request_id not in active, "double admission"
            if active:
                midflight.append(e.t)
            active.add(e.request_id)
            peak = max(peak, len(active))
            assert len(active) <= cap
        elif e.kind == "finish":
            assert e.request_id in active, "finish freed a slot twice"
            active.remove(e.request_id)
    assert not active
    return peak, midflight


def test_midflight_retire_frees_slot_exactly_once():
    cost = _cost()
    cfg = cost.cfg

    def mk(i, arrival):
        n = 4_000 + 700 * i
        plans = make_baseline_plans("cacheflow", f"r{i}", n, chunk_size=512,
                                    l_delta=0, num_layers=cfg.num_layers)
        return EngineRequest(f"r{i}", n, arrival=arrival, plans=plans,
                             new_len=64, decode_len=8 + 4 * i)

    reqs = [mk(i, 0.1 * i) for i in range(6)]
    core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                      max_active=2, strict=True)
    res, trace = capture(core, reqs)
    peak, midflight = _slot_walk(trace, cap=2)
    assert peak == 2
    # continuous batching: freed slots are refilled MID-FLIGHT — admissions
    # happen while another request is still live (restoring or decoding)
    assert midflight, "no mid-flight refill under continuous admission"
    assert set(res.finish) == {r.request_id for r in reqs}


def test_gang_admission_waits_for_batch_close():
    """The run-to-completion baseline: arrivals NEVER join a live batch —
    every admission happens either into an empty engine or at the instant
    the whole previous batch retired."""
    cost = _cost()
    cfg = cost.cfg

    def mk(i, arrival):
        n = 3_000 + 500 * i
        plans = make_baseline_plans("cacheflow", f"g{i}", n, chunk_size=512,
                                    l_delta=0, num_layers=cfg.num_layers)
        return EngineRequest(f"g{i}", n, arrival=arrival, plans=plans,
                             new_len=64, decode_len=8)

    reqs = [mk(i, 0.05 * i) for i in range(6)]
    core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                      max_active=2, admission="gang", strict=True)
    res, trace = capture(core, reqs)
    active = set()
    batch_close_times = set()
    for e in trace.events:
        if e.kind == "admit":
            # gang: admission only into an empty engine or exactly at a
            # batch-close instant (same-timestamp group admissions allowed)
            assert not active or e.t in batch_close_times, \
                (e.request_id, e.t)
            active.add(e.request_id)
        elif e.kind == "finish":
            active.discard(e.request_id)
            if not active:
                batch_close_times.add(e.t)
    assert set(res.finish) == {r.request_id for r in reqs}
    # and the same stream under continuous admission strictly beats it on
    # mean TTFT: slots refill mid-flight instead of idling to batch close
    cont = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                      max_active=2, strict=True).run(
        [mk(i, 0.05 * i) for i in range(6)])
    mean = lambda d, reqs: float(np.mean(  # noqa: E731
        [d[r.request_id] - r.arrival for r in reqs]))
    assert mean(cont.first_token, reqs) < mean(res.first_token, reqs)


def test_gang_rejects_preemption_and_unknown_admission():
    cost = _cost()
    with pytest.raises(ValueError, match="gang"):
        EngineCore(SimBackend(cost), admission="gang", preempt="priority")
    with pytest.raises(ValueError, match="admission"):
        EngineCore(SimBackend(cost), admission="bogus")


# ---------------------------------------------------------------------------
# Trace schema v5: sim + real replay with prefetch and decode-load gates
# ---------------------------------------------------------------------------


def _mt_requests(n=8, seed=11):
    # rate 8/s backlogs the 2-slot batch (so the idle channel prefetches a
    # queued request) and the 64-step decodes keep a live batch under every
    # restoration (so gates are priced with decode_load > 0)
    return [Request(r.request_id, r.arrival, min(r.prefix_len, 6_000),
                    min(r.new_len, 128), decode_len=min(r.decode_len, 64),
                    priority=r.priority, deadline=r.deadline)
            for r in multi_tenant(n, seed=seed, arrival_rate=8.0)]


def test_trace_v5_sim_replay_bit_identical_with_prefetch():
    """A continuous-batching capture — prefetch dispatches, prefetch gates,
    admission meta — replays bit-identically WITHOUT the KV store (every
    store-derived decision is pinned in the trace) and survives JSON."""
    cfg = get_config("qwen3-8b")
    store = TieredKVStore(remote_bw=IO_BANDWIDTHS["10Gbps"])
    eng = SimServingEngine(cfg, HARDWARE["h100"],
                           io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                           stages=2, max_batch=2, kvstore=store,
                           kv_tier="remote", prefetch=True,
                           decode_interference=0.3)
    rec = TraceRecorder()
    eng.run(_mt_requests(), trace=rec)
    trace = rec.trace
    assert trace.version == TRACE_VERSION == 5
    assert trace.meta["admission"] == "continuous"
    assert trace.meta["prefetch"] is True
    assert trace.prefetch_gates(), "no prefetch decisions captured"
    assert trace.prefetches(), "no prefetch transfers captured"
    assert any(e.decode_load for e in trace.gates()), \
        "no gate was priced against a live decode batch"
    res = trace.captured_result()
    assert res.overlap_decode_restore > 0.0
    assert replay_trace(trace) == res
    loaded = ScheduleTrace.from_json(trace.to_json())
    assert loaded == trace
    assert replay_trace(loaded) == res


def test_trace_v4_loads_by_upgrade():
    """A pre-continuous-batching (v4) trace — no admission/prefetch meta, no
    overlap in the result — loads cleanly and replays bit-identically under
    the implicit admission="continuous"/prefetch=False upgrade."""
    cost = _cost()
    cfg = cost.cfg
    plans = make_baseline_plans("cacheflow", "r", 6_000, chunk_size=512,
                                l_delta=0, num_layers=cfg.num_layers)
    core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                      max_active=2, strict=True)
    res, trace = capture(core, [EngineRequest("r", 6_000, 0.0, plans,
                                              new_len=64, decode_len=8)])
    d = trace.to_dict()
    d["version"] = 4
    del d["meta"]["admission"], d["meta"]["prefetch"]
    del d["result"]["overlap_decode_restore"]
    up = ScheduleTrace.from_dict(d)
    assert up.version == TRACE_VERSION
    rep = replay_trace(up)
    assert rep == res          # incl. the overlap recomputed from ops_log


def test_trace_v5_real_replay_with_cache_verification():
    """Real mode: a continuous-batching lifecycle capture re-executes on
    device with per-request cache verification under the recorded
    interleaving."""
    from repro.core.executor import RestorationExecutor
    from repro.models import build_model
    import jax

    from repro.serving import RealServingEngine

    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = RealServingEngine(m, params, system="cacheflow", stages=2,
                            chunk_size=8, max_batch=2)
    reqs = [Request("a", 0.0, 40, 8, decode_len=4),
            Request("b", 0.05, 24, 8, decode_len=3),
            Request("c", 0.1, 32, 8, decode_len=4)]
    rec = TraceRecorder()
    res = eng.serve(reqs, op_order="random",
                    rng=np.random.default_rng(7), trace=rec)
    trace = rec.trace
    assert trace.version == TRACE_VERSION
    assert trace.meta["admission"] == "continuous"
    # sim replay of the real capture is bit-identical
    assert replay_trace(trace) == trace.captured_result()
    # real replay: every dispatched op re-executes on device; each restored
    # cache is verified against full-prefill ground truth
    ex = RestorationExecutor(m, params, chunk_size=8, stages=2)
    rng = jax.random.PRNGKey(9)
    for r in reqs:
        rng, key = jax.random.split(rng)
        if cfg.input_mode == "tokens":
            inputs = jax.random.randint(key, (1, r.prefix_len), 0,
                                        cfg.vocab_size)
        else:
            inputs = jax.random.normal(key, (1, r.prefix_len, cfg.d_model))
        ex.remember(r.request_id, inputs)
        rng, key = jax.random.split(rng)
        if cfg.input_mode == "tokens":
            suffix = jax.random.randint(key, (1, r.new_len), 0, cfg.vocab_size)
        else:
            suffix = jax.random.normal(key, (1, r.new_len, cfg.d_model))
        ex.set_suffix(r.request_id, suffix, decode_len=r.decode_len)
    rep = replay_trace(trace, ex, verify=True)
    assert rep == trace.captured_result()
    assert set(rep.finish) == set(res.finishes)


# ---------------------------------------------------------------------------
# Queued-request prefetch (satellite)
# ---------------------------------------------------------------------------


def test_prefetch_promotes_queued_requests():
    """With a hard admission cap, queued requests' KV is promoted remote ->
    host on idle channel time; their admission-time restoration then rides
    the faster tier.  Disabled, the trace carries no prefetch events."""
    cfg = get_config("qwen3-8b")

    def serve(prefetch):
        store = TieredKVStore(remote_bw=IO_BANDWIDTHS["10Gbps"])
        eng = SimServingEngine(cfg, HARDWARE["h100"],
                               io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                               stages=1, max_batch=1, kvstore=store,
                               kv_tier="remote", prefetch=prefetch)
        # q0 holds the single slot through a long decode — the channel
        # idles meanwhile, which is exactly the prefetch window for the
        # queued q1/q2 (small enough to finish promoting before admission)
        reqs = [Request("q0", 0.0, 4_000, 64, decode_len=120),
                Request("q1", 0.0, 1_500, 64, decode_len=8),
                Request("q2", 0.0, 2_000, 64, decode_len=8)]
        rec = TraceRecorder()
        rep = eng.run(reqs, trace=rec)
        return rep, rec.trace, store

    rep_on, trace_on, store_on = serve(True)
    rep_off, trace_off, _ = serve(False)
    assert not trace_off.prefetches()
    pf_rids = {e.op["request_id"] for e in trace_on.prefetches()}
    assert pf_rids, "no queued request was prefetched"
    # only QUEUED requests are prefetched (q0 is admitted immediately)
    assert "q0" not in pf_rids
    # the prefetched requests' restoration was strictly faster: their
    # transfers rode host bandwidth instead of the remote link
    for rid in pf_rids:
        assert rep_on.restore_secs[rid] < rep_off.restore_secs[rid]
    # prefetch decisions are pinned: the capture replays without the store
    assert replay_trace(trace_on) == trace_on.captured_result()


def test_prefetch_aborted_when_admission_wins_race():
    """A short-lived batch admits the queued request while its prefetch is
    still inflight: the transfer is cancelled (channel freed for the
    foreground restoration), so prefetch is never WORSE than off — and the
    abort is derived state, replaying bit-identically without the store."""
    cfg = get_config("qwen3-8b")

    def serve(prefetch):
        store = TieredKVStore(remote_bw=IO_BANDWIDTHS["10Gbps"])
        eng = SimServingEngine(cfg, HARDWARE["h100"],
                               io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                               stages=1, max_batch=1, kvstore=store,
                               kv_tier="remote", prefetch=prefetch)
        reqs = [Request("q0", 0.0, 4_000, 64, decode_len=8),
                Request("q1", 0.0, 1_500, 64, decode_len=8),
                Request("q2", 0.0, 2_000, 64, decode_len=8)]
        rec = TraceRecorder()
        return eng.run(reqs, trace=rec), rec.trace

    rep_on, trace_on = serve(True)
    rep_off, _ = serve(False)
    aborted = [e for e in trace_on.events
               if e.kind == "abort" and e.op
               and e.op.get("kind") == "prefetch"]
    assert aborted, "q0's 8-step decode should outpace the prefetches"
    for rid in ("q1", "q2"):   # cancelled background work costs nothing
        assert rep_on.restore_secs[rid] == \
            pytest.approx(rep_off.restore_secs[rid])
    assert replay_trace(trace_on) == trace_on.captured_result()


# ---------------------------------------------------------------------------
# Priority/deadline-aware I/O dispatch (satellite)
# ---------------------------------------------------------------------------


def _two_plans(sched, *, prio=None, deadline=None):
    cfg = get_config("qwen3-8b")
    for i, rid in enumerate(("first", "urgent")):
        plans = make_baseline_plans("lmcache", rid, 8_000 - 2_000 * i,
                                    chunk_size=512, l_delta=0,
                                    num_layers=cfg.num_layers)
        kw = {}
        if prio is not None:
            kw["priority"] = prio[i]
        if deadline is not None:
            kw["deadline"] = deadline[i]
        sched.add_request(plans, **kw)


def test_priority_jumps_io_queue():
    """Same candidates, three SLO configurations: default classes keep the
    classic longest-remaining-first order; a higher priority (or tighter
    deadline) makes the urgent request's transfer dispatch first."""
    s = BatchScheduler()
    _two_plans(s)
    assert s.next_io().request_id == "first"     # classic: FCFS head leads

    s = BatchScheduler()
    _two_plans(s, prio=(0, 2))
    assert s.next_io().request_id == "urgent"    # priority jumps the queue

    s = BatchScheduler()
    _two_plans(s, deadline=(120.0, 1.5))
    assert s.next_io().request_id == "urgent"    # deadline breaks the tie


# ---------------------------------------------------------------------------
# Decode-aware marginal-benefit gate (satellite)
# ---------------------------------------------------------------------------


def test_benefit_gate_flips_under_live_decode_batch():
    """A transfer that loses to recompute on an IDLE device wins once the
    recompute alternative is priced against a live decode batch eating
    ``decode_interference`` of the chips; with interference 0 the live
    batch changes nothing (bit-compat default).  The tight case is the
    LAST restoration chunk (pointers converged, one unit left): early
    gates price recompute over the whole remaining span and always pass."""
    idle = SimBackend(_cost())
    busy = SimBackend(_cost(decode_interference=0.6))
    flipped = False
    for n in range(8_192, 33_000, 4_096):
        p = make_baseline_plans("cacheflow", "r", n, chunk_size=512,
                                l_delta=0,
                                num_layers=idle.cost.cfg.num_layers)[0]
        p.plan.comp_next = p.plan.io_next     # one chunk left to cover
        unit = p.plan.io_next
        base = idle.io_benefit(p, unit, None)
        # interference without a live batch changes nothing
        assert busy.io_benefit(p, unit, None) == base
        assert idle.io_benefit(p, unit, None, decode_load=8) == base
        if not base and busy.io_benefit(p, unit, None, decode_load=8):
            flipped = True
    assert flipped, "no length where a live decode batch flips the gate"
