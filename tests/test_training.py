"""Training substrate: loss decreases, determinism, checkpoint/restart,
gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.training import (AdamWConfig, CheckpointManager, DataConfig,
                            batch_at, init_opt_state, make_train_step)
from repro.training.compression import compress_decompress
from repro.distributed.fault_tolerance import (FailureDetector, HostFailure,
                                               StragglerMonitor, TrainingSupervisor)


def _small_setup(grad_accum=1):
    cfg = get_config("qwen1.5-0.5b").reduced()
    m = build_model(cfg, remat_policy="dots")
    params = m.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=50)
    state = init_opt_state(params)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    step = jax.jit(make_train_step(m, opt_cfg, grad_accum=grad_accum))
    return m, params, state, dc, step


def test_loss_decreases():
    m, params, state, dc, step = _small_setup()
    losses = []
    for s in range(25):
        params, state, metrics = step(params, state, batch_at(dc, s))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]


def test_grad_accum_matches_full_batch():
    """grad_accum=4 must give (nearly) the same update as one big batch."""
    m, params, state, dc, step1 = _small_setup(grad_accum=1)
    _, _, _, _, step4 = _small_setup(grad_accum=4)
    batch = batch_at(dc, 0)
    p1, s1, m1 = step1(params, state, batch)
    p4, s4, m4 = step4(params, state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    l1, l4 = jax.tree.leaves(p1)[0], jax.tree.leaves(p4)[0]
    # fp32 summation order differs between one big batch and 4 accumulated
    # micro-batches; on CPU the worst element lands a few e-5 apart.
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l4), atol=1e-4)


def test_data_pipeline_determinism_and_sharding():
    dc1 = DataConfig(vocab_size=100, seq_len=16, global_batch=8, num_hosts=2, host_id=0)
    dc2 = DataConfig(vocab_size=100, seq_len=16, global_batch=8, num_hosts=2, host_id=1)
    a = batch_at(dc1, 7)["tokens"]
    b = batch_at(dc1, 7)["tokens"]
    c = batch_at(dc2, 7)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))   # deterministic
    assert not np.array_equal(np.asarray(a), np.asarray(c))       # host-sharded
    assert a.shape == (4, 17)                                     # local batch


def test_checkpoint_atomic_restart_reshard():
    m, params, state, dc, step = _small_setup()
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep=2)
        for s in (0, 1, 2, 3):
            ck.save(s, {"p": params, "o": state})
        assert ck.steps() == [2, 3]                               # keep=2 gc
        # a crashed tmp dir must not be visible
        os.makedirs(os.path.join(d, "tmp_step_9"), exist_ok=True)
        assert ck.latest_step() == 3
        st, tree = ck.restore({"p": params, "o": state})
        assert st == 3
        np.testing.assert_array_equal(
            np.asarray(jax.tree.leaves(tree["p"])[0]),
            np.asarray(jax.tree.leaves(params)[0]))


def test_supervisor_restarts_from_checkpoint():
    """Injected host failure -> restart resumes from the manifest."""
    m, params0, state0, dc, step = _small_setup()
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep=3)
        sup = TrainingSupervisor(ck)
        trace = []

        def session(start):
            params, state = params0, state0
            first = 0
            if start is not None:
                first, tree = ck.restore({"p": params, "o": state})
                params, state = tree["p"], tree["o"]
                first += 1
            for s in range(first, 12):
                if s == 6 and sup.restarts == 0:
                    raise HostFailure("boom")
                params, state, _ = step(params, state, batch_at(dc, s))
                trace.append(s)
                if s % 2 == 0:
                    ck.save(s, {"p": params, "o": state})
            return 11

        assert sup.run(session) == 11
        assert sup.restarts == 1
        assert trace.count(5) >= 2 or 5 in trace  # resumed near failure point
        assert trace[-1] == 11


def test_failure_detector_and_straggler_monitor():
    t = [0.0]
    fd = FailureDetector(4, timeout=5.0, clock=lambda: t[0])
    t[0] = 3.0
    fd.beat(0); fd.beat(1); fd.beat(2)
    t[0] = 7.0
    assert fd.scan() == [3]
    assert sorted(fd.alive_hosts()) == [0, 1, 2]

    sm = StragglerMonitor(straggler_factor=0.5)
    for _ in range(5):
        sm.report("io0", 100.0)
        sm.report("io1", 10.0)
    assert sm.stragglers() == ["io1"]


def test_compression_error_feedback_converges():
    """Residual carry-over keeps the accumulated compression error bounded."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(4096,)), jnp.float32)
    residual = None
    acc_hat = jnp.zeros_like(g_true)
    acc_true = jnp.zeros_like(g_true)
    for _ in range(20):
        xh, residual = compress_decompress(g_true, residual)
        acc_hat += xh
        acc_true += g_true
    rel = float(jnp.linalg.norm(acc_hat - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel
