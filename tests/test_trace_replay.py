"""Schedule capture & deterministic replay (repro/core/trace.py).

Differential harness for the trace subsystem:

  (a) a SimBackend trace replays through the sim side bit-identically —
      same ops_log, restore_finish and busy fractions — including across a
      JSON round trip and with an injected channel failure;
  (b) the SAME trace replays through the RealBackend side: every dispatched
      op executes on device under the captured interleaving and every
      request's restored cache verifies against its full-prefill ground
      truth (the channel-failure incident re-executes its aborted transfer);
  (c) sim and real replays of one trace agree on dispatch ORDER when
      durations are pinned — the schedule is backend-invariant.

Plus: replay divergence detection, determinism property tests, and
regression tests for the stage-blocked dispatch starvation fix and the
zero-plan strict error.
"""
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core import (CostModel, EngineBackend, EngineCore, EngineRequest,
                        ReplayDivergence, RestorationExecutor, ScheduleTrace,
                        SimBackend, TraceRecorder, capture, replay_trace)
from repro.core.baselines import make_baseline_plans
from repro.core.plans import RequestPlan
from repro.models import build_model

RNG = jax.random.PRNGKey(0)
LENS = {"a": 40, "b": 24, "c": 32}


def _executor(stages=2, chunk=8, lens=LENS):
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    ex = RestorationExecutor(m, params, chunk_size=chunk, stages=stages)
    for rid, n in lens.items():
        inputs = jax.random.randint(RNG, (1, n), 0, cfg.vocab_size) \
            if cfg.input_mode == "tokens" else \
            jax.random.normal(RNG, (1, n, cfg.d_model), jnp.float32)
        ex.remember(rid, inputs)
    return cfg, ex


def _requests(cfg, lens=LENS, *, chunk=8, bounds=None, arrivals=None):
    arrivals = arrivals or {}
    return [EngineRequest(rid, n, arrivals.get(rid, 0.0),
                          make_baseline_plans("cacheflow", rid, n,
                                              chunk_size=chunk, l_delta=16,
                                              num_layers=cfg.num_layers,
                                              stage_bounds=bounds))
            for rid, n in lens.items()]


def _sim_capture(cfg, *, bounds, fail=False, io_channels=2, stages=2):
    """Capture a >=3-request SimBackend trace on the reduced-model geometry;
    with ``fail=True`` a channel dies mid-transfer (abort guaranteed by
    picking the failure time inside a dry-run transfer interval)."""
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    kw = dict(stages=stages, io_channels=io_channels, strict=True)
    fail_at = None
    if fail:
        dry = EngineCore(SimBackend(cost, benefit_gate=False), **kw) \
            .run(_requests(cfg, bounds=bounds))
        t0, t1 = next((t0, t1) for t0, t1, res, _ in dry.ops_log
                      if res == "io1")
        fail_at = {1: (t0 + t1) / 2}
    core = EngineCore(SimBackend(cost, benefit_gate=False),
                      channel_fail_at=fail_at, **kw)
    res, trace = capture(core, _requests(cfg, bounds=bounds))
    assert set(res.restore_finish) == set(LENS)
    if fail:
        assert trace.aborts(), "failure was injected but nothing aborted"
    return res, trace


# ---------------------------------------------------------------------------
# (a) sim -> sim: bit-identical replay, JSON round trip, failure incidents
# ---------------------------------------------------------------------------


def test_sim_replay_bit_identical():
    cfg = get_config("qwen3-8b").reduced()
    bounds = [(0, cfg.num_layers // 2), (cfg.num_layers // 2, cfg.num_layers)]
    res, trace = _sim_capture(cfg, bounds=bounds)
    rep = replay_trace(trace)
    assert rep == res                       # whole EngineResult, bit-exact
    assert rep.ops_log == res.ops_log
    assert rep.restore_finish == res.restore_finish
    assert rep.compute_busy == res.compute_busy
    assert rep.io_busy == res.io_busy


def test_sim_replay_bit_identical_after_json_round_trip(tmp_path):
    cfg = get_config("qwen3-8b").reduced()
    bounds = [(0, cfg.num_layers // 2), (cfg.num_layers // 2, cfg.num_layers)]
    res, trace = _sim_capture(cfg, bounds=bounds, fail=True)
    path = tmp_path / "trace.json"
    trace.save(str(path))
    loaded = ScheduleTrace.load(str(path))
    assert loaded == trace                  # lossless serialization
    rep = replay_trace(loaded)
    assert rep == res
    assert rep == loaded.captured_result()


def test_sim_replay_with_failure_incident_bit_identical():
    """An injected channel failure (aborted + re-dispatched transfer) is part
    of the captured schedule and replays exactly."""
    cfg = get_config("qwen3-8b").reduced()
    bounds = [(0, cfg.num_layers // 2), (cfg.num_layers // 2, cfg.num_layers)]
    res, trace = _sim_capture(cfg, bounds=bounds, fail=True)
    op = trace.aborts()[0].op
    redispatched = [e for e in trace.dispatches() if e.op == op]
    assert len(redispatched) >= 2           # aborted once, re-executed
    assert replay_trace(trace) == res


# ---------------------------------------------------------------------------
# (b) sim -> real: the captured interleaving executes on device and every
#     cache verifies against full-prefill ground truth (tentpole acceptance)
# ---------------------------------------------------------------------------


def test_sim_trace_replays_through_real_backend_with_verification():
    cfg, ex = _executor(stages=2)
    sim_res, trace = _sim_capture(cfg, bounds=ex.bounds, fail=True)
    rep = replay_trace(trace, ex, verify=True)   # verify raises on mismatch
    assert set(rep.restore_finish) == set(LENS)
    for rid in LENS:
        ex.verify(rid)                           # bit-exact per-request cache
    # the real replay executed the EXACT captured interleaving
    assert rep.ops_log == sim_res.ops_log
    assert rep.restore_finish == sim_res.restore_finish


def test_real_capture_replays_through_real_backend():
    """real -> real: a trace captured from on-device execution re-executes
    deterministically (pinned measured durations) and still verifies."""
    cfg, ex = _executor(stages=2)
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    from repro.core import RealBackend
    core = EngineCore(RealBackend(ex), stages=2, io_channels=2, strict=True)
    res, trace = capture(core, _requests(cfg, bounds=ex.bounds))
    cfg2, ex2 = _executor(stages=2)
    rep = replay_trace(trace, ex2, verify=True)
    assert rep.ops_log == res.ops_log
    for rid in LENS:
        ex2.verify(rid)


# ---------------------------------------------------------------------------
# (c) sim <-> real dispatch-order parity under pinned durations
# ---------------------------------------------------------------------------


def test_sim_and_real_replays_dispatch_in_identical_order():
    cfg, ex = _executor(stages=2)
    _, trace = _sim_capture(cfg, bounds=ex.bounds, fail=True)
    rec_sim, rec_real = TraceRecorder(), TraceRecorder()
    res_sim = replay_trace(trace, trace_out=rec_sim)
    res_real = replay_trace(trace, ex, verify=True, trace_out=rec_real)
    key = lambda e: (e.resource, e.op["kind"], e.op["request_id"],
                     e.op["stage"], e.op["unit"])
    assert [key(e) for e in rec_sim.trace.dispatches()] == \
           [key(e) for e in rec_real.trace.dispatches()]
    assert res_sim.ops_log == res_real.ops_log
    assert res_sim.restore_finish == res_real.restore_finish


# ---------------------------------------------------------------------------
# Divergence detection
# ---------------------------------------------------------------------------


def test_replay_divergence_raises():
    cfg = get_config("qwen3-8b").reduced()
    bounds = [(0, cfg.num_layers // 2), (cfg.num_layers // 2, cfg.num_layers)]
    _, trace = _sim_capture(cfg, bounds=bounds)
    # tamper: swap two different recorded dispatches -> op identity mismatch
    d = trace.dispatches()
    i, j = 0, next(k for k, e in enumerate(d) if e.op != d[0].op)
    d[i].op, d[j].op = d[j].op, d[i].op
    with pytest.raises(ReplayDivergence, match="diverged"):
        replay_trace(trace)


def test_replay_rejects_truncated_trace():
    cfg = get_config("qwen3-8b").reduced()
    bounds = [(0, cfg.num_layers // 2), (cfg.num_layers // 2, cfg.num_layers)]
    _, trace = _sim_capture(cfg, bounds=bounds)
    cut = trace.dispatches()[len(trace.dispatches()) // 2]
    trace.events = trace.events[:trace.events.index(cut)]
    with pytest.raises(ReplayDivergence, match="past the end"):
        replay_trace(trace, strict=False)


# ---------------------------------------------------------------------------
# Determinism properties (seeded; hypothesis when available)
# ---------------------------------------------------------------------------


def _seeded_requests(cfg, seed):
    import numpy as np
    rng = np.random.default_rng(seed)
    lens = {f"r{i}": int(rng.integers(600, 6000))
            for i in range(int(rng.integers(3, 7)))}
    arrivals = {rid: float(rng.uniform(0, 0.01)) for rid in lens}
    return [EngineRequest(rid, n, arrivals[rid],
                          make_baseline_plans("cacheflow", rid, n,
                                              chunk_size=256, l_delta=1000,
                                              num_layers=cfg.num_layers))
            for rid, n in lens.items()]


@pytest.mark.property
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_identical_seeds_give_identical_results_and_replays(seed):
    """Same seed -> bit-identical ops_log/EngineResult across repeated
    SimBackend runs; the captured trace replays to the same result; the
    trace JSON round-trips losslessly."""
    cfg = get_config("qwen3-8b")
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    kw = dict(stages=1, io_channels=2, max_active=3, strict=True)
    res1, trace1 = capture(EngineCore(SimBackend(cost), **kw),
                           _seeded_requests(cfg, seed))
    res2, trace2 = capture(EngineCore(SimBackend(cost), **kw),
                           _seeded_requests(cfg, seed))
    assert res1 == res2
    assert res1.ops_log == res2.ops_log
    assert trace1 == trace2
    round_tripped = ScheduleTrace.from_json(trace1.to_json())
    assert round_tripped == trace1
    assert replay_trace(round_tripped) == res1


# ---------------------------------------------------------------------------
# Regression: stage-blocked head must not starve other requests (sequential
# ablation), and zero-plan requests fail cleanly under strict.
# ---------------------------------------------------------------------------


class _ConstBackend(EngineBackend):
    def compute_secs(self, op, req):
        return 1.0

    def io_secs(self, op, req, bandwidth):
        return 0.1


def _two_stage_starvation_requests():
    # "a": compute-only, 4 chunks per stage -> occupies comp0 for 4s, its
    # stage-1 ops are blocked (sequential ablation) until t=4.
    a = [RequestPlan("a", 32, 8, "token", 0, 2, stage=0),
         RequestPlan("a", 32, 8, "token", 2, 4, stage=1)]
    for p in a:
        p.plan.io_enabled = False
    # "b": stage 0 restored by one fast load (t=0.1); its single stage-1
    # compute chunk is then runnable while "a" still grinds stage 0.
    b = [RequestPlan("b", 8, 8, "token", 0, 2, stage=0),
         RequestPlan("b", 8, 8, "token", 2, 4, stage=1)]
    b[0].plan.comp_enabled = False
    b[1].plan.io_enabled = False
    return [EngineRequest("a", 32, 0.0, a), EngineRequest("b", 8, 0.0, b)]


def test_stage_blocked_head_does_not_starve_other_requests():
    core = EngineCore(_ConstBackend(), stages=2, io_channels=1,
                      stage_parallel=False, strict=True)
    res = core.run(_two_stage_starvation_requests())
    # before the fix, b's stage-1 chunk was stranded behind a's blocked head
    # until a finished stage 0 AND stage 1 (finish ~9.0); with blocked
    # requests skipped it dispatches right after b's stage-0 load.
    assert res.restore_finish["b"] == pytest.approx(1.1)
    assert res.restore_finish["a"] == pytest.approx(8.0)
    # b's stage-1 compute overlaps a's stage-0 window in the log
    b_comp1 = next(t0 for t0, _, r, d in res.ops_log
                   if r == "comp1" and d.startswith("b:"))
    assert b_comp1 < 4.0


def test_strict_raises_cleanly_on_zero_plan_request():
    core = EngineCore(_ConstBackend(), stages=1, strict=True)
    with pytest.raises(ValueError, match="zero plans"):
        core.run([EngineRequest("empty", 10)])
    # non-strict: plan-less requests are dropped, the rest still run
    core = EngineCore(_ConstBackend(), stages=1)
    ok = [RequestPlan("ok", 8, 8, "token", 0, 2, stage=0)]
    res = core.run([EngineRequest("empty", 10), EngineRequest("ok", 8, 0.0, ok)])
    assert set(res.restore_finish) == {"ok"}


def test_engine_request_default_plans_not_shared():
    r1, r2 = EngineRequest("x", 1), EngineRequest("y", 1)
    assert r1.plans == [] and r1.plans is not r2.plans
