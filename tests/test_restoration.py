"""CacheFlow restoration correctness (the paper's core):
restored cache ≡ full-prefill cache for every strategy, stage count, and
legal op interleaving; first-token logits agree with the reference path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import RestorationExecutor
from repro.core.baselines import make_baseline_plans
from repro.models import build_model

ARCHS = ["qwen3-8b", "deepseek-v2-236b", "deepseek-moe-16b",
         "recurrentgemma-2b", "rwkv6-7b", "musicgen-large"]
N = 40
RNG = jax.random.PRNGKey(0)


def _setup(arch, stages=1, chunk=8):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    ex = RestorationExecutor(m, params, chunk_size=chunk, stages=stages)
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(RNG, (1, N), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(RNG, (1, N, cfg.d_model), jnp.float32)
    ex.remember("req", inputs)
    return cfg, m, ex


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("strategy", ["token", "layer"])
def test_restoration_matches_prefill(arch, strategy):
    cfg, m, ex = _setup(arch)
    if cfg.rwkv is not None and strategy == "token":
        pytest.skip("token pointers inapplicable to attention-free archs")
    ex.restore("req", strategy=strategy, op_order="alternate")
    ex.verify("req")


@pytest.mark.parametrize("arch", ["qwen3-8b", "recurrentgemma-2b"])
@pytest.mark.parametrize("stages", [2, 3])
def test_stage_parallel_restoration(arch, stages):
    """3D dimension: per-stage restoration from boundary activations."""
    cfg, m, ex = _setup(arch, stages=stages)
    ex.restore("req", l_delta=16)
    ex.verify("req")


@pytest.mark.property
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       order=st.sampled_from(["random", "io_first", "compute_first"]))
def test_any_interleaving_is_correct(seed, order):
    """Property: op interleaving must not affect the restored cache."""
    cfg, m, ex = _setup("qwen3-8b")
    ex.restore("req", l_delta=16, op_order=order,
               rng=np.random.default_rng(seed))
    ex.verify("req")


@pytest.mark.parametrize("system", ["vllm", "lmcache", "cake", "cacheflow"])
def test_baseline_plans_restore_correctly(system):
    """Every baseline strategy produces a correct cache (they differ in
    TIME, never in the result)."""
    cfg, m, ex = _setup("qwen3-8b")
    plans = make_baseline_plans(system, "req", N, chunk_size=8, l_delta=16,
                                num_layers=cfg.num_layers)
    ex.restore("req", plans=plans)
    ex.verify("req")


def test_first_token_matches_reference():
    """TTFT tokens from a restored engine == tokens from the cold path."""
    cfg, m, ex = _setup("qwen3-8b")
    new = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab_size)
    # reference: full prefill of prefix+suffix in one go
    req = ex.store.get("req")
    full = jnp.concatenate([req.inputs, new], axis=1)
    logits_ref, _ = m.prefill(m.init(RNG), full)  # fresh params? no — same
    params = ex.params
    logits_ref, _ = m.prefill(params, full)
    # restored path
    ex.restore("req", l_delta=16)
    logits_restored = ex.first_token_logits("req", new)
    np.testing.assert_allclose(np.asarray(logits_restored, np.float32),
                               np.asarray(logits_ref, np.float32),
                               atol=3e-2, rtol=3e-2)
    assert int(jnp.argmax(logits_restored)) == int(jnp.argmax(logits_ref))


def test_boundary_activations_smaller_than_kv():
    """Paper §3.2: the boundary payload is much smaller than the stage KV."""
    cfg, m, ex = _setup("qwen3-8b", stages=2)
    req = ex.store.get("req")
    b_bytes = ex.store.boundary_bytes("req", 1)
    kv_bytes = sum(int(np.asarray(v).nbytes) for k, v in req.kv_reference.items()
                   if k in ("k", "v", "ckv"))
    assert b_bytes * 2 < kv_bytes
