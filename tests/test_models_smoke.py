"""Per-architecture smoke tests (deliverable f): REDUCED config of every
assigned arch runs one forward + one train step on CPU; output shapes and
finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import build_model
from repro.training import AdamWConfig, DataConfig, batch_at, embedding_batch_at, \
    init_opt_state, make_train_step

B, S = 2, 16


def _inputs(cfg, rng):
    if cfg.input_mode == "tokens":
        return jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    return jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    logits, aux = m.forward(params, _inputs(cfg, jax.random.PRNGKey(1)))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg, remat_policy="dots", moe_dropless=False)
    params = m.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B)
    batch = (batch_at(dc, 0) if cfg.input_mode == "tokens"
             else embedding_batch_at(dc, 0, cfg.d_model))
    step = jax.jit(make_train_step(m, AdamWConfig(total_steps=10)))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt2.step) == 1
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(params2)[0]
    assert not np.array_equal(np.asarray(d0, np.float32), np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-moe-16b",
                                  "recurrentgemma-2b", "rwkv6-7b", "musicgen-large"])
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill == greedy decode from full forward."""
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    inputs = _inputs(cfg, jax.random.PRNGKey(2))
    logits_full, _ = m.forward(params, inputs)
    last, cache = m.prefill(params, inputs)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               atol=2e-2, rtol=2e-2)


def test_param_count_accounting_matches_init():
    """config.param_counts() ≈ actual initialized leaf count."""
    for arch in ("qwen3-8b", "deepseek-v2-236b", "rwkv6-7b", "recurrentgemma-2b"):
        cfg = get_config(arch).reduced()
        m = build_model(cfg)
        n_real = m.num_params(m.init(jax.random.PRNGKey(0)))
        n_pred = cfg.param_counts()["total"]
        assert abs(n_real - n_pred) / n_real < 0.15, (arch, n_real, n_pred)
