"""Property tests (hypothesis) on the paper's scheduling invariants."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.cost_model import CostModel
from repro.core.plans import TwoPointerPlan, make_request_plans
from repro.core.scheduler import BatchScheduler
from repro.config import HARDWARE, ModelConfig

CFG = ModelConfig(name="t", family="dense", num_layers=8, d_model=256,
                  num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512,
                  vocab_size=1024)


# ---------------------------------------------------------------------------
# TwoPointerPlan invariants: pointers never cross, every unit exactly once
# ---------------------------------------------------------------------------


@pytest.mark.property
@settings(max_examples=200, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 2**31 - 1),
       io_on=st.booleans(), comp_on=st.booleans())
def test_two_pointer_exact_coverage(n, seed, io_on, comp_on):
    if not io_on and not comp_on:
        comp_on = True
    plan = TwoPointerPlan(n, comp_enabled=comp_on, io_enabled=io_on)
    rng = np.random.default_rng(seed)
    restored = []
    guard = 0
    while not plan.done:
        guard += 1
        assert guard < 10 * n + 10, "livelock"
        if rng.random() < 0.5:
            u = plan.claim_compute()
            if u is not None:
                plan.complete_compute(u)
                restored.append(u)
        else:
            u = plan.claim_io()
            if u is not None:
                plan.complete_io(u)
                restored.append(u)
    # every unit exactly once
    assert sorted(restored) == list(range(n))
    # pointers never crossed: compute prefix and io suffix are disjoint
    assert plan.comp_done + plan.io_done == n


@pytest.mark.property
@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 30), seed=st.integers(0, 2**31 - 1))
def test_inflight_units_never_collide(n, seed):
    plan = TwoPointerPlan(n)
    rng = np.random.default_rng(seed)
    guard = 0
    while not plan.done and guard < 500:
        guard += 1
        c = plan.claim_compute() if rng.random() < 0.7 else None
        i = plan.claim_io() if rng.random() < 0.7 else None
        if c is not None and i is not None:
            assert c != i
        if c is not None:
            plan.complete_compute(c)
        if i is not None:
            plan.complete_io(i)


# ---------------------------------------------------------------------------
# Batch scheduler: coverage across requests; policy sanity
# ---------------------------------------------------------------------------


@pytest.mark.property
@settings(max_examples=50, deadline=None)
@given(lengths=st.lists(st.integers(100, 30_000), min_size=1, max_size=6),
       seed=st.integers(0, 2**31 - 1),
       policy=st.sampled_from(["longest_remaining", "fifo", "shortest_remaining"]))
def test_batch_scheduler_completes_everything(lengths, seed, policy):
    sched = BatchScheduler(io_policy=policy)
    for i, n in enumerate(lengths):
        sched.add_request(make_request_plans(f"r{i}", n, chunk_size=512,
                                             l_delta=4096, num_layers=8))
    rng = np.random.default_rng(seed)
    guard = 0
    while not sched.all_done():
        guard += 1
        assert guard < 10_000
        progressed = False
        if rng.random() < 0.5:
            op = sched.next_io()
            if op:
                sched.complete(op)
                progressed = True
        op = sched.next_compute(stage=0)
        if op:
            sched.complete(op)
            progressed = True
        if not progressed:
            op = sched.next_io()
            if op:
                sched.complete(op)
                progressed = True
        assert progressed or sched.all_done()
    for i in range(len(lengths)):
        assert sched.request_done(f"r{i}")


def test_longest_remaining_priority():
    """Operationalised §3.3 policy: the compute-head request's transfers are
    critical-path-first; surplus channel capacity prefetches the request with
    the LARGEST remaining restoration (not FIFO)."""
    sched = BatchScheduler(io_policy="longest_remaining")
    sched.add_request(make_request_plans("head", 1000, chunk_size=100,
                                         l_delta=0, num_layers=8))
    sched.add_request(make_request_plans("mid", 5000, chunk_size=100,
                                         l_delta=0, num_layers=8))
    sched.add_request(make_request_plans("long", 10_000, chunk_size=100,
                                         l_delta=0, num_layers=8))
    op1 = sched.next_io()
    assert op1.request_id == "head"          # critical path first
    op2 = sched.next_io()                    # head busy -> longest prefetch
    assert op2.request_id == "long"


# ---------------------------------------------------------------------------
# Harmonic-mean bound (Eq. 1): two-pointer optimum <= any static split
# ---------------------------------------------------------------------------


@pytest.mark.property
@settings(max_examples=50, deadline=None)
@given(n=st.integers(1_000, 40_000), bw_gbps=st.floats(1.0, 100.0),
       mfu=st.floats(0.2, 0.9))
def test_token_split_beats_static_splits(n, bw_gbps, mfu):
    cost = CostModel(CFG, HARDWARE["tpu_v5e"], bw_gbps * 1e9 / 8, mfu=mfu)
    t_opt = cost.t_token_wise(n)
    # optimal two-pointer beats any static split, up to one chunk's fixed
    # overhead (the split is chunk-quantised)
    slack = cost.hw.kernel_overhead_s + 1e-9
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        k = int(n * frac)
        t_static = max(cost.t_comp(k), cost.t_io_tokens(n - k))
        assert t_opt <= t_static + slack
    # and the harmonic bound lower-bounds both pure strategies (Eq. 1)
    assert cost.harmonic_bound(n) <= min(cost.t_comp(n), cost.t_io_tokens(n)) + 1e-9


@pytest.mark.property
@settings(max_examples=30, deadline=None)
@given(n=st.integers(2_000, 40_000), stages=st.integers(1, 8))
def test_stage_parallel_linear_speedup(n, stages):
    cost = CostModel(CFG, HARDWARE["tpu_v5e"], 10e9 / 8)
    t1 = cost.stage_parallel_bound(n, 1)
    ts = cost.stage_parallel_bound(n, stages)
    np.testing.assert_allclose(ts, t1 / stages, rtol=1e-9)  # Eq. 2


@pytest.mark.property
@settings(max_examples=30, deadline=None)
@given(bw=st.floats(1.0, 200.0), mfu=st.floats(0.2, 0.9))
def test_l_delta_crossover_is_stable(bw, mfu):
    """Fig. 3: a crossover exists and once token-wise wins it KEEPS winning
    for longer prefixes (the quadratic recompute skew only grows)."""
    c = CostModel(CFG, HARDWARE["tpu_v5e"], bw * 1e9 / 8, mfu=mfu)
    ld = c.crossover_l_delta(max_n=32768)
    assert 128 <= ld <= 32768
    if ld <= 8192:
        # one kernel-launch of absolute slack: at tiny scales both strategies
        # are fixed-overhead dominated and the comparison is launch noise
        assert c.t_token_wise(4 * ld) <= (c.t_layer_wise(4 * ld) * 1.1
                                          + c.hw.kernel_overhead_s)
