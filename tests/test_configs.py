"""Config registry + parameter accounting tests."""
import pytest

from repro.config import SHAPES, supports_shape
from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config

# published sizes (±5%)
EXPECTED_TOTAL = {
    "phi4-mini-3.8b": 3.8e9,
    "mistral-large-123b": 123e9,
    "qwen1.5-0.5b": 0.46e9,
    "qwen1.5-110b": 111e9,
    "pixtral-12b": 12.2e9,
    "deepseek-v2-236b": 236e9,
    "deepseek-moe-16b": 16.4e9,
    "recurrentgemma-2b": 2.7e9,
    "rwkv6-7b": 7.5e9,
    "qwen3-8b": 8.2e9,
    "llama3.1-8b": 8.0e9,
    "qwen3-30b-a3b": 30.5e9,
}
EXPECTED_ACTIVE = {
    "deepseek-v2-236b": 21e9,
    "deepseek-moe-16b": 2.8e9,
    "qwen3-30b-a3b": 3.3e9,
}


def test_registry_has_ten_assigned():
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.num_layers > 0 and cfg.d_model > 0


@pytest.mark.parametrize("arch", sorted(EXPECTED_TOTAL))
def test_param_counts_match_published(arch):
    pc = get_config(arch).param_counts()
    exp = EXPECTED_TOTAL[arch]
    assert abs(pc["total"] - exp) / exp < 0.08, (pc["total"], exp)
    if arch in EXPECTED_ACTIVE:
        expa = EXPECTED_ACTIVE[arch]
        assert abs(pc["active"] - expa) / expa < 0.12


def test_long_context_support_matrix():
    subq = {a for a in ALL_ARCHS if get_config(a).sub_quadratic}
    assert subq == {"recurrentgemma-2b", "rwkv6-7b"}
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        assert supports_shape(cfg, SHAPES["train_4k"])
        assert supports_shape(cfg, SHAPES["decode_32k"])
        assert supports_shape(cfg, SHAPES["long_500k"]) == cfg.sub_quadratic


def test_kv_bytes_per_token():
    # MLA cache must be dramatically smaller than an equivalent MHA cache
    ds = get_config("deepseek-v2-236b")
    assert ds.kv_bytes_per_token() == 60 * (512 + 64) * 2
    # attention-free: no KV
    assert get_config("rwkv6-7b").kv_bytes_per_token() == 0
    # hybrid: only the 1-in-3 attention layers hold KV
    rg = get_config("recurrentgemma-2b")
    assert rg.kv_bytes_per_token() == len(rg.attention_layers) * 2 * 1 * 256 * 2


def test_reduced_configs_are_small():
    for arch in ALL_ARCHS:
        r = get_config(arch).reduced()
        assert r.d_model <= 256 and r.num_layers <= 6
        assert r.family == get_config(arch).family
