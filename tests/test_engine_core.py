"""The shared engine core: batched REAL restoration (N requests in flight,
randomized interleavings, per-request verification), backend-agnostic
scheduling parity, continuous-batching admission, KV-store tier integration
and failure injection — all through the one event loop both serving engines
use."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core import (CostModel, EngineBackend, EngineCore, EngineRequest,
                        RealBackend, RestorationExecutor, SimBackend,
                        interleaving_dur_fn)
from repro.core.baselines import make_baseline_plans
from repro.models import build_model
from repro.serving import RealServingEngine, Request, TieredKVStore

RNG = jax.random.PRNGKey(0)
LENS = {"a": 40, "b": 24, "c": 32}


def _executor(arch="qwen3-8b", stages=1, chunk=8, lens=LENS):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    ex = RestorationExecutor(m, params, chunk_size=chunk, stages=stages)
    for rid, n in lens.items():
        if cfg.input_mode == "tokens":
            inputs = jax.random.randint(RNG, (1, n), 0, cfg.vocab_size)
        else:
            inputs = jax.random.normal(RNG, (1, n, cfg.d_model), jnp.float32)
        ex.remember(rid, inputs)
    return cfg, ex


def _engine_requests(cfg, ex, lens=LENS, system="cacheflow", l_delta=16):
    bounds = ex.bounds if ex.stages > 1 else None
    return [EngineRequest(rid, n, 0.0,
                          make_baseline_plans(system, rid, n,
                                              chunk_size=ex.chunk_size,
                                              l_delta=l_delta,
                                              num_layers=cfg.num_layers,
                                              stage_bounds=bounds))
            for rid, n in lens.items()]


# ---------------------------------------------------------------------------
# Tentpole acceptance: >= 3 requests restored CONCURRENTLY in real mode,
# every per-request cache verified against its full-prefill ground truth.
# ---------------------------------------------------------------------------


def test_batched_real_restoration_three_requests():
    cfg, ex = _executor()
    reqs = _engine_requests(cfg, ex)
    # seeded schedule durations: measured CPU timings occasionally let the
    # FIFO head run as a sequential block, making the interleaving
    # assertion below flaky; rng durations keep the schedule deterministic
    # while the ops still execute for real on device.  Two channels make
    # the interleaving structural: the surplus channel always prefetches a
    # non-head request (with one channel, FCFS compute + head-first I/O
    # legitimately drain requests as sequential blocks now that compute can
    # no longer double-claim the unit an in-flight transfer is restoring).
    dur = interleaving_dur_fn("random", np.random.default_rng(0))
    core = EngineCore(RealBackend(ex, dur_fn=dur), stages=1, io_channels=2,
                      strict=True)
    res = core.run(reqs)
    assert set(res.restore_finish) == set(LENS)
    for rid in LENS:
        ex.verify(rid)
    # the schedule truly interleaved: ops of different requests alternate
    # rather than running as three sequential blocks
    rids = [desc.split(":")[0] for _, _, _, desc in res.ops_log]
    switches = sum(1 for x, y in zip(rids, rids[1:]) if x != y)
    assert switches > len(LENS) - 1, rids


@pytest.mark.property
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_batched_real_any_interleaving_is_correct(seed):
    """Multi-request extension of the single-request interleaving property:
    rng-drawn op durations reorder completions (and hence every subsequent
    claim), and each restored cache must still match its ground truth."""
    cfg, ex = _executor(stages=2)
    reqs = _engine_requests(cfg, ex)
    dur = interleaving_dur_fn("random", np.random.default_rng(seed))
    core = EngineCore(RealBackend(ex, dur_fn=dur), stages=2, io_channels=2,
                      strict=True)
    core.run(reqs)
    for rid in LENS:
        ex.verify(rid)


def test_real_serving_engine_batched_with_admission():
    """RealServingEngine routes through the core: batched restoration under
    a continuous-batching cap, per-request verify + suffix prefill."""
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    eng = RealServingEngine(m, params, system="cacheflow", stages=2,
                            chunk_size=8, max_batch=2)
    reqs = [Request("a", 0.0, 40, 8), Request("b", 0.0, 24, 8),
            Request("c", 0.0, 32, 8)]
    rep = eng.serve(reqs, verify=True)     # verify raises on any KV mismatch
    assert set(rep.ttfts) == {"a", "b", "c"}
    assert all(v > 0 for v in rep.ttfts.values())


def test_real_failure_injection_recovers():
    """A transfer channel failing mid-restoration re-queues its claims; real
    re-execution is idempotent so every cache still verifies."""
    cfg, ex = _executor()
    reqs = _engine_requests(cfg, ex, system="lmcache")   # I/O-heavy
    dur = interleaving_dur_fn("alternate", np.random.default_rng(7))
    core = EngineCore(RealBackend(ex, dur_fn=dur), stages=1, io_channels=2,
                      channel_fail_at={1: 1.5}, strict=True)
    res = core.run(reqs)
    assert set(res.restore_finish) == set(LENS)
    for rid in LENS:
        ex.verify(rid)


# ---------------------------------------------------------------------------
# Backend-agnosticism: identical durations => identical scheduling decisions
# ---------------------------------------------------------------------------


class _ConstBackend(EngineBackend):
    def compute_secs(self, op, req):
        return 1.0

    def io_secs(self, op, req, bandwidth):
        return 1.0


def test_sim_and_real_backends_schedule_identically():
    cfg, ex = _executor()
    kw = dict(stages=1, io_channels=1, strict=True)
    res_real = EngineCore(RealBackend(ex, dur_fn=lambda op: 1.0),
                          **kw).run(_engine_requests(cfg, ex))
    cfg2, ex2 = _executor()
    res_stub = EngineCore(_ConstBackend(), **kw).run(_engine_requests(cfg2, ex2))
    assert [d for *_, d in res_real.ops_log] == [d for *_, d in res_stub.ops_log]
    assert res_real.restore_finish == res_stub.restore_finish


# ---------------------------------------------------------------------------
# Admission + KV-store integration (sim backend — pure event loop)
# ---------------------------------------------------------------------------


def _sim_core(**kw):
    cfg = get_config("qwen3-8b")
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    return cfg, EngineCore(SimBackend(cost), **kw)


def _sim_requests(cfg, lens, **plan_kw):
    return [EngineRequest(rid, n, 0.0,
                          make_baseline_plans("cacheflow", rid, n,
                                              chunk_size=256, l_delta=0,
                                              num_layers=cfg.num_layers,
                                              **plan_kw))
            for rid, n in lens.items()]


def test_admission_cap_serializes_requests():
    cfg, core = _sim_core(stages=1, io_channels=1, max_active=1)
    res = core.run(_sim_requests(cfg, {"r0": 8000, "r1": 8000}))
    assert res.restore_start["r1"] >= res.restore_finish["r0"]
    cfg, core2 = _sim_core(stages=1, io_channels=1, max_active=0)
    res2 = core2.run(_sim_requests(cfg, {"r0": 8000, "r1": 8000}))
    assert res2.restore_start["r1"] < res.restore_start["r1"]


def test_kvstore_touch_and_promote_on_restore():
    """Restoring a request must refresh its LRU position and pull the
    payload up a tier — previously dead TieredKVStore API, now wired into
    the engine loop."""
    store = TieredKVStore(hbm_cap=0, host_cap=10**9, remote_cap=10**12)
    cfg, core = _sim_core(stages=1, io_channels=1, kvstore=store)
    store.put("cold", 1000, tier="remote")
    store.put("hot", 1000, tier="remote")
    assert store.tier_of("cold") == "remote"
    res = core.run(_sim_requests(cfg, {"cold": 4000}))
    assert "cold" in res.restore_finish
    assert store.tier_of("cold") == "host"          # promoted on completion
    assert store.tier_of("hot") == "remote"         # untouched request stays
    # dispatch-time bandwidth: the loads saw the REMOTE tier's bandwidth,
    # so a full-chunk transfer takes exactly chunk_bytes / remote_bw
    # (orders of magnitude above what the host tier would give)
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    io_durs = [t1 - t0 for t0, t1, res_name, _ in res.ops_log
               if res_name.startswith("io")]
    assert io_durs, "expected I/O dispatches"
    chunk_bytes = 256 * cost.bytes_per_token()
    assert max(io_durs) == pytest.approx(
        chunk_bytes / store.tiers["remote"].bandwidth, rel=1e-6)
    assert max(io_durs) > 10 * chunk_bytes / store.tiers["host"].bandwidth


def test_stalled_engine_raises_when_strict():
    cfg, core = _sim_core(stages=1, io_channels=1, strict=True,
                          channel_fail_at={0: 0.0})
    reqs = _sim_requests(cfg, {"r0": 4000})
    for r in reqs:                     # load-only plan, no working channel
        for p in r.plans:
            p.plan.comp_enabled = False
    with pytest.raises(RuntimeError, match="stalled"):
        core.run(reqs)
