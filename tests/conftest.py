import os
import sys

# tests run against the source tree; smoke tests must see 1 device
# (the 512-device override belongs ONLY to launch/dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
