"""Preemption-safe engine core: restoration preempt/resume under admission
pressure, plus the contention-blind benefit-gate / abort-accounting fixes.

  * Policy: under ``preempt="priority"`` a higher-priority arrival that
    finds ``max_active`` full suspends the still-restoring victim with the
    smallest remaining restoration benefit instead of queueing; the victim
    resumes on a freed slot with every completed unit intact (resume, not
    restart — EngineResult accounting proves it).
  * Invariants (property test): across randomized interleavings and
    preempt/resume cycles every unit is restored exactly once, no claim
    leaks, and phase transitions stay monotone.
  * Real mode: a preempted-then-resumed request's restored cache verifies
    bit-exactly and its first-token logits + greedy decode outputs match
    the no-preemption full-prefill reference.
  * Trace schema v3: preempt/resume events round-trip and replay
    bit-identically; v2 (pre-preemption) traces still load.
  * Gate fix: the marginal-benefit gate prices transfers at the candidate
    channel's EFFECTIVE bandwidth — a degraded channel flips the decision.
  * Abort fix: aborted transfers are excluded from ``io_busy`` and tagged
    ``:aborted`` in ``ops_log``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _engine_helpers import RngBackend
from _hypothesis_compat import given, settings, st

from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core import (CostModel, EngineCore, EngineRequest,
                        RealBackend, RestorationExecutor, ScheduleTrace,
                        SimBackend, capture, interleaving_dur_fn, replay_trace)
from repro.core.baselines import make_baseline_plans
from repro.core.plans import make_request_plans
from repro.core.trace import TRACE_VERSION
from repro.models import build_model
from repro.models.kvcache import grow_cache
from repro.serving import RealServingEngine, Request, SimServingEngine
from repro.serving.workloads import bursty_priority

RNG = jax.random.PRNGKey(0)


def _cost(arch="qwen3-8b", hw="h100", bw="10Gbps"):
    return CostModel(get_config(arch), HARDWARE[hw], IO_BANDWIDTHS[bw], mfu=0.45)


def _req(cfg, rid, n, arrival=0.0, prio=0, new=128, dec=8, chunk=512):
    plans = make_baseline_plans("cacheflow", rid, n, chunk_size=chunk,
                                l_delta=0, num_layers=cfg.num_layers)
    return EngineRequest(rid, n, arrival, plans, new_len=new, decode_len=dec,
                         priority=prio)


def _burst(cfg):
    """Two long low-priority restorations saturate max_active=2; a burst of
    two short high-priority requests lands mid-restoration."""
    return [_req(cfg, "bg0", 30_000), _req(cfg, "bg1", 28_000),
            _req(cfg, "hi0", 1_000, 0.5, prio=1),
            _req(cfg, "hi1", 1_200, 0.5, prio=1)]


def _completed_restoration_units(res, rid):
    """Restoration ops of ``rid`` that ran to completion (aborted excluded)."""
    return sum(1 for *_, desc in res.ops_log
               if desc.startswith(f"{rid}:") and not desc.endswith(":aborted")
               and desc.split(":")[1][0] in "cl")


# ---------------------------------------------------------------------------
# Tentpole: priority preemption cuts high-priority TTFT; resume, not restart
# ---------------------------------------------------------------------------


def test_priority_preemption_reduces_high_priority_ttft():
    cost = _cost()
    cfg = cost.cfg
    results = {}
    for policy in ("none", "priority"):
        core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                          max_active=2, preempt=policy, strict=True)
        results[policy] = core.run(_burst(cfg))
    base, pre = results["none"], results["priority"]
    assert not base.preemptions and pre.preemptions
    hi = ("hi0", "hi1")
    ttft = lambda r: np.mean([r.first_token[h] - 0.5 for h in hi])
    # acceptance: high-priority mean TTFT drops, makespan regresses < 10%
    assert ttft(pre) < ttft(base) * 0.7
    assert pre.makespan < base.makespan * 1.10
    # resume, not restart: a preempted request's completed units are all
    # kept — the non-aborted restoration op count is EXACTLY its unit total
    for rid, count in pre.preemptions.items():
        assert count >= 1
        req = next(r for r in _burst(cfg) if r.request_id == rid)
        total_units = sum(p.plan.n_units for p in req.plans)
        assert _completed_restoration_units(pre, rid) == total_units


def test_preempted_victim_is_least_remaining_benefit():
    """Among eligible victims the engine suspends the one with the SMALLEST
    remaining restoration (least marginal recompute saving): bg1 is nearly
    done when the urgent request arrives, so bg1 — not bg0 — is paused."""
    cost = _cost()
    cfg = cost.cfg
    reqs = [_req(cfg, "bg0", 30_000), _req(cfg, "bg1", 6_000),
            _req(cfg, "hi0", 1_000, 0.5, prio=1)]
    core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                      max_active=2, preempt="priority", strict=True)
    res = core.run(reqs)
    assert "bg1" in res.preemptions and "bg0" not in res.preemptions


def test_deadline_policy_preempts_later_deadline():
    cost = _cost()
    cfg = cost.cfg

    def mk():
        slack = _req(cfg, "slack", 20_000)
        slack.deadline = 500.0
        urgent = _req(cfg, "edf", 1_000, 0.5)
        urgent.deadline = 1.0
        return [slack, urgent]

    results = {}
    for policy in ("none", "deadline"):
        core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                          max_active=1, preempt=policy, strict=True)
        results[policy] = core.run(mk())
    res = results["deadline"]
    # the slack request (later deadline) is the victim, never the EDF winner
    assert res.preemptions == {"slack": 1}
    # EDF admission puts the urgent request far ahead of FCFS queueing
    ttft = lambda r: r.first_token["edf"] - 0.5
    assert ttft(res) < ttft(results["none"]) * 0.5
    # the suspended request still finishes, with all its units intact
    assert _completed_restoration_units(res, "slack") == \
        sum(p.plan.n_units for p in mk()[0].plans)


def test_preempt_none_keeps_fcfs_and_rejects_unknown_policy():
    cost = _cost()
    cfg = cost.cfg
    core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                      max_active=2, preempt="none", strict=True)
    res = core.run(_burst(cfg))
    assert res.preemptions == {}
    # FCFS: the burst waits for a freed slot, after the earlier arrivals
    assert min(res.restore_start["hi0"], res.restore_start["hi1"]) \
        >= min(res.finish["bg0"], res.finish["bg1"])
    with pytest.raises(ValueError, match="preempt"):
        EngineCore(SimBackend(cost), preempt="sometimes")


def test_sim_engine_bursty_priority_acceptance():
    """End-to-end acceptance on the serving facade: bursty two-priority
    workload under max_active pressure — preempt="priority" cuts the
    high-priority mean TTFT while total makespan regresses < 10%."""
    cfg = get_config("qwen3-8b")
    reqs = bursty_priority(18, seed=3, burst_every=2.0, burst_size=3)
    reports = {}
    for policy in ("none", "priority"):
        eng = SimServingEngine(cfg, HARDWARE["h100"],
                               io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                               stages=1, max_batch=2, preempt=policy)
        reports[policy] = eng.run([Request(**{
            "request_id": r.request_id, "arrival": r.arrival,
            "prefix_len": r.prefix_len, "new_len": r.new_len,
            "decode_len": r.decode_len, "priority": r.priority,
            "deadline": r.deadline}) for r in reqs])
    base, pre = reports["none"], reports["priority"]
    assert sum(pre.preemptions.values()) > 0
    hi = [r.request_id for r in reqs if r.priority > 0]
    hi_mean = lambda rep: np.mean([rep.ttfts[h] for h in hi])
    e2e_end = lambda rep: max(rep.e2e[r.request_id] + r.arrival for r in reqs)
    assert hi_mean(pre) < hi_mean(base)
    assert e2e_end(pre) < e2e_end(base) * 1.10


# ---------------------------------------------------------------------------
# Property: preemption invariants under randomized interleavings
# ---------------------------------------------------------------------------


@pytest.mark.property
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_preemption_invariants_random_interleavings(seed):
    """Across preempt/resume cycles: every unit restored exactly once, no
    claim leaks, monotone phase transitions, nothing lost or restarted."""
    rng = np.random.default_rng(seed)
    stages = int(rng.integers(1, 3))
    bounds = [(0, 2), (2, 4)][:stages] if stages == 2 else [(0, 4)]
    policy = ["priority", "deadline"][int(rng.integers(0, 2))]
    reqs = []
    for i in range(int(rng.integers(4, 8))):
        n = int(rng.integers(16, 160))
        plans = make_request_plans(f"r{i}", n, chunk_size=8, l_delta=0,
                                   num_layers=4, stage_bounds=bounds,
                                   strategy="token")
        reqs.append(EngineRequest(
            f"r{i}", n, arrival=float(rng.uniform(0, 3.0)), plans=plans,
            new_len=int(rng.integers(0, 3)) * 16,
            decode_len=int(rng.integers(0, 5)),
            priority=int(rng.integers(0, 3)),
            deadline=float(rng.uniform(0.5, 20.0))))
    core = EngineCore(RngBackend(seed), stages=stages,
                      io_channels=int(rng.integers(1, 3)),
                      max_active=int(rng.integers(1, 4)),
                      preempt=policy, strict=True)
    res = core.run(reqs)
    for r in reqs:
        rid = r.request_id
        # lifecycle completed, monotone
        assert rid in res.restore_finish and rid in res.finish
        assert res.restore_start[rid] <= res.restore_finish[rid] \
            <= res.finish[rid]
        if r.new_len > 0 or r.decode_len > 0:
            assert res.restore_finish[rid] <= res.first_token[rid] \
                <= res.finish[rid]
        # no claim leaks, all plans done
        for p in r.plans:
            assert p.plan.done
            assert p.plan.comp_inflight is None and p.plan.io_inflight is None
            assert p.plan.comp_done + p.plan.io_done == p.plan.n_units
        # every unit restored EXACTLY once (preempted or not): completed
        # restoration ops == unit total; aborted ops are tagged separately
        total_units = sum(p.plan.n_units for p in r.plans)
        assert _completed_restoration_units(res, rid) == total_units


# ---------------------------------------------------------------------------
# Real mode: preempted-then-resumed request bit-matches the reference
# ---------------------------------------------------------------------------


def test_real_preempted_request_parity_vs_full_prefill_reference():
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    eng = RealServingEngine(m, params, system="cacheflow", stages=2,
                            chunk_size=8, max_batch=1, preempt="priority")
    reqs = [Request("bg", 0.0, 48, 8, decode_len=3, priority=0),
            Request("hi", 0.3, 16, 8, decode_len=3, priority=1),
            Request("bg2", 0.4, 40, 8, decode_len=3, priority=0)]
    rep = eng.serve(reqs, verify=True, op_order="random",
                    rng=np.random.default_rng(3))  # verify: KV bit-exact
    assert sum(rep.preemptions.values()) > 0, "scenario produced no preemption"
    ex = eng.executor
    for r in reqs:
        out = ex.outputs(r.request_id)
        full = jnp.concatenate([ex.store.get(r.request_id).inputs,
                                ex.suffix_inputs(r.request_id)], axis=1)
        ref_logits, cache = m.prefill(params, full)
        np.testing.assert_allclose(np.asarray(out["first_logits"]),
                                   np.asarray(ref_logits), atol=1e-4)
        cache = grow_cache(cfg, cache, full.shape[1] + r.decode_len)
        logits, pos = ref_logits, full.shape[1]
        toks = [int(jnp.argmax(logits[0]))]
        for _ in range(r.decode_len - 1):
            inp = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            logits, cache = m.decode_step(params, inp, cache, pos)
            pos += 1
            toks.append(int(jnp.argmax(logits[0])))
        assert out["tokens"] == toks, r.request_id


# ---------------------------------------------------------------------------
# Trace schema v3: preempt/resume round-trip + replay; v2 still loads
# ---------------------------------------------------------------------------


def test_trace_v3_preemption_round_trip_and_replay():
    cost = _cost()
    cfg = cost.cfg
    core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                      max_active=2, preempt="priority", strict=True)
    res, trace = capture(core, _burst(cfg))
    assert trace.version == TRACE_VERSION == 5
    assert trace.preempts() and trace.resumes()
    assert trace.meta["preempt"] == "priority"
    assert replay_trace(trace) == res            # bit-identical, incl. aborts
    loaded = ScheduleTrace.from_json(trace.to_json())
    assert loaded == trace
    assert replay_trace(loaded) == res
    assert loaded.captured_result().preemptions == res.preemptions


def test_trace_v2_loads_by_upgrade():
    """A pre-preemption (v2) trace — no priorities, no preempt meta, no
    preemptions in the result — loads cleanly and replays bit-identically
    under the implicit preempt="none" upgrade.  The capture uses
    priority-free requests: a real v2 engine had no SLO classes, so its
    schedule could not depend on them (since v5 the default I/O dispatch
    key IS priority-aware, so a priority-bearing capture would not survive
    having the field stripped)."""
    cost = _cost()
    cfg = cost.cfg
    core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                      max_active=2, strict=True)
    reqs = _burst(cfg)
    for r in reqs:
        r.priority = 0
    res, trace = capture(core, reqs)
    d = trace.to_dict()
    d["version"] = 2
    del d["meta"]["preempt"]
    del d["result"]["preemptions"]
    for r in d["requests"]:
        r.pop("priority", None)
        r.pop("deadline", None)
    up = ScheduleTrace.from_dict(d)
    assert up.version == TRACE_VERSION
    assert replay_trace(up) == res


# ---------------------------------------------------------------------------
# Satellite: contention-aware marginal-benefit gate
# ---------------------------------------------------------------------------


def test_benefit_gate_prices_candidate_channel_slowdown():
    """A transfer that beats recompute at nominal bandwidth LOSES on a
    10x-degraded channel: the gate must flip, and the engine must recompute
    those units instead of loading them over the slow channel."""
    cost = _cost(bw="80Gbps")       # I/O clearly wins at nominal bandwidth
    cfg = cost.cfg
    backend = SimBackend(cost)
    plans = make_baseline_plans("cacheflow", "r", 16_000, chunk_size=512,
                                l_delta=0, num_layers=cfg.num_layers)
    unit = plans[0].plan.io_next
    assert backend.io_benefit(plans[0], unit, None, slowdown=1.0)
    assert not backend.io_benefit(plans[0], unit, None, slowdown=1000.0)

    def run(slowdown):
        core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                          channel_slowdown=slowdown, strict=True)
        return core.run([_req(cfg, "r", 16_000, new=0, dec=0)])

    fast, slow = run(None), run({0: 1000.0})
    loads = lambda r: sum(1 for *_, d in r.ops_log if ":l" in d)
    assert loads(fast) > 0            # nominal channel: gate admits transfers
    assert loads(slow) == 0           # degraded channel: recompute wins
    assert set(slow.restore_finish) == {"r"}


# ---------------------------------------------------------------------------
# Satellite: aborted transfers are not useful work
# ---------------------------------------------------------------------------


def test_aborted_transfer_excluded_from_io_busy_and_tagged():
    cost = _cost()
    cfg = cost.cfg
    kw = dict(stages=1, io_channels=2, strict=True)

    def mk():
        return [EngineRequest(rid, n, 0.0,
                              make_baseline_plans("lmcache", rid, n,
                                                  chunk_size=512, l_delta=0,
                                                  num_layers=cfg.num_layers))
                for rid, n in (("r0", 16_000), ("r1", 12_000))]

    dry = EngineCore(SimBackend(cost), **kw).run(mk())
    t0, t1 = next((t0, t1) for t0, t1, res, _ in dry.ops_log if res == "io1")
    res = EngineCore(SimBackend(cost), channel_fail_at={1: (t0 + t1) / 2},
                     **kw).run(mk())
    aborted = [(t0, t1) for t0, t1, rn, d in res.ops_log
               if d.endswith(":aborted")]
    assert aborted, "failure injected but no op tagged as aborted"
    useful = sum(t1 - t0 for t0, t1, rn, d in res.ops_log
                 if rn.startswith("io") and not d.endswith(":aborted"))
    wasted = sum(t1 - t0 for t0, t1 in aborted)
    assert res.io_busy == pytest.approx(useful / (2 * res.makespan))
    # the uncorrected (pre-fix) fraction would have counted the dead time
    assert res.io_busy < (useful + wasted) / (2 * res.makespan)


# ---------------------------------------------------------------------------
# Satellite: synthetic decode durations see the true batch composition
# ---------------------------------------------------------------------------


def test_real_decode_dur_fn_sees_full_batch():
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    ex = RestorationExecutor(m, params, chunk_size=8, stages=1)
    seen = []

    def dur_fn(op):
        if op.kind == "decode":
            seen.append(op)
        return 0.5

    reqs = []
    # "a" decodes a long tail so "b" joins mid-decode: some steps MUST batch
    for rid, dec in (("a", 16), ("b", 4)):
        ex.remember(rid, jax.random.randint(RNG, (1, 24), 0, cfg.vocab_size))
        ex.set_suffix(rid, jax.random.randint(RNG, (1, 8), 0, cfg.vocab_size),
                      decode_len=dec)
        reqs.append(EngineRequest(rid, 24, 0.0,
                                  ex.make_plans(rid, l_delta=16),
                                  new_len=8, decode_len=dec))
    core = EngineCore(RealBackend(ex, dur_fn=dur_fn), stages=1,
                      io_channels=1, strict=True)
    core.run(reqs)
    assert seen, "no decode steps dispatched"
    # identical durations -> both requests decode in the same batched steps
    assert any(op.batch == ("a", "b") for op in seen)
    for op in seen:
        assert op.batch and op.request_id == op.batch[0]
        assert op.tokens == (0, len(op.batch))
