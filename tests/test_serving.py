"""Serving layer: workloads, KV store tiers, simulation engine reproduces the
paper's qualitative results, real engine end-to-end."""
import jax
import numpy as np
import pytest

from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.models import build_model
from repro.serving import (RealServingEngine, Request, SimServingEngine,
                           TieredKVStore, generate)
from repro.serving.metrics import cdf, percentiles


def test_workload_shapes():
    for w in ("lmsys_chat", "wildchat", "swe_bench"):
        reqs = generate(w, 50, seed=3)
        assert len(reqs) == 50
        lens = [r.prefix_len for r in reqs]
        assert max(lens) > 4000, w            # long-prefix mass (paper Fig 1a)
        arr = [r.arrival for r in reqs]
        assert arr == sorted(arr)
    # agentic prefix reuse
    sw = generate("swe_bench", 30, seed=0)
    assert len({r.prefix_id for r in sw}) < 30


def test_workload_determinism():
    a = generate("lmsys_chat", 20, seed=5)
    b = generate("lmsys_chat", 20, seed=5)
    assert [(r.prefix_len, r.arrival) for r in a] == \
           [(r.prefix_len, r.arrival) for r in b]


def test_kvstore_tiers_lru_spill():
    st = TieredKVStore(hbm_cap=100, host_cap=250, remote_cap=10_000,
                       hbm_bw=800e9, host_bw=100e9, remote_bw=1e9)
    st.put("a", 80, tier="hbm")
    st.put("b", 80, tier="hbm")            # spills "a" to host
    assert st.tier_of("b") == "hbm"
    assert st.tier_of("a") == "host"
    assert st.bandwidth_for("a") == 100e9
    st.put("c", 200, tier="host")          # spills "a" to remote
    assert st.tier_of("a") == "remote"
    st.promote("a", "host")
    assert st.tier_of("a") == "host"


def _run_sim(system, stages=2, **kw):
    cfg = get_config("qwen3-8b")
    reqs = generate("swe_bench", 24, seed=1)
    eng = SimServingEngine(cfg, HARDWARE["h100"],
                           io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                           system=system, stages=stages, max_batch=8, **kw)
    return eng.run(reqs)


def test_sim_reproduces_paper_ordering():
    """Paper §4.2: CacheFlow beats vLLM / LMCache / Cake on mean and tail."""
    reports = {s: _run_sim(s) for s in ("vllm", "lmcache", "cake", "cacheflow")}
    cf = reports["cacheflow"].stats
    for base in ("vllm", "lmcache", "cake"):
        bs = reports[base].stats
        assert cf["mean"] < bs["mean"], (base, cf["mean"], bs["mean"])
        assert cf["p90"] < bs["p90"] * 1.05, base
    # paper band: 1.1x-1.7x+ vs best baseline (we allow the upper side)
    best = min(reports[b].stats["mean"] for b in ("vllm", "lmcache", "cake"))
    assert best / cf["mean"] > 1.1


def test_sim_utilization_pattern():
    """Paper Fig. 5: vLLM compute-bound w/ idle IO; LMCache IO-bound w/ idle
    compute; CacheFlow high on both."""
    r_v = _run_sim("vllm")
    r_l = _run_sim("lmcache")
    r_c = _run_sim("cacheflow")
    assert r_v.io_busy < 0.05 and r_v.compute_busy > 0.3
    assert r_l.compute_busy < 0.05 and r_l.io_busy > 0.5
    assert r_c.compute_busy > r_l.compute_busy
    assert r_c.io_busy > r_v.io_busy


def test_sim_3d_ablation():
    """Paper Fig. 7: disabling stage-parallel restoration hurts."""
    r3d = _run_sim("cacheflow", stages=2)
    r2d = _run_sim("cacheflow_2d", stages=2)
    assert r3d.stats["mean"] < r2d.stats["mean"]


def test_sim_bandwidth_monotonicity():
    """Paper Fig. 8: more I/O bandwidth -> lower TTFT under CacheFlow."""
    cfg = get_config("qwen3-8b")
    means = []
    for bw in ("10Gbps", "40Gbps", "80Gbps"):
        reqs = generate("lmsys_chat", 16, seed=2)
        eng = SimServingEngine(cfg, HARDWARE["h100"],
                               io_bandwidth=IO_BANDWIDTHS[bw],
                               system="cacheflow", stages=1)
        means.append(eng.run(reqs).stats["mean"])
    assert means[0] >= means[1] >= means[2]


def test_real_engine_serves_and_verifies():
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = RealServingEngine(m, params, system="cacheflow", stages=2, chunk_size=8)
    reqs = [Request("a", 0.0, 40, 8), Request("b", 0.0, 24, 8)]
    rep = eng.serve(reqs, verify=True)     # verify raises on any KV mismatch
    assert set(rep.ttfts) == {"a", "b"}
    assert all(v > 0 for v in rep.ttfts.values())


def test_metrics_helpers():
    vals = list(range(1, 101))
    st = percentiles(vals)
    assert st["p50"] == pytest.approx(50.5)
    pts = cdf(vals, n_points=11)
    assert pts[0][1] == 0.0 and pts[-1][1] == 1.0
