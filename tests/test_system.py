"""End-to-end behaviour tests for the CacheFlow system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core import CostModel, RestorationSimulator, SimRequest
from repro.core.baselines import plans_and_kwargs
from repro.core.profiler import profile_analytic
from repro.launch.train import run as train_run


def test_harmonic_bound_is_optimal_envelope():
    """Eq. 1: T* = Tc·Tio/(Tc+Tio) and the simulator's single-request
    two-pointer finish time approaches it (within chunk granularity)."""
    cfg = get_config("qwen3-8b")
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    n = 24_000
    plans, kw = plans_and_kwargs("cake", "r", n, chunk_size=256,
                                 l_delta=0, num_layers=cfg.num_layers)
    sim = RestorationSimulator(cost, stages=1, io_channels=1, **kw)
    res = sim.run([SimRequest("r", n, 0.0, plans)])
    t_sim = res.restore_finish["r"]
    t_star = cost.harmonic_bound(n)
    assert t_star * 0.9 <= t_sim <= t_star * 1.6, (t_sim, t_star)
    assert t_sim <= min(cost.t_comp(n), cost.t_io_tokens(n)) * 1.05


def test_stage_scaling_near_linear():
    """Eq. 2: S stages give ~S× restoration speedup."""
    cfg = get_config("qwen3-8b")
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    n = 24_000
    times = {}
    for s in (1, 2, 4):
        plans, kw = plans_and_kwargs("cacheflow", "r", n, chunk_size=256,
                                     l_delta=0, num_layers=cfg.num_layers,
                                     stage_bounds=[(i * cfg.num_layers // s,
                                                    (i + 1) * cfg.num_layers // s)
                                                   for i in range(s)])
        sim = RestorationSimulator(cost, stages=s, io_channels=s, **kw)
        times[s] = sim.run([SimRequest("r", n, 0.0, plans)]).restore_finish["r"]
    assert times[1] / times[2] > 1.6
    assert times[1] / times[4] > 2.8


def test_l_delta_crossover_exists():
    """Fig. 3: layer-wise wins short prefixes, token-wise wins long ones."""
    cfg = get_config("qwen3-8b")
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["40Gbps"], mfu=0.45)
    prof = profile_analytic(cost, lengths=[128, 512, 2048, 8192, 32768])
    assert prof.t_layer[0] <= prof.t_token[0] * 1.05       # short: layer wins
    assert prof.t_token[-1] <= prof.t_layer[-1] * 1.05     # long: token wins
    assert 128 <= prof.l_delta <= 32768


def test_straggler_channel_failure_recovers():
    """A failed I/O channel mid-restoration must not lose work or hang —
    transfers re-queue (idempotent restoration)."""
    cfg = get_config("qwen3-8b")
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    n = 16_000
    plans, kw = plans_and_kwargs("cacheflow", "r", n, chunk_size=256,
                                 l_delta=0, num_layers=cfg.num_layers)
    sim = RestorationSimulator(cost, stages=1, io_channels=2,
                               channel_fail_at={1: 0.05}, **kw)
    res = sim.run([SimRequest("r", n, 0.0, plans)])
    assert "r" in res.restore_finish          # completed despite the failure
    plans2, kw2 = plans_and_kwargs("cacheflow", "r", n, chunk_size=256,
                                   l_delta=0, num_layers=cfg.num_layers)
    sim2 = RestorationSimulator(cost, stages=1, io_channels=2, **kw2)
    res2 = sim2.run([SimRequest("r", n, 0.0, plans2)])
    assert res.restore_finish["r"] >= res2.restore_finish["r"]  # failure costs time


def test_train_driver_end_to_end_with_failure(tmp_path):
    """launch/train.py: loss decreases and an injected host failure restarts
    from the checkpoint manifest."""
    last = train_run("qwen1.5-0.5b", reduced=True, steps=24,
                     ckpt_dir=str(tmp_path), global_batch=4, seq_len=32,
                     ckpt_every=8, fail_at_step=10)
    assert last == 23
