"""Fused restoration data path (DESIGN.md §13).

Covers: fused-vs-legacy cache equivalence (bit-exact for quant="none",
within the documented tolerance for int8) with byte-identical store
accounting; strictly fewer copy dispatches on the fused path; the
double-buffered transfer stream's depth bound, backpressure and
serial-equivalence (depth=1 ≡ depth=2 caches); the int8 shadow keeping
demote/promote cycles drift-free; channel→device routing through the
sharding mesh helper; engine-level serving through the fused path with
verification and bit-identical trace replay."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.baselines import make_baseline_plans
from repro.core.datapath import RestoreDatapath, TransferStream
from repro.core.executor import RestorationExecutor
from repro.core.trace import TraceRecorder, replay_trace
from repro.models import build_model
from repro.serving import ChunkStore, RealServingEngine, Request

RNG = jax.random.PRNGKey(0)

_MODEL = {}


def _model():
    if not _MODEL:
        cfg = get_config("qwen3-8b").reduced()
        m = build_model(cfg)
        _MODEL.update(cfg=cfg, model=m, params=m.init(RNG))
    return _MODEL


def _executor(*, datapath, quant="none", store_chunk=8, tier="host",
              depth=2, stages=1):
    mm = _model()
    store = ChunkStore(chunk_size=store_chunk, quant=quant,
                      default_tier=tier)
    dp = RestoreDatapath.for_channels(1, depth=depth) if datapath else None
    ex = RestorationExecutor(mm["model"], mm["params"], chunk_size=16,
                             stages=stages, chunk_store=store, datapath=dp)
    return ex, store


def _restore(ex, rid="r", n=40, op_order="alternate", rng=None):
    plans = make_baseline_plans("lmcache", rid, n, chunk_size=16, l_delta=0,
                                num_layers=_model()["cfg"].num_layers)
    return ex.restore(rid, plans=plans, op_order=op_order, rng=rng)


def _remember(ex, rid="r", n=40):
    inputs = jax.random.randint(jax.random.fold_in(RNG, n), (1, n), 0,
                                _model()["cfg"].vocab_size)
    ex.remember(rid, inputs)


# ---------------------------------------------------------------------------
# Fused vs legacy: caches and accounting
# ---------------------------------------------------------------------------


def test_fused_bit_identical_to_legacy_and_reference():
    """quant="none": the fused packed-staging + scatter path restores the
    exact bits of both the legacy per-chunk path and the full-prefill
    reference, with byte-identical store accounting and strictly fewer
    dispatched copy ops."""
    exL, stL = _executor(datapath=False)
    _remember(exL)
    cacheL = _restore(exL)
    exF, stF = _executor(datapath=True)
    _remember(exF)
    cacheF = _restore(exF)
    for f in cacheL:
        np.testing.assert_array_equal(np.asarray(cacheL[f]),
                                      np.asarray(cacheF[f]))
    exF.verify("r")                      # strict vs kv_reference
    assert exF.fused_loads > 0 and exF.legacy_loads == 0
    assert exL.fused_loads == 0
    # accounting parity: same bytes, fetches, hits either way
    assert stF.bytes_transferred == stL.bytes_transferred > 0
    assert stF.fetches == stL.fetches
    assert stF.io_hits == stL.io_hits
    assert stF.store_misses == stL.store_misses == 0
    # the tentpole perf claim at op granularity
    assert exF.load_dispatches < exL.load_dispatches
    stF.audit(), stL.audit()


def test_fused_int8_within_tolerance_and_half_bytes():
    """int8 chunks cross the wire quantized (scales ride along) and the
    kernel dequantizes on device: restored cache within quant_tolerance,
    wire bytes ≈ the quantized encoding (about half of fp16)."""
    exQ, stQ = _executor(datapath=True, quant="int8")
    _remember(exQ)
    _restore(exQ)
    exQ.verify("r", atol=2e-2 + stQ.quant_tolerance())
    exN, stN = _executor(datapath=True)
    _remember(exN)
    _restore(exN)
    itemsize = np.dtype(_model()["model"].compute_dtype).itemsize
    fp16_equiv = stN.bytes_transferred * 2 / itemsize
    assert 0.4 < stQ.bytes_transferred / fp16_equiv < 0.75
    # legacy int8 moves the same bytes (the decode point moved, not the
    # wire format)
    exQL, stQL = _executor(datapath=False, quant="int8")
    _remember(exQL)
    _restore(exQL)
    assert stQL.bytes_transferred == stQ.bytes_transferred
    stQ.audit()


def test_fused_random_interleavings_match_reference():
    """Property: fused restoration is correct under ANY legal op
    interleaving (mixed compute/load claims), same as the legacy path."""
    ex, store = _executor(datapath=True)
    _remember(ex, n=56)
    for seed in range(3):
        if ex.is_live("r"):
            ex.drop_restore("r")
        plans = make_baseline_plans("cacheflow", "r", 56, chunk_size=16,
                                    l_delta=32,
                                    num_layers=_model()["cfg"].num_layers)
        ex.restore("r", plans=plans, op_order="random",
                   rng=np.random.default_rng(seed))
        ex.verify("r")


def test_fused_resident_rerun_is_device_local():
    """A second restoration of the same prefix finds every chunk HBM-
    resident: the fused path copies out of the pool views (io hits, no
    wire bytes, no staging puts)."""
    ex, store = _executor(datapath=True)
    _remember(ex)
    _restore(ex)
    b0, p0 = store.bytes_transferred, sum(s.puts for s in ex.datapath.streams)
    ex.drop_restore("r")
    _restore(ex)
    ex.verify("r")
    assert store.bytes_transferred == b0          # nothing crossed the wire
    assert sum(s.puts for s in ex.datapath.streams) == p0
    assert ex.datapath.resident_copies > 0
    assert store.io_hits > 0


# ---------------------------------------------------------------------------
# Transfer stream: depth bound, backpressure, serial equivalence
# ---------------------------------------------------------------------------


def test_transfer_stream_depth_bound():
    s = TransferStream(depth=2)
    for i in range(5):
        s.put({"x": np.full((4, 4), i, np.float32)})
        assert len(s._inflight) <= 2
    assert s.puts == 5
    assert s.bytes_staged == 5 * 4 * 4 * 4
    s.sync()
    assert not s._inflight


def test_double_buffered_pipeline_matches_serial():
    """Overlap test: depth=2 (op k+1's host→device copy in flight under
    op k's scatter) produces caches bit-identical to the fully serial
    depth=1 stream."""
    ex1, _ = _executor(datapath=True, depth=1)
    _remember(ex1, n=64)
    c1 = _restore(ex1, n=64)
    ex2, _ = _executor(datapath=True, depth=2)
    _remember(ex2, n=64)
    c2 = _restore(ex2, n=64)
    for f in c1:
        np.testing.assert_array_equal(np.asarray(c1[f]), np.asarray(c2[f]))
    ex2.verify("r")


# ---------------------------------------------------------------------------
# int8 shadow: same-precision tier moves keep the quantized payload
# ---------------------------------------------------------------------------


def test_promote_keeps_int8_shadow_no_requant_drift():
    """Promote→demote cycles of a quantized chunk must reuse the
    authoritative int8 encoding (shadowed across the promote) instead of
    requantizing the decoded bf16 view — payload stays bit-stable over
    arbitrarily many cycles."""
    ex, store = _executor(datapath=True, quant="int8")
    _remember(ex)
    key = store.requests["r"][0]
    ref = {f: np.array(store._host_payload(key)[f]["q"])
           for f in store.chunks[key].fields}
    for _ in range(3):
        got = store.fetch_packed(key)           # promotes via fused path?
        if got[0] != "hbm":
            # land it on device the way the datapath would
            dev = store._decode_device(key)
            store.promote_staged(key, dev)
        assert store.core.tier_of(key) == "hbm"
        assert "host" in store.chunks[key].reprs      # the shadow
        store.core.put(key, "host")                   # demote back
        pay = store._host_payload(key)
        for f, q in ref.items():
            np.testing.assert_array_equal(np.asarray(pay[f]["q"]), q)
    store.audit()


def test_quant_none_promote_drops_stale_reprs():
    """Without quantization there is no shadow: tier moves keep exactly
    one authoritative repr (memory hygiene regression guard)."""
    ex, store = _executor(datapath=True, quant="none")
    _remember(ex)
    key = store.requests["r"][0]
    store.fetch(key)
    assert set(store.chunks[key].reprs) == {"hbm"}
    store.core.put(key, "host")
    assert set(store.chunks[key].reprs) == {"host"}


# ---------------------------------------------------------------------------
# Channel → device routing
# ---------------------------------------------------------------------------


def test_io_channel_devices_and_stream_routing():
    from repro.distributed.sharding import io_channel_devices
    devs = io_channel_devices(None, 3)
    assert len(devs) == 3 and all(d is not None for d in devs)
    dp = RestoreDatapath.for_channels(3)
    assert len(dp.streams) == 3
    assert all(s.device is not None for s in dp.streams)
    assert dp.stream_for(0) is dp.streams[0]
    assert dp.stream_for(4) is dp.streams[1]      # modulo wrap


def test_engine_channel_hint_reaches_executor():
    from repro.core.engine_core import RealBackend
    ex, _ = _executor(datapath=True)
    backend = RealBackend(ex)
    assert ex.datapath.measure is True            # measured mode
    backend.io_channel_hint(1)
    assert ex.io_channel == 1


# ---------------------------------------------------------------------------
# Engine-level serving + trace replay
# ---------------------------------------------------------------------------


def _engine(store, **kw):
    mm = _model()
    return RealServingEngine(mm["model"], mm["params"],
                             system=kw.pop("system", "cacheflow"),
                             stages=kw.pop("stages", 2), chunk_size=8,
                             kvstore=store, **kw)


@pytest.mark.parametrize("quant", ["none", "int8"])
def test_engine_serve_fused_verified(quant):
    """End-to-end: multi-request serving through the fused datapath in
    MEASURED mode (datapath wall secs feed RealBackend.io_secs) passes
    per-request cache verification, measures per-channel bandwidth, and
    matches legacy-path store accounting.  The parity engines run the
    load-only baseline: under cacheflow's two-pointer race WHICH chunks
    load is schedule-dependent (fused and legacy time differently), the
    wrong substrate for byte assertions — see benchmarks/fork.py."""
    store = ChunkStore(chunk_size=8, quant=quant, default_tier="host")
    eng = _engine(store, system="lmcache", datapath="fused", io_channels=2)
    reqs = [Request(f"r{i}", 0.0, 24 + 16 * i, 8, decode_len=2)
            for i in range(3)]
    rep = eng.serve(reqs, verify=True)
    assert eng.executor.fused_loads > 0
    assert all(v > 0 for v in rep.ttfts.values())
    # measured per-channel bandwidth is now an observable
    assert any(b is not None and b > 0 for b in eng.datapath.bandwidths())
    store2 = ChunkStore(chunk_size=8, quant=quant, default_tier="host")
    eng2 = _engine(store2, system="lmcache", datapath="legacy",
                   io_channels=2)
    eng2.serve([Request(f"r{i}", 0.0, 24 + 16 * i, 8, decode_len=2)
                for i in range(3)], verify=True)
    assert eng2.datapath is None and eng2.executor.fused_loads == 0
    assert store.bytes_transferred == store2.bytes_transferred
    assert store.fetches == store2.fetches
    store.audit(), store2.audit()


def test_fused_trace_replays_bit_identically():
    """Scheduler decisions are datapath-independent: a trace captured
    through the fused engine replays bit-identically on the analytic
    replay core (schema v5 unchanged)."""
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _engine(store, datapath="fused")
    rec = TraceRecorder()
    eng.serve([Request("a", 0.0, 40, 8, decode_len=2),
               Request("b", 0.1, 24, 8, decode_len=2)],
              verify=True, op_order="random",
              rng=np.random.default_rng(0), trace=rec)
    assert replay_trace(rec.trace) == rec.trace.captured_result()
