"""CacheFlow sanitizer, trace linter and codelint (DESIGN.md §14).

Three layers of self-test:

  * **Fuzz**: randomized mixed interleavings (preempt + evict + prefetch +
    channel failure + fork-style CoW) run under ``sanitize=True`` — the
    sanitizer must stay silent on correct engine behavior, and every
    captured trace must lint clean.
  * **Mutation**: for every sanitizer invariant class, every trace-lint
    rule and every codelint rule, a deliberately broken input must trigger
    exactly that detector (a checker that can't fail its mutant is dead
    code).
  * **Regression**: the PlacementCore demote-cascade double-count (a
    bottom-tier drop previously counted as a demotion AND a drop) and the
    sanitized serving report plumbing.
"""
import copy

import numpy as np
import pytest

from _engine_helpers import RngBackend
from _hypothesis_compat import given, settings, st

from repro.analysis.codelint import (check_at_set_loops,
                                     check_kernel_oracles,
                                     check_trace_kinds, check_unseeded_rng,
                                     run_all)
from repro.analysis.sanitizer import EngineSanitizer, SanitizerViolation
from repro.analysis.trace_lint import ALL_RULES, lint_trace
from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core import EngineCore, EngineRequest
from repro.core.baselines import make_baseline_plans
from repro.core.trace import ScheduleTrace, TraceEvent, TraceRecorder
from repro.serving import Request, SimServingEngine, TieredKVStore
from repro.storage import PlacementCore, Tier


# ---------------------------------------------------------------------------
# Direct-hook harness for the runtime sanitizer
# ---------------------------------------------------------------------------


class _Op:
    """Minimal op stub: the sanitizer hooks only read these fields."""

    def __init__(self, kind, rid, stage=0, unit=0):
        self.kind = kind
        self.request_id = rid
        self.stage = stage
        self.unit = unit


class _Core:
    def __init__(self, max_active=0, kvstore=None):
        self.max_active = max_active
        self.kvstore = kvstore


def _san(max_active=0, kvstore=None):
    san = EngineSanitizer(_Core(max_active=max_active, kvstore=kvstore))
    san.bind(ops_log=[], busy_comp={0: 0.0}, busy_io={0: 0.0})
    return san


def _mk_req(rid, n=32, **kw):
    plans = make_baseline_plans("cacheflow", rid, n, chunk_size=8,
                                l_delta=0, num_layers=4)
    return EngineRequest(rid, n, 0.0, plans, **kw)


def test_mutation_double_claim_both_pointers():
    san = _san()
    san.on_admit(0.0, _mk_req("r0"))
    san.on_dispatch(0.0, "comp0", _Op("compute", "r0", 0, 2), 1.0)
    with pytest.raises(SanitizerViolation, match="double-claim"):
        san.on_dispatch(0.0, "io0", _Op("load", "r0", 0, 2), 1.0)


def test_mutation_channel_double_occupancy():
    san = _san()
    san.on_admit(0.0, _mk_req("r0"))
    san.on_dispatch(0.0, "comp0", _Op("compute", "r0", 0, 0), 1.0)
    with pytest.raises(SanitizerViolation, match="channel-occupancy"):
        san.on_dispatch(0.0, "comp0", _Op("compute", "r0", 0, 1), 1.0)


def test_mutation_double_restore():
    san = _san()
    san.on_admit(0.0, _mk_req("r0"))
    op = _Op("load", "r0", 0, 3)
    san.on_dispatch(0.0, "io0", op, 1.0)
    san.on_complete(1.0, "io0", op)
    with pytest.raises(SanitizerViolation, match="double-restore"):
        san.on_dispatch(1.0, "io0", _Op("load", "r0", 0, 3), 1.0)


def test_mutation_inexact_completion_time():
    san = _san()
    san.on_admit(0.0, _mk_req("r0"))
    op = _Op("load", "r0", 0, 3)
    san.on_dispatch(0.0, "io0", op, 1.0)
    with pytest.raises(SanitizerViolation, match="completion-time"):
        san.on_complete(1.0 + 1e-12, "io0", op)


def test_mutation_virtual_time_regression():
    san = _san()
    san.on_event(2.0, "comp_done")
    with pytest.raises(SanitizerViolation, match="time-monotonic"):
        san.on_event(1.5, "io_done")


def test_mutation_negative_duration_and_inactive_dispatch():
    san = _san()
    san.on_admit(0.0, _mk_req("r0"))
    with pytest.raises(SanitizerViolation, match="negative-duration"):
        san.on_dispatch(0.0, "io0", _Op("load", "r0", 0, 3), -0.5)
    san = _san()
    with pytest.raises(SanitizerViolation, match="inactive-dispatch"):
        san.on_dispatch(0.0, "io0", _Op("load", "ghost", 0, 3), 0.5)


def test_mutation_slot_overflow_and_double_admit():
    san = _san(max_active=1)
    san.on_admit(0.0, _mk_req("r0"))
    with pytest.raises(SanitizerViolation, match="slot-overflow"):
        san.on_admit(0.0, _mk_req("r1"))
    san = _san(max_active=4)
    san.on_admit(0.0, _mk_req("r0"))
    with pytest.raises(SanitizerViolation, match="slot-conservation"):
        san.on_admit(0.0, _mk_req("r0"))


def test_mutation_finish_and_resume_of_inactive():
    san = _san()
    with pytest.raises(SanitizerViolation, match="slot-conservation"):
        san.on_finish(0.0, "never-admitted")
    san = _san()
    with pytest.raises(SanitizerViolation, match="slot-conservation"):
        san.on_resume(0.0, "never-suspended")


def test_mutation_restore_incomplete():
    san = _san()
    req = _mk_req("r0", n=32)            # 4 units of 8 tokens
    san.on_admit(0.0, req)
    op = _Op("load", "r0", 0, 3)
    san.on_dispatch(0.0, "io0", op, 1.0)
    san.on_complete(1.0, "io0", op)
    with pytest.raises(SanitizerViolation, match="restore-incomplete"):
        san.on_restore_done(1.0, "r0")   # 3 units never completed


def test_mutation_rollback_drift_detected_at_run_end():
    san = _san()
    san.on_admit(0.0, _mk_req("r0"))
    op = _Op("load", "r0", 0, 3)
    san.on_dispatch(0.0, "io0", op, 1.0)
    san.on_complete(1.0, "io0", op)
    busy_comp, busy_io = san._engine_busy
    busy_io[0] += 0.25        # engine accounting drifts off the mirror
    with pytest.raises(SanitizerViolation, match="rollback-exact"):
        san.on_run_end(active=set(), pending=[], suspended=set())


def test_mutation_store_audit_drift():
    class _BadStore:
        def audit(self):
            raise AssertionError("host: used 512 != sum 256")

    san = _san(kvstore=_BadStore())
    with pytest.raises(SanitizerViolation, match="store-audit"):
        san.on_run_end(active=set(), pending=[], suspended=set())


def test_mutation_trace_schema_unregistered_kind():
    san = _san()
    with pytest.raises(SanitizerViolation, match="trace-schema"):
        san.on_trace_event(TraceEvent(kind="warp_core_breach", t=0.0))


# -- CoW parent-bytes check -------------------------------------------------


class _FakePool:
    """Dict-backed pool with a controllable copy(); mimics BlockPool's
    read/copy/refcounts surface."""

    def __init__(self, mutate_parent=False, diverge_copy=False):
        self._data = {0: {"k": np.arange(8.0)}}
        self.refcounts = [1]
        self.mutate_parent = mutate_parent
        self.diverge_copy = diverge_copy

    def read(self, bid):
        return self._data[bid]

    def copy(self, bid):
        new = max(self._data) + 1
        self._data[new] = {f: a.copy() for f, a in self._data[bid].items()}
        self.refcounts.append(1)
        if self.mutate_parent:
            self._data[bid]["k"][0] = 999.0
        if self.diverge_copy:
            self._data[new]["k"][1] = -999.0
        return new


class _PoolStore:
    def __init__(self, pool):
        self.pool = pool

    def audit(self):
        pass


def test_mutation_cow_parent_mutated():
    san = _san(kvstore=_PoolStore(_FakePool(mutate_parent=True)))
    with pytest.raises(SanitizerViolation, match="cow-parent-mutated"):
        san.core.kvstore.pool.copy(0)


def test_mutation_cow_copy_diverged():
    san = _san(kvstore=_PoolStore(_FakePool(diverge_copy=True)))
    with pytest.raises(SanitizerViolation, match="cow-copy-diverged"):
        san.core.kvstore.pool.copy(0)


def test_cow_check_passes_on_honest_pool_and_unwraps_at_run_end():
    pool = _FakePool()
    san = _san(kvstore=_PoolStore(pool))
    wrapped = pool.copy
    assert pool.copy(0) == 1             # wrapped, passes
    assert san.counters.cow_checks == 1
    san.on_run_end(active=set(), pending=[], suspended=set())
    assert pool.copy is not wrapped      # original restored


# ---------------------------------------------------------------------------
# Fuzz: mixed interleavings must sanitize silently and lint clean
# ---------------------------------------------------------------------------


class _FuzzBackend(RngBackend):
    def prefetch_secs(self, op, req, bandwidth):
        return float(self.rng.uniform(0.05, 1.0))

    def prefetch_gate(self, req):
        return True


@pytest.mark.property
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fuzz_sanitizer_silent_and_traces_lint_clean(seed):
    """Random preempt+evict+prefetch+channel-failure interleavings: the
    sanitizer must not fire on correct engine behavior, and the captured
    schedule must pass every offline lint rule."""
    rng = np.random.default_rng(seed)
    stages = int(rng.integers(1, 3))
    bounds = [(0, 2), (2, 4)] if stages == 2 else None
    policy = ["none", "priority", "deadline"][int(rng.integers(0, 3))]
    evict = policy != "none" and bool(rng.integers(0, 2))
    prefetch = bool(rng.integers(0, 2))
    io_channels = int(rng.integers(1, 3))
    kvstore = TieredKVStore() if (prefetch or rng.integers(0, 2)) else None
    fail = ({int(rng.integers(0, io_channels)): float(rng.uniform(0.5, 3.0))}
            if int(rng.integers(0, 3)) == 0 else None)
    reqs = []
    for i in range(int(rng.integers(3, 8))):
        n = int(rng.integers(16, 160))
        plans = make_baseline_plans("cacheflow", f"r{i}", n, chunk_size=8,
                                    l_delta=0, num_layers=4,
                                    stage_bounds=bounds)
        reqs.append(EngineRequest(
            f"r{i}", n, arrival=float(rng.uniform(0, 3.0)), plans=plans,
            new_len=int(rng.integers(0, 3)) * 16,
            decode_len=int(rng.integers(0, 5)),
            priority=int(rng.integers(0, 3)),
            deadline=float(rng.uniform(0.5, 20.0))))
        if kvstore is not None:
            kvstore.put(f"r{i}", n * 1024, tier="remote")
    rec = TraceRecorder()
    core = EngineCore(_FuzzBackend(seed), stages=stages,
                      io_channels=io_channels,
                      max_active=int(rng.integers(1, 4)),
                      preempt=policy, evict=evict, prefetch=prefetch,
                      kvstore=kvstore, channel_fail_at=fail,
                      sanitize=True, strict=True)
    core.run(reqs, trace=rec)
    san = core.last_sanitizer
    assert san is not None
    assert san.counters.admits >= len(reqs)
    assert san.counters.finishes == len(reqs)
    # hard invariants only: the starvation rule is an advisory heuristic
    # and adversarial workloads (channel failure + max_active=1) can
    # legitimately stall one request for over half the span
    findings = lint_trace(rec.trace,
                          rules=[r for r in ALL_RULES if r != "starvation"])
    assert not findings, [str(f) for f in findings[:5]]


# ---------------------------------------------------------------------------
# Trace linter: clean baseline + one mutant per rule
# ---------------------------------------------------------------------------


def _base_trace():
    reqs = [_mk_req(f"r{i}", n=32 + 16 * i, new_len=16, decode_len=2,
                    priority=i % 2)
            for i in range(4)]
    rec = TraceRecorder()
    EngineCore(RngBackend(11), stages=1, io_channels=2, max_active=2,
               preempt="priority", strict=True).run(reqs, trace=rec)
    return rec.trace


BASE = _base_trace()


def _mutant():
    return copy.deepcopy(BASE)


def _rules(findings):
    return {f.rule for f in findings}


def test_lint_base_trace_clean_and_roundtrips():
    assert lint_trace(BASE) == []
    # dict round-trip (what the CLI loads) is equally clean
    t = ScheduleTrace.from_dict(BASE.to_dict())
    assert lint_trace(t, raw_version=BASE.version) == []


def test_lint_mutation_schema_unknown_kind_and_missing_field():
    t = _mutant()
    next(e for e in t.events if e.kind == "admit").kind = "warp"
    assert "schema" in _rules(lint_trace(t))
    t = _mutant()
    next(e for e in t.events if e.kind == "dispatch").op = None
    assert "schema" in _rules(lint_trace(t))


def test_lint_mutation_schema_version_aware():
    t = _mutant()
    # a v3 event kind inside a trace claiming schema v1
    assert "schema" in _rules(lint_trace(t, raw_version=1))
    assert "schema" not in _rules(lint_trace(t, raw_version=5))


def test_lint_mutation_causality_time_regression():
    t = _mutant()
    t.events[len(t.events) // 2].t = -1.0
    assert "causality" in _rules(lint_trace(t))


def test_lint_mutation_causality_wrong_completion_time():
    t = _mutant()
    ev = next(e for e in t.events
              if e.kind == "complete" and e.op["kind"] in ("compute", "load"))
    ev.t += 1e-9
    assert "causality" in _rules(lint_trace(t))


def test_lint_mutation_channel_overlap():
    t = _mutant()
    d = next(e for e in t.events if e.kind == "dispatch")
    dup = copy.deepcopy(d)
    dup.op = dict(dup.op)
    t.events.insert(t.events.index(d) + 1, dup)
    assert "channel-overlap" in _rules(lint_trace(t))


def test_lint_mutation_slot_leak_dropped_finish():
    t = _mutant()
    fin = next(e for e in t.events if e.kind == "finish")
    t.events.remove(fin)
    assert "slot-leak" in _rules(lint_trace(t))


def test_lint_mutation_restored_twice():
    t = _mutant()
    ev = next(e for e in t.events
              if e.kind == "complete" and e.op["kind"] in ("compute", "load"))
    d = copy.deepcopy(next(e for e in t.events if e.kind == "dispatch"
                           and e.op == ev.op))
    c = copy.deepcopy(ev)
    i = t.events.index(ev) + 1
    d.t = c.t = t.events[i].t if i < len(t.events) else ev.t
    d.duration = 0.0
    t.events[i:i] = [d, c]
    assert "causality" in _rules(lint_trace(t))


# -- hand-crafted traces for gate-inversion / starvation / prefetch-race ----


def _plan_d(rid, n_tokens, stage=0):
    return {"request_id": rid, "n_tokens": n_tokens, "chunk_size": 8,
            "strategy": "token", "layer_lo": 0, "layer_hi": 4,
            "stage": stage, "comp_enabled": True, "io_enabled": True}


def _op_d(kind, rid, unit, stage=0):
    return {"kind": kind, "request_id": rid, "stage": stage, "unit": unit,
            "tokens": [0, 8], "layers": [0, 4]}


def _craft(events, requests, meta=None):
    base = {"max_active": 4, "evict": False,
            "io_policy": "longest_remaining", "stage_parallel": True}
    base.update(meta or {})
    return ScheduleTrace(meta=base, requests=requests,
                         events=[TraceEvent(**e) for e in events])


def test_lint_gate_inversion_skipped_better_candidate():
    reqs = [{"request_id": "big", "plans": [_plan_d("big", 64)]},
            {"request_id": "small", "plans": [_plan_d("small", 16)]}]
    ev = [dict(kind="admit", t=0.0, request_id="big"),
          dict(kind="admit", t=0.0, request_id="small"),
          # "small" (1 unit remaining fewer tokens, admitted later) loads
          # while "big" — strictly better under longest_remaining — was
          # never gated this pass: inversion
          dict(kind="dispatch", t=0.0, resource="io0",
               op=_op_d("load", "small", 1), duration=1.0)]
    assert "gate-inversion" in _rules(lint_trace(_craft(ev, reqs)))
    # a recorded gate=False for "big" justifies the skip
    ev_ok = ev[:2] + [dict(kind="gate", t=0.0, request_id="big", stage=0,
                           unit=7, allowed=False)] + ev[2:]
    assert lint_trace(_craft(ev_ok, reqs)) == []
    # gate=True AND skipped => benefit-gate inversion
    ev_bad = ev[:2] + [dict(kind="gate", t=0.0, request_id="big", stage=0,
                            unit=7, allowed=True)] + ev[2:]
    assert "gate-inversion" in _rules(lint_trace(_craft(ev_bad, reqs)))


def test_lint_starvation_window():
    reqs = [{"request_id": "fed", "plans": [_plan_d("fed", 64)]},
            {"request_id": "starved", "plans": [_plan_d("starved", 64)]}]
    ev = [dict(kind="admit", t=0.0, request_id="fed"),
          dict(kind="admit", t=0.0, request_id="starved")]
    t = 0.0
    for u in range(7, 1, -1):      # "fed" gets every dispatch for 6 units
        ev.append(dict(kind="dispatch", t=t, resource="io0",
                       op=_op_d("load", "fed", u), duration=2.0))
        t += 2.0
        ev.append(dict(kind="complete", t=t, resource="io0",
                       op=_op_d("load", "fed", u)))
    trace = _craft(ev, reqs)
    assert "starvation" in _rules(lint_trace(trace, starvation_bound=3.0,
                                             rules=["starvation"]))
    assert lint_trace(trace, starvation_bound=100.0,
                      rules=["starvation"]) == []


def test_lint_prefetch_race_misaccounting():
    reqs = [{"request_id": "q", "plans": [_plan_d("q", 16)]}]
    pf = _op_d("prefetch", "q", 0, stage=-1)
    race = [dict(kind="prefetch_gate", t=0.0, request_id="q", allowed=True),
            dict(kind="dispatch", t=0.0, resource="io0", op=pf,
                 duration=5.0),
            # admitted mid-prefetch with NO abort recorded, and the
            # transfer then "completes" anyway: the race the engine's
            # cancel-at-admit path must make impossible
            dict(kind="admit", t=2.0, request_id="q"),
            dict(kind="complete", t=5.0, resource="io0", op=dict(pf))]
    assert "prefetch-race" in _rules(lint_trace(_craft(race, reqs)))
    ok = [race[0], race[1],
          dict(kind="abort", t=2.0, resource="io0", op=dict(pf)),
          dict(kind="admit", t=2.0, request_id="q")]
    assert "prefetch-race" not in _rules(lint_trace(_craft(ok, reqs)))
    # a prefetch dispatched without a passing gate is also a race bug
    nogate = [dict(kind="dispatch", t=0.0, resource="io0", op=dict(pf),
                   duration=5.0)]
    assert "prefetch-race" in _rules(lint_trace(_craft(nogate, reqs)))


def test_golden_traces_lint_clean():
    """Every captured trace committed under tests/data/ stays lint-clean
    (and exercises the file-loading path the CLI uses, including raw
    schema-version extraction)."""
    from repro.analysis.trace_lint import lint_trace_file
    data = _repo_root() / "tests" / "data"
    traces = sorted(data.glob("*trace*.json"))
    assert traces, "no golden traces committed under tests/data/"
    for p in traces:
        findings = lint_trace_file(p)
        assert not findings, (p.name, [str(f) for f in findings[:5]])


def test_lint_cli_exit_codes(tmp_path):
    from repro.analysis.lint_trace import main
    golden = sorted((_repo_root() / "tests" / "data").glob("*trace*.json"))
    assert main([str(golden[0])]) == 0
    import json
    d = json.loads(golden[0].read_text())
    d["events"][3]["kind"] = "warp"
    bad = tmp_path / "bad_trace.json"
    bad.write_text(json.dumps(d))
    assert main([str(bad)]) == 1
    assert main([str(tmp_path / "missing.json")]) == 2


# ---------------------------------------------------------------------------
# codelint: repo is clean; one mutant per rule
# ---------------------------------------------------------------------------


def _repo_root():
    # repro is a namespace package (__file__ is None); anchor on a real one
    import repro.analysis
    from pathlib import Path
    return Path(repro.analysis.__file__).resolve().parents[3]


def test_codelint_repo_is_clean():
    assert run_all(_repo_root()) == []


def test_codelint_mutation_at_set_loop(tmp_path):
    bad = tmp_path / "hot.py"
    bad.write_text("for i in range(4):\n"
                   "    cache = cache.at[i].set(x)\n")
    findings = check_at_set_loops([bad])
    assert [f.rule for f in findings] == ["at-set-loop"]
    bad.write_text("for i in range(4):\n"
                   "    cache = cache.at[i].set(x)  "
                   "# codelint: allow(at-set-loop)\n")
    assert check_at_set_loops([bad]) == []
    # pragma on the loop header covers the whole loop
    bad.write_text("for i in range(4):  # codelint: allow(at-set-loop)\n"
                   "    cache = cache.at[i].set(x)\n")
    assert check_at_set_loops([bad]) == []
    # out of a loop: fine
    bad.write_text("cache = cache.at[0].set(x)\n")
    assert check_at_set_loops([bad]) == []


def test_codelint_mutation_unseeded_rng(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text("import time\nimport random\nimport numpy as np\n"
                   "a = time.time()\n"
                   "b = random.random()\n"
                   "c = np.random.default_rng()\n"
                   "d = np.random.normal()\n")
    rules = [f.rule for f in check_unseeded_rng([bad])]
    assert rules == ["unseeded-rng"] * 4
    ok = tmp_path / "ok.py"
    ok.write_text("import time\nimport numpy as np\n"
                  "a = time.perf_counter()\n"
                  "rng = np.random.default_rng(0)\n"
                  "b = rng.normal()\n")
    assert check_unseeded_rng([ok]) == []


def test_codelint_mutation_kernel_oracle(tmp_path):
    kdir = tmp_path / "kernels" / "myker"
    kdir.mkdir(parents=True)
    (kdir / "kernel.py").write_text("pass\n")
    tdir = tmp_path / "tests"
    tdir.mkdir()
    findings = check_kernel_oracles(tmp_path / "kernels", tdir)
    assert sorted(f.rule for f in findings) == ["kernel-oracle"] * 2
    (kdir / "ref.py").write_text("pass\n")
    (tdir / "test_k.py").write_text(
        "def test_myker_interpret_parity(): pass\n")
    assert check_kernel_oracles(tmp_path / "kernels", tdir) == []


def test_codelint_mutation_trace_kinds(tmp_path):
    tr = tmp_path / "trace.py"
    tr.write_text('EVENT_KINDS = {"admit": 1}\n'
                  'def record(self, t):\n'
                  '    self._ev(kind="admit", t=t)\n'
                  '    self._ev(kind="vanish", t=t)\n')
    findings = check_trace_kinds(tr)
    assert [f.rule for f in findings] == ["trace-kinds"]
    assert "vanish" in findings[0].message
    tr.write_text('EVENT_KINDS = {"admit": 1}\n'
                  'def scan(e):\n'
                  '    return e.kind == "ghost"\n')
    assert [f.rule for f in check_trace_kinds(tr)] == ["trace-kinds"]


# ---------------------------------------------------------------------------
# Satellites: placement accounting fix + serving report plumbing
# ---------------------------------------------------------------------------


def test_placement_drop_from_bottom_is_not_a_demotion():
    core = PlacementCore([Tier("only", 1e9, 100)])
    core.put("a", "only", nbytes=80)
    core.put("b", "only", nbytes=80)   # evicts a -> falls off the bottom
    assert core.drops == 1
    assert core.demotions == 0         # previously double-counted
    core.audit()


def test_placement_demote_cascade_counts_each_landing_once():
    core = PlacementCore([Tier("top", 1e9, 100), Tier("bot", 1e8, 100)])
    core.put("a", "top", nbytes=80)
    core.put("b", "top", nbytes=80)    # a demotes to bot (lands)
    assert (core.demotions, core.drops) == (1, 0)
    core.put("c", "top", nbytes=80)    # b demotes, evicting a off the bottom
    assert (core.demotions, core.drops) == (2, 1)
    core.audit()


def test_serving_report_carries_sanitizer_counters(monkeypatch):
    # isolate from the ambient env (CI runs some suites with
    # CACHEFLOW_SANITIZE=1): this test pins the explicit-kwarg behavior
    monkeypatch.delenv("CACHEFLOW_SANITIZE", raising=False)
    cfg = get_config("qwen3-8b")
    reqs = [Request(f"r{i}", 0.2 * i, prefix_len=4096, new_len=128,
                    decode_len=2) for i in range(3)]
    eng = SimServingEngine(cfg, HARDWARE["h100"],
                           io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                           stages=2, max_batch=2, sanitize=True)
    rep = eng.run(reqs)
    assert rep.sanitizer is not None
    assert rep.sanitizer["admits"] == 3
    assert rep.sanitizer["finishes"] == 3
    assert rep.sanitizer["max_active"] <= 2
    # off by default: no counters attached, no sanitizer constructed
    rep2 = SimServingEngine(cfg, HARDWARE["h100"],
                            io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                            stages=2, max_batch=2).run(
        [Request("s0", 0.0, prefix_len=4096, new_len=128, decode_len=2)])
    assert rep2.sanitizer is None


def test_engine_env_var_opt_in(monkeypatch):
    monkeypatch.setenv("CACHEFLOW_SANITIZE", "1")
    core = EngineCore(RngBackend(3), stages=1, io_channels=1)
    assert core.sanitize
    core.run([_mk_req("r0")])
    assert core.last_sanitizer is not None
    assert core.last_sanitizer.counters.finishes == 1
    monkeypatch.setenv("CACHEFLOW_SANITIZE", "0")
    assert not EngineCore(RngBackend(3), stages=1, io_channels=1).sanitize
