"""Hypothesis shim for offline environments.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt). When it
is installed the real library is re-exported unchanged; when it is missing we
fall back to a minimal deterministic sampler implementing just the strategy
surface these tests use, so property tests still *run* (as seeded random
sampling) instead of aborting collection for the whole suite.

Tests using this module should also carry ``@pytest.mark.property`` so they
can be deselected wholesale with ``-m "not property"``.
"""
from __future__ import annotations

import functools
import inspect
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_CAP = 50          # bound sampling time offline

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.example(rng)
                for _ in range(int(rng.integers(min_size, max_size + 1)))])

    st = _Strategies()

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = min(getattr(run, "_max_examples", 20), _FALLBACK_CAP)
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = np.random.default_rng((base + i) % 2**31)
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # pytest must see a zero-arg signature, not the sampled params
            # (they would otherwise be collected as fixtures)
            del run.__wrapped__
            run.__signature__ = inspect.Signature()
            run.is_hypothesis_fallback = True
            return run
        return deco

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
