"""Shared engine-core test backends (imported by lifecycle/preemption tests)."""
import numpy as np

from repro.core import EngineBackend


class RngBackend(EngineBackend):
    """Random op durations: completion order (and hence every subsequent
    scheduling decision) is scrambled across the whole lifecycle."""

    def __init__(self, seed):
        self.rng = np.random.default_rng(seed)

    def compute_secs(self, op, req):
        return float(self.rng.uniform(0.05, 1.0))

    def io_secs(self, op, req, bandwidth):
        return float(self.rng.uniform(0.05, 1.0))

    def prefill_secs(self, op, req):
        return float(self.rng.uniform(0.05, 1.0))

    def decode_secs(self, reqs):
        return float(self.rng.uniform(0.01, 0.3))
