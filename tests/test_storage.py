"""Materialized chunk-granular KV storage subsystem (DESIGN.md §10).

Covers: the shared placement core's demotion cascade (regression for the
historical ``TieredKVStore._evict_for`` over-fill/silent-drop), dedup
refcount + bytes-conservation invariants under randomized op sequences,
quantize/dequantize round trips through the tiers, real-mode restoration
served from actual stored chunk bytes (bit-matching the full-prefill
reference un-quantized, within the documented tolerance with int8),
residency-based transfer skipping for dedup hits, and eviction-mode
preemption (drop + restart from the store)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.trace import TraceRecorder, replay_trace
from repro.models import build_model
from repro.serving import (ChunkStore, RealServingEngine, Request,
                           SimServingEngine, TieredKVStore)
from repro.storage import PlacementCore, Tier, chunk_hash_chain
from repro.config import HARDWARE, IO_BANDWIDTHS

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Placement core: cascading demotion (satellite regression)
# ---------------------------------------------------------------------------


def test_evict_cascade_when_tier_below_full():
    """Demoting out of a full tier into another full tier must cascade,
    never over-fill: the historical _evict_for stopped at one level."""
    st_ = TieredKVStore(hbm_cap=100, host_cap=100, remote_cap=100,
                        hbm_bw=800e9, host_bw=100e9, remote_bw=1e9)
    st_.put("a", 90, tier="hbm")
    st_.put("b", 90, tier="host")
    st_.put("c", 90, tier="remote")
    st_.put("d", 90, tier="hbm")     # a->host forces b->remote forces c off
    assert st_.tier_of("d") == "hbm"
    assert st_.tier_of("a") == "host"
    assert st_.tier_of("b") == "remote"
    assert st_.tier_of("c") is None            # dropped, counted — not silent
    assert st_.core.drops == 1
    for t in st_.tiers.values():
        assert t.used <= t.capacity
    st_.core.audit()


def test_oversized_entry_skips_tier_instead_of_overfilling():
    """An entry larger than a tier's whole capacity must not evict that
    tier to zero and then over-fill it; it belongs in the first tier that
    can hold it."""
    st_ = TieredKVStore(hbm_cap=100, host_cap=250, remote_cap=10_000)
    st_.put("small", 80, tier="hbm")
    st_.put("big", 300, tier="hbm")    # > hbm and > host capacity
    assert st_.tier_of("big") == "remote"
    assert st_.tier_of("small") == "hbm"       # untouched: no pointless evict
    for t in st_.tiers.values():
        assert t.used <= t.capacity
    st_.core.audit()


def test_placement_benefit_aware_eviction():
    """victim_fn orders eviction by benefit, not recency."""
    benefit = {"cheap": 1.0, "precious": 100.0, "newer": 50.0}
    core = PlacementCore([Tier("hot", 1e9, 200), Tier("cold", 1e6, 1000)],
                         victim_fn=lambda k: benefit[k])
    core.put("precious", "hot", nbytes=90)
    core.put("cheap", "hot", nbytes=90)
    core.put("newer", "hot", nbytes=90)        # someone must go
    # LRU would evict "precious" (oldest); benefit-aware evicts "cheap"
    assert core.tier_of("cheap") == "cold"
    assert core.tier_of("precious") == "hot"
    assert core.tier_of("newer") == "hot"
    core.audit()


@pytest.mark.property
@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_ops=st.integers(5, 60))
def test_placement_randomized_invariants(seed, n_ops):
    """Under random put/touch/promote/remove: per-tier byte accounting is
    conserved, no tier over capacity, placement map consistent."""
    rng = np.random.default_rng(seed)
    core = PlacementCore([Tier("a", 1e9, 500), Tier("b", 1e8, 800),
                          Tier("c", 1e6, 1200)])
    keys = [f"k{i}" for i in range(12)]
    for _ in range(n_ops):
        k = keys[rng.integers(len(keys))]
        op = rng.integers(4)
        if op == 0:
            core.put(k, ["a", "b", "c"][rng.integers(3)],
                     nbytes=int(rng.integers(10, 400)))
        elif op == 1:
            core.touch(k)
        elif op == 2:
            core.promote(k, ["a", "b"][rng.integers(2)])
        else:
            core.remove(k)
        core.audit()


# ---------------------------------------------------------------------------
# Chunk store: hashing, dedup, refcounts, quantized round trips
# ---------------------------------------------------------------------------


def _toy_cache(n_layers=2, n_tok=16, heads=2, dh=8, seed=0, dtype=jnp.bfloat16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {
        "k": jax.random.normal(ks[0], (n_layers, 1, n_tok, heads, dh), dtype),
        "v": jax.random.normal(ks[1], (n_layers, 1, n_tok, heads, dh), dtype),
        "kpos": jnp.tile(jnp.arange(n_tok, dtype=jnp.int32), (n_layers, 1)),
    }


def test_chunk_hash_chain_prefix_dependence():
    a = np.arange(16)[None]
    b = a.copy(); b[0, 0] = 99                  # differs in the FIRST chunk
    ka, kb = chunk_hash_chain(a, 4), chunk_hash_chain(b, 4)
    assert ka[0] != kb[0]
    # prefix chaining: EVERY later chunk key differs too (same tokens,
    # different prefix)
    assert all(x != y for x, y in zip(ka, kb))
    # identical prefixes share keys
    c = a.copy(); c[0, 15] = 99                 # differs only in the LAST chunk
    kc = chunk_hash_chain(c, 4)
    assert kc[:3] == ka[:3] and kc[3] != ka[3]


def test_chunkstore_dedup_single_copy_with_refcounts():
    cs = ChunkStore(chunk_size=4)
    cache = _toy_cache()
    cs.put_request("a", np.arange(16)[None], cache)
    bytes_once = cs.bytes_put
    cs.put_request("b", np.arange(16)[None], cache)
    assert cs.bytes_put == bytes_once           # one stored copy
    assert cs.dedup_hits == 4
    assert all(cs.chunks[k].refcount == 2 for k in cs.requests["a"])
    cs.free_request("a")
    assert all(cs.chunks[k].refcount == 1 for k in cs.requests["b"])
    cs.audit()


@pytest.mark.property
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_ops=st.integers(4, 30))
def test_chunkstore_randomized_put_evict_free_invariants(seed, n_ops):
    """Refcounts never go negative and tier byte accounting is conserved
    under randomized put/free/promote/touch sequences with tight tiers
    (forcing demotion cascades and bottom-tier drops)."""
    rng = np.random.default_rng(seed)
    cs = ChunkStore(chunk_size=4, hbm_cap=4096, host_cap=8192, disk_cap=16384,
                    quant="int8" if seed % 2 else "none")
    caches = {n: _toy_cache(seed=n) for n in range(3)}
    live = set()
    for i in range(n_ops):
        op = rng.integers(4)
        rid = f"r{rng.integers(6)}"
        if op == 0:
            n = int(rng.integers(3))
            cs.put_request(rid, (np.arange(16) + n)[None], caches[n],
                           tier=["hbm", "host", "disk"][rng.integers(3)])
            live.add(rid)
        elif op == 1 and rid in live:
            cs.free_request(rid)
            live.discard(rid)
        elif op == 2:
            cs.touch(rid)
        elif op == 3 and rid in live:
            for key in cs.requests[rid]:
                cs.fetch(key)
        cs.audit()
        assert all(c.refcount >= 0 for c in cs.chunks.values())


@pytest.mark.property
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_chunk_demote_quantize_promote_dequantize_round_trip(seed):
    """put -> demote to disk (quantize) -> promote/fetch (dequantize)
    stays within the store's documented int8 tolerance."""
    cs = ChunkStore(chunk_size=8, quant="int8")
    cache = _toy_cache(seed=seed)
    cs.put_request("r", np.arange(16)[None], cache, tier="disk")
    pays = cs.fetch_range("r", 0, 16)
    assert pays is not None and cs.bytes_transferred > 0
    tol = cs.quant_tolerance()
    assert 0 < tol < 0.5
    for c0, c1, pay in pays:
        for f in ("k", "v"):
            ref = np.asarray(cache[f][:, :, c0:c1], np.float32)
            got = np.asarray(pay[f], np.float32)
            assert np.max(np.abs(ref - got)) <= tol
        np.testing.assert_array_equal(np.asarray(pay["kpos"]),
                                      np.asarray(cache["kpos"][:, c0:c1]))
    cs.audit()


def test_int8_store_put_to_hbm_stays_exact_until_demotion():
    """Quantization applies on DEMOTION below HBM, never at put: a chunk
    placed straight into the hbm tier under quant="int8" serves bit-exact
    bytes; only once capacity pressure demotes it does the int8 form
    become authoritative."""
    cs = ChunkStore(chunk_size=8, quant="int8", hbm_cap=1 << 20)
    cache = _toy_cache()
    cs.put_request("r", np.arange(16)[None], cache, tier="hbm")
    pays = cs.fetch_range("r", 0, 16)
    assert cs.bytes_transferred == 0            # resident: nothing moved
    for c0, c1, pay in pays:
        for f in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(pay[f]), np.asarray(cache[f][:, :, c0:c1]))
    # force a demotion: now (and only now) the stored form is lossy
    for key in cs.requests["r"]:
        cs.core.put(key, "host")
    got = cs.fetch_range("r", 0, 8)[0][2]["k"]
    ref = np.asarray(cache["k"][:, :, 0:8], np.float32)
    err = np.max(np.abs(ref - np.asarray(got, np.float32)))
    assert 0 < err <= cs.quant_tolerance()
    cs.audit()


def test_chunkstore_unquantized_round_trip_bit_exact_through_disk(tmp_path):
    """quant="none" must round-trip every tier (including real .npz files
    under --store-dir) bit-exactly, bf16 included."""
    cs = ChunkStore(chunk_size=8, quant="none", store_dir=str(tmp_path))
    cache = _toy_cache()
    cs.put_request("r", np.arange(16)[None], cache, tier="disk")
    assert any(f.endswith(".npz") for f in os.listdir(tmp_path))
    pays = cs.fetch_range("r", 0, 16)
    for c0, c1, pay in pays:
        for f in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(pay[f]), np.asarray(cache[f][:, :, c0:c1]))
    cs.audit()


def test_chunkstore_benefit_eviction_prefers_low_benefit_chunks():
    """Under HBM pressure the evicted chunk is the one with the least
    recompute benefit per byte: early-prefix chunks (cheap to recompute)
    demote before late ones, refcount-0 chunks before referenced ones."""
    cache = _toy_cache(n_tok=16)
    raw_chunk = sum(np.asarray(cache[f][:, :, :4]).nbytes for f in ("k", "v"))
    raw_chunk += np.asarray(cache["kpos"][:, :4]).nbytes
    cs = ChunkStore(chunk_size=4, hbm_cap=raw_chunk * 3 + 1, host_cap=1 << 20)
    cs.put_request("r", np.arange(16)[None], cache, tier="hbm")  # 4 chunks, 3 fit
    keys = cs.requests["r"]
    tiers = [cs.core.tier_of(k) for k in keys]
    assert tiers.count("hbm") == 3
    # the demoted chunk is the EARLIEST (lowest t1^2 - t0^2 recompute saving)
    assert cs.core.tier_of(keys[0]) == "host"
    assert all(t == "hbm" for t in tiers[1:])


# ---------------------------------------------------------------------------
# Real-mode restoration served from the materialized store
# ---------------------------------------------------------------------------


def _real_engine(store, **kw):
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    return RealServingEngine(m, params, system=kw.pop("system", "cacheflow"),
                             stages=kw.pop("stages", 2), chunk_size=8,
                             kvstore=store, **kw)


def test_real_restore_from_store_bit_matches_reference():
    """Load-only restoration (every byte comes out of the store's tiers)
    must reproduce the full-prefill reference cache BIT-exactly when
    un-quantized; the executor's verify() (strict kpos + tight atol)
    passes and the store actually moved bytes."""
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _real_engine(store, system="lmcache")       # load-only baseline
    reqs = [Request("r0", 0.0, 32, 0, decode_len=0)]
    eng.serve(reqs, verify=True)
    ex = eng.executor
    live = ex.live_cache("r0")
    ref = ex.store.get("r0").kv_reference
    for f in ref:
        np.testing.assert_array_equal(np.asarray(live[f]), np.asarray(ref[f]),
                                      err_msg=f)
    assert store.fetches > 0 and store.bytes_transferred > 0


def test_real_restore_int8_within_documented_tolerance():
    store = ChunkStore(chunk_size=8, quant="int8", default_tier="host")
    eng = _real_engine(store, system="lmcache")
    reqs = [Request("r0", 0.0, 32, 0, decode_len=0)]
    eng.serve(reqs, verify=False)      # default verify atol is for exact mode
    ex = eng.executor
    tol = store.quant_tolerance()
    errs = ex.verify("r0", atol=tol)
    assert 0 < max(errs[f] for f in ("k", "v")) <= tol


def test_real_lifecycle_with_store_and_quant_finishes_verified():
    """Full cacheflow lifecycle (restore -> prefill -> decode) on the
    materialized store; compute+load mix under a randomized interleaving."""
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _real_engine(store)
    reqs = [Request("r0", 0.0, 32, 8, decode_len=2),
            Request("r1", 0.1, 24, 8, decode_len=2)]
    rep = eng.serve(reqs, verify=True, op_order="random",
                    rng=np.random.default_rng(1))
    assert set(rep.ttfts) == {"r0", "r1"}
    store.audit()


def test_dedup_hits_skip_transfers_and_reduce_bytes():
    """Two requests sharing an identical prefix: the second one's loads
    are served from the first's HBM-resident chunks — engine-level
    skipped transfers > 0 and no extra bytes move for the shared span."""
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _real_engine(store, system="lmcache", stages=1)
    # same prefix_len => identical inputs (engine rng reuse) => shared chunks
    eng.serve([Request("a", 0.0, 32, 0, decode_len=0)], verify=True)
    assert store.dedup_hits == 0
    bytes_first = store.bytes_transferred
    assert bytes_first > 0
    eng.serve([Request("b", 0.0, 32, 0, decode_len=0)], verify=True)
    assert store.dedup_hits == 4                # b's chunks deduped to a's
    assert store.skipped_transfers > 0          # engine skipped the channel
    assert store.bytes_transferred == bytes_first   # no new bytes moved
    # and b's cache is still bit-exact
    ex = eng.executor
    ref = ex.store.get("b").kv_reference
    live = ex.live_cache("b")
    for f in ref:
        np.testing.assert_array_equal(np.asarray(live[f]), np.asarray(ref[f]))


def test_sim_hbm_residency_skips_transfer_time():
    """Sim facade residency: prefixes starting in the hbm tier restore
    with zero I/O channel time (dedup/residency hit), strictly faster than
    host-tier starts."""
    cfg = get_config("qwen3-8b")

    def run(kv_tier, rec=None):
        store = TieredKVStore(remote_bw=IO_BANDWIDTHS["10Gbps"])
        eng = SimServingEngine(cfg, HARDWARE["h100"],
                               io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                               system="lmcache", stages=1, max_batch=4,
                               kvstore=store, kv_tier=kv_tier)
        reqs = [Request(f"r{i}", 0.0, 6000, 128, decode_len=4)
                for i in range(4)]
        return eng.run(reqs, trace=rec), store

    rec = TraceRecorder()
    rep_hbm, st_hbm = run("hbm", rec)
    rep_host, st_host = run("host")
    assert st_hbm.io_hits > 0 and st_host.io_hits == 0
    assert np.mean(list(rep_hbm.ttfts.values())) < \
        np.mean(list(rep_host.ttfts.values()))
    # a residency-hit schedule (zero-duration transfers) replays
    # bit-identically even though the replay core has no kvstore: the hit
    # is encoded purely as a pinned gate answer + 0-second dispatch
    assert replay_trace(rec.trace) == rec.trace.captured_result()


# ---------------------------------------------------------------------------
# Eviction-mode preemption: drop + restart from the store (ROADMAP item)
# ---------------------------------------------------------------------------


def test_evicted_then_restarted_request_finishes_verified():
    """preempt + evict: the victim's partially-restored cache is dropped,
    its plans reset, and after re-admission it restores FROM THE STORE and
    finishes with a verified cache and the right greedy tokens."""
    store = ChunkStore(chunk_size=8, quant="none", default_tier="host")
    eng = _real_engine(store, max_batch=1, preempt="priority", evict=True)
    reqs = [Request("bg", 0.0, 48, 8, decode_len=3, priority=0),
            Request("hi", 0.3, 16, 8, decode_len=3, priority=1),
            Request("bg2", 0.4, 40, 8, decode_len=3, priority=0)]
    rec = TraceRecorder()
    rep = eng.serve(reqs, verify=True, op_order="random",
                    rng=np.random.default_rng(3), trace=rec)
    assert sum(rep.preemptions.values()) > 0, "scenario produced no preemption"
    assert rec.trace.meta["evict"] is True
    for r in reqs:
        assert eng.executor.outputs(r.request_id)["tokens"], r.request_id
    # the evict-mode trace replays bit-identically (schema v4 meta)
    assert replay_trace(rec.trace) == rec.trace.captured_result()


def test_sim_evict_mode_matches_roadmap_semantics():
    """Sim engine: with evict=True the preempted victim restarts (strictly
    more total restoration work than park mode), yet everything finishes."""
    from repro.core.cost_model import CostModel
    from repro.core.engine_core import EngineCore, EngineRequest, SimBackend
    from repro.config import ModelConfig

    cfg = ModelConfig(name="t", family="dense", num_layers=8, d_model=256,
                      num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512,
                      vocab_size=1024)
    cost = CostModel(cfg, HARDWARE["h100"], IO_BANDWIDTHS["10Gbps"])

    def run(evict):
        core = EngineCore(SimBackend(cost), stages=1, io_channels=1,
                          max_active=1, preempt="priority", evict=evict,
                          strict=True)
        reqs = [EngineRequest("bg", 16384, 0.0,
                              plans=_plans("bg", 16384), priority=0),
                EngineRequest("hi", 1024, 1e-4,
                              plans=_plans("hi", 1024), priority=1)]
        return core.run(reqs)

    def _plans(rid, n):
        from repro.core.plans import make_request_plans
        return make_request_plans(rid, n, chunk_size=512, l_delta=0,
                                  num_layers=cfg.num_layers)

    res_park = run(evict=False)
    res_drop = run(evict=True)
    assert res_park.preemptions and res_drop.preemptions
    assert set(res_drop.finish) == {"bg", "hi"}
    # dropping completed units costs work: the victim finishes no earlier
    assert res_drop.finish["bg"] >= res_park.finish["bg"]
