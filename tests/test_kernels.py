"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU; same pallas_call lowers to Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import ops as fd_ops, ref as fd_ref
from repro.kernels.flash_prefill import ops as fp_ops, ref as fp_ref
from repro.kernels.rglru_scan import ops as rg_ops, ref as rg_ref
from repro.kernels.rwkv6_scan import ops as wk_ops, ref as wk_ref

RNG = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,hq,hk,dh,off,window", [
    (1, 128, 128, 4, 4, 64, 0, 0),          # pure causal, MHA
    (2, 128, 384, 4, 2, 64, 256, 0),        # chunk with cached prefix, GQA
    (1, 256, 256, 8, 1, 32, 0, 64),         # MQA, windowed
    (1, 200, 328, 4, 2, 64, 128, 0),        # non-multiple-of-block shapes
])
def test_flash_prefill_matches_ref(dtype, b, sq, skv, hq, hk, dh, off, window):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, skv, hk, dh), dtype)
    v = jax.random.normal(ks[2], (b, skv, hk, dh), dtype)
    scale = 1.0 / np.sqrt(dh)
    ref = fp_ref.flash_prefill_ref(q, k, v, off, skv, scale=scale, window=window)
    out = fp_ops.flash_prefill_attention(q, k, v, off, skv, scale=scale,
                                         window=window, backend="interpret",
                                         bq=128, bk=128)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hk,dh,valid,window", [
    (2, 512, 8, 2, 64, 300, 0),
    (1, 256, 4, 4, 128, 256, 0),
    (1, 384, 8, 1, 64, 200, 128),            # ring/windowed
])
def test_flash_decode_matches_ref(dtype, b, s, hq, hk, dh, valid, window):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hk, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hk, dh), dtype)
    kpos = jnp.where(jnp.arange(s) < valid, jnp.arange(s), -1).astype(jnp.int32)
    q_pos = valid - 1
    scale = 1.0 / np.sqrt(dh)
    ref = fd_ref.flash_decode_ref(q, k, v, kpos, q_pos, scale=scale, window=window)
    out = fd_ops.flash_decode_attention(q, k, v, kpos, q_pos, scale=scale,
                                        window=window, backend="interpret", bk=128)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,s,w,bs,bw", [
    (2, 256, 256, 128, 128),
    (1, 512, 128, 256, 128),
    (3, 128, 384, 64, 256),
])
def test_rglru_scan_matches_ref(b, s, w, bs, bw):
    ks = jax.random.split(RNG, 3)
    log_a = -jax.nn.softplus(jax.random.normal(ks[0], (b, s, w)))
    bt = jax.random.normal(ks[1], (b, s, w))
    h0 = jax.random.normal(ks[2], (b, w))
    h_ref, hl_ref = rg_ref.rglru_scan_ref(log_a, bt, h0)
    h, hl = rg_ops.rglru_scan(log_a, bt, h0, backend="interpret", bs=bs, bw=bw)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_ref), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("b,s,h,dh,bs", [
    (2, 128, 2, 32, 64),
    (1, 256, 4, 64, 128),
])
def test_rwkv6_scan_matches_ref(b, s, h, dh, bs):
    ks = jax.random.split(RNG, 6)
    r = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, dh)) - 2))
    u = jax.random.normal(ks[4], (h, dh)) * 0.1
    s0 = jax.random.normal(ks[5], (b, h, dh, dh)) * 0.1
    y_ref, sl_ref = wk_ref.wkv6_ref(r, k, v, w, u, s0)
    y, sl = wk_ops.wkv6(r, k, v, w, u, s0, backend="interpret", bs=bs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(sl), np.asarray(sl_ref), atol=2e-3, rtol=2e-3)


def test_wkv_chunked_matches_sequential():
    """The chunked wkv (model fast path / kernel structure) == per-token scan."""
    from repro.models.rwkv6 import wkv_scan_chunked, wkv_scan_ref
    ks = jax.random.split(RNG, 6)
    b, s, h, dh = 2, 256, 2, 32
    r, k, v = (jax.random.normal(ks[i], (b, s, h, dh)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, dh)) - 2))
    u = jax.random.normal(ks[4], (h, dh)) * 0.1
    s0 = jax.random.normal(ks[5], (b, h, dh, dh)) * 0.1
    y1, sl1 = wkv_scan_ref(r, k, v, w, u, s0)
    y2, sl2 = wkv_scan_chunked(r, k, v, w, u, s0, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(sl1), np.asarray(sl2), atol=2e-3, rtol=2e-3)


def test_flash_prefill_is_restoration_primitive():
    """Chunk-with-prefix flash == slicing the full causal result (the
    recompute-pointer step semantics)."""
    b, n, hq, hk, dh = 1, 256, 4, 2, 64
    c0 = 128  # prefix boundary
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, n, hq, dh))
    k = jax.random.normal(ks[1], (b, n, hk, dh))
    v = jax.random.normal(ks[2], (b, n, hk, dh))
    scale = 1 / np.sqrt(dh)
    full = fp_ref.flash_prefill_ref(q, k, v, 0, n, scale=scale)
    chunk = fp_ops.flash_prefill_attention(q[:, c0:], k, v, c0, n, scale=scale,
                                           backend="interpret")
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(full[:, c0:]),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# kv_quant: per-channel int8 quantize/dequantize (storage demotion codec)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (2, 1, 16, 4, 64),        # (n_attn, B, T, Hkv, Dh) attention KV chunk
    (2, 1, 16, 96),           # MLA ckv chunk (no head axis)
    (1, 1, 5, 3, 24),         # ragged tail chunk, non-multiple-of-block dims
    (300, 8),                 # tall-thin 2D (row padding path)
])
def test_kv_quant_kernel_matches_ref(dtype, shape):
    from repro.kernels.kv_quant import ops as kq_ops, ref as kq_ref
    x = jax.random.normal(jax.random.fold_in(RNG, sum(shape)), shape, dtype)
    q_ref, s_ref = kq_ref.kv_quantize_ref(x)
    q, s = kq_ops.kv_quantize(x, backend="interpret")
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-6, atol=0)
    y = kq_ops.kv_dequantize(q, s, dtype, backend="interpret")
    y_ref = kq_ref.kv_dequantize_ref(q_ref, s_ref, dtype)
    # 1-ULP slack: interpret-mode lowering may fuse the f32 multiply
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=3e-7, atol=0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kv_quant_round_trip_error_bound(dtype):
    """|x - deq(quant(x))| <= 0.5*scale (round-off) + 0.5*scale (target-
    dtype recast) per channel — the bound ChunkStore.quant_tolerance
    documents."""
    from repro.kernels.kv_quant import ops as kq_ops
    x = jax.random.normal(jax.random.fold_in(RNG, 7), (4, 1, 32, 2, 16), dtype)
    q, s = kq_ops.kv_quantize(x, backend="ref")
    y = kq_ops.kv_dequantize(q, s, dtype, backend="ref")
    err = np.abs(np.asarray(x, np.float32) - np.asarray(y, np.float32))
    bound = np.asarray(s) * (0.5 if dtype == jnp.float32 else 1.0) + 1e-7
    assert (err <= bound).all()


# ---------------------------------------------------------------------------
# kv_restore: fused restoration dequant-scatter (one launch per load op)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("a,s,c,cs,t0,nch", [
    (4, 32, 128, 8, 8, 2),        # aligned mid-prefix range
    (4, 30, 128, 8, 16, 2),       # odd tail: t0+T=32 > S=30 (boundary clip)
    (2, 20, 256, 4, 0, 5),        # whole prefix, many chunks
    (3, 9, 128, 8, 8, 1),        # single tail chunk, 7 padded rows clipped
])
def test_kv_restore_kernel_matches_ref(dtype, a, s, c, cs, t0, nch):
    from repro.kernels.kv_restore import ops as kr_ops
    t = nch * cs
    ks = jax.random.split(jax.random.fold_in(RNG, a * s + c + t0), 4)
    # two fields with different channel widths in ONE launch (k/v vs ckv)
    caches = [jax.random.normal(ks[0], (a, s, c), dtype),
              jax.random.normal(ks[1], (a, s, 2 * c), dtype)]
    staged = [jax.random.randint(ks[2], (a, t, c), -127, 128, jnp.int8),
              jax.random.randint(ks[3], (a, t, 2 * c), -127, 128, jnp.int8)]
    scales = [jnp.abs(jax.random.normal(ks[0], (nch, c))) * 0.05 + 1e-3,
              jnp.abs(jax.random.normal(ks[1], (nch, 2 * c))) * 0.05 + 1e-3]
    out_i = kr_ops.kv_restore_scatter(caches, staged, scales, t0=t0,
                                      chunk_size=cs, backend="interpret")
    out_r = kr_ops.kv_restore_scatter(caches, staged, scales, t0=t0,
                                      chunk_size=cs, backend="ref")
    for oi, orr in zip(out_i, out_r):
        np.testing.assert_array_equal(np.asarray(oi), np.asarray(orr))
    # untouched regions preserved bit-exactly despite the aliased in-place
    # partial-grid write
    for cache, oi in zip(caches, out_i):
        np.testing.assert_array_equal(np.asarray(oi)[:, :t0],
                                      np.asarray(cache)[:, :t0])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kv_restore_raw_copy_bit_exact(dtype):
    """quant="none" staging buffers carry the cache dtype: the scatter is
    a pure copy and the restored range equals the payload bit-for-bit."""
    from repro.kernels.kv_restore import ops as kr_ops
    a, s, c, cs, t0, nch = 3, 26, 128, 8, 8, 2
    t = nch * cs
    ks = jax.random.split(jax.random.fold_in(RNG, 11), 2)
    cache = jax.random.normal(ks[0], (a, s, c), dtype)
    staged = jax.random.normal(ks[1], (a, t, c), dtype)
    for backend in ("interpret", "ref"):
        out = kr_ops.kv_restore_scatter([cache], [staged], None, t0=t0,
                                        chunk_size=cs, backend=backend)[0]
        o = np.asarray(out)
        t_eff = min(t, s - t0)
        np.testing.assert_array_equal(o[:, t0:t0 + t_eff],
                                      np.asarray(staged)[:, :t_eff])
        np.testing.assert_array_equal(o[:, :t0], np.asarray(cache)[:, :t0])


def test_kv_restore_slot_subspan():
    """A layer span owning only slots [lo, hi) must leave other slots'
    rows untouched (multi-stage splits restore sub-spans)."""
    from repro.kernels.kv_restore import ops as kr_ops
    a, s, c, cs = 4, 16, 64, 8
    cache = jax.random.normal(jax.random.fold_in(RNG, 3), (a, s, c))
    staged = jax.random.normal(jax.random.fold_in(RNG, 4), (a, cs, c))
    out = kr_ops.kv_restore_scatter([cache], [staged], None, t0=8,
                                    slot_lo=1, n_slots=2, chunk_size=cs,
                                    backend="ref")[0]
    o, ca, st = (np.asarray(x) for x in (out, cache, staged))
    np.testing.assert_array_equal(o[0], ca[0])
    np.testing.assert_array_equal(o[3], ca[3])
    np.testing.assert_array_equal(o[1:3, 8:16], st[1:3])


def test_kv_restore_dequant_matches_kv_dequantize():
    """The fused scatter's on-device dequant math is bit-identical to the
    storage codec's kv_dequantize — fused restoration lands the same bits
    the legacy decode-then-copy path would."""
    from repro.kernels.kv_quant import ops as kq_ops
    from repro.kernels.kv_restore import ops as kr_ops
    a, s, hk, dh, cs = 2, 16, 2, 64, 8
    x = jax.random.normal(jax.random.fold_in(RNG, 5), (a, 1, cs, hk, dh))
    q, scales = kq_ops.kv_quantize(x, backend="ref")
    dec = kq_ops.kv_dequantize(q, scales, jnp.float32, backend="ref")
    c = hk * dh
    cache = jnp.zeros((a, s, c))
    staged = [jnp.asarray(np.asarray(q).reshape(a, cs, c))]
    sc = [jnp.tile(scales, hk)[None]]          # (1, C): one chunk
    for backend in ("interpret", "ref"):
        out = kr_ops.kv_restore_scatter([cache], staged, sc, t0=0,
                                        chunk_size=cs, backend=backend)[0]
        np.testing.assert_array_equal(
            np.asarray(out)[:, :cs], np.asarray(dec).reshape(a, cs, c))
