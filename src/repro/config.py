"""Model / shape / hardware configuration for the repro framework.

Every architecture in the assigned pool is describable by one frozen
:class:`ModelConfig`.  Family-specific knobs live in optional sub-configs
(:class:`MoEConfig`, :class:`MLAConfig`, :class:`RGLRUConfig`,
:class:`RWKVConfig`).  Configs are pure data — models are built from them in
``repro.models.model`` and sharding rules in ``repro.distributed.sharding``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """GShard/Switch-style mixture of experts (shared + routed, top-k)."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0          # d_ff of the shared-expert block (0 = expert_d_ff * num_shared)
    first_k_dense: int = 0        # leading layers that use a dense FFN instead
    dense_d_ff: int = 0           # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    q_lora_rank: int = 1536       # 0 => full-rank q projection
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin RG-LRU recurrent block."""

    lru_width: int = 0            # 0 => d_model
    conv1d_width: int = 4
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    num_rglru_heads: int = 0      # block-diagonal gating heads (0 => d/128)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 "Finch" time-mix with data-dependent decay."""

    head_size: int = 64
    decay_lora_rank: int = 64     # LoRA rank of the data-dependent decay path
    tokenshift_lora_rank: int = 32


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "vlm", "hybrid", "ssm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attention-free)
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- normalisation / activation / position ---
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-6
    activation: str = "swiglu"    # swiglu | geglu | gelu (non-gated)
    position: str = "rope"        # rope | sinusoidal | none
    rope_theta: float = 10_000.0
    use_qkv_bias: bool = False
    use_qk_norm: bool = False
    tie_embeddings: bool = False
    logits_softcap: float = 0.0

    # --- attention variants ---
    attn_window: int = 0          # 0 = full causal; >0 = sliding window
    mla: Optional[MLAConfig] = None

    # --- family extras ---
    moe: Optional[MoEConfig] = None
    rglru: Optional[RGLRUConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # --- frontend ---
    input_mode: str = "tokens"    # tokens | embeddings (vlm/audio stub frontends)

    # ------------------------------------------------------------------
    # Derived structure
    # ------------------------------------------------------------------
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind: 'attention' | 'recurrent' | 'rwkv'."""
        if self.rwkv is not None:
            return ("rwkv",) * self.num_layers
        if self.rglru is not None:
            pat = self.rglru.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        return ("attention",) * self.num_layers

    @property
    def is_uniform(self) -> bool:
        """True if every layer is identical => scan-over-layers applies."""
        kinds = set(self.layer_kinds())
        if len(kinds) != 1:
            return False
        if self.moe is not None and self.moe.first_k_dense > 0:
            return False
        return True

    @property
    def attention_layers(self) -> Tuple[int, ...]:
        return tuple(i for i, k in enumerate(self.layer_kinds()) if k == "attention")

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context (O(1)/O(window) state)?"""
        if self.rwkv is not None:
            return True
        if self.rglru is not None:
            return self.attn_window > 0
        return False

    @property
    def qk_head_dim(self) -> int:
        if self.mla is not None:
            return self.mla.qk_nope_head_dim + self.mla.qk_rope_head_dim
        return self.head_dim

    @property
    def v_head_dim(self) -> int:
        if self.mla is not None:
            return self.mla.v_head_dim
        return self.head_dim

    # ------------------------------------------------------------------
    # Parameter / cache accounting (exact, used for roofline MODEL_FLOPS)
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            p = 0
            if m.q_lora_rank > 0:
                p += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * self.qk_head_dim
            else:
                p += d * self.num_heads * self.qk_head_dim
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)                    # kv down (+ shared rope key)
            p += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)  # kv up
            p += self.num_heads * m.v_head_dim * d                            # o proj
            return p
        hq, hk, dh = self.num_heads, self.num_kv_heads, self.head_dim
        p = d * hq * dh + 2 * d * hk * dh + hq * dh * d
        if self.use_qkv_bias:
            p += (hq + 2 * hk) * dh
        return p

    def _ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        return mult * self.d_model * d_ff

    def _rglru_params(self) -> int:
        w = self.rglru.lru_width or self.d_model
        d = self.d_model
        conv = self.rglru.conv1d_width * w
        # linear in (x2 branches) + gates (recurrence + input, block-diagonal approx dense) + out
        return 2 * d * w + 2 * w * (w // max(1, self.rglru.num_rglru_heads or (w // 128))) + conv + w * d

    def _rwkv_params(self) -> int:
        d = self.d_model
        r = self.rwkv.decay_lora_rank
        # time-mix: r,k,v,g,o projections + decay LoRA + token-shift LoRAs (5 small)
        # + channel-mix receptance (the 2·d·d_ff channel-mix mats are counted as FFN)
        tm = 5 * d * d + (d * r + r * d) + 5 * (d * self.rwkv.tokenshift_lora_rank * 2)
        return tm + d * d

    def param_counts(self) -> dict:
        """Returns dict(total=..., active=..., embedding=...)."""
        d = self.d_model
        n_tables = 1 if (self.tie_embeddings or self.input_mode == "embeddings") else 2
        emb = self.vocab_size * d * n_tables
        total = emb
        active = emb
        for i, kind in enumerate(self.layer_kinds()):
            lp_tot = lp_act = 2 * d  # two norms
            if kind == "attention":
                a = self._attn_params()
                lp_tot += a
                lp_act += a
            elif kind == "recurrent":
                a = self._rglru_params()
                lp_tot += a
                lp_act += a
            elif kind == "rwkv":
                a = self._rwkv_params()
                lp_tot += a
                lp_act += a
            # FFN
            if self.moe is not None and i >= self.moe.first_k_dense:
                m = self.moe
                e = self._ffn_params(m.expert_d_ff)
                shared_ff = m.shared_d_ff or m.num_shared_experts * m.expert_d_ff
                s = self._ffn_params(shared_ff) if shared_ff else 0
                router = d * m.num_experts
                lp_tot += m.num_experts * e + s + router
                lp_act += m.top_k * e + s + router
            elif self.moe is not None and i < self.moe.first_k_dense:
                f = self._ffn_params(self.moe.dense_d_ff or self.d_ff)
                lp_tot += f
                lp_act += f
            else:
                f = self._ffn_params(self.d_ff)
                lp_tot += f
                lp_act += f
            total += lp_tot
            active += lp_act
        total += d  # final norm
        active += d
        return dict(total=int(total), active=int(active), embedding=int(emb))

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes for ONE token across all layers (the I/O unit of
        CacheFlow restoration)."""
        per_layer = 0
        if self.mla is not None:
            per_layer = (self.mla.kv_lora_rank + self.mla.qk_rope_head_dim) * dtype_bytes
        elif self.num_kv_heads > 0:
            per_layer = 2 * self.num_kv_heads * self.head_dim * dtype_bytes
        n_attn = len(self.attention_layers)
        return per_layer * n_attn

    def state_bytes(self, batch: int = 1, dtype_bytes: int = 4) -> int:
        """Recurrent-state bytes (RG-LRU / RWKV) — O(1) in sequence length."""
        b = 0
        for kind in self.layer_kinds():
            if kind == "recurrent":
                w = self.rglru.lru_width or self.d_model
                b += batch * (w + (self.rglru.conv1d_width - 1) * w) * dtype_bytes
            elif kind == "rwkv":
                h = self.d_model // self.rwkv.head_size
                b += batch * (h * self.rwkv.head_size * self.rwkv.head_size + 2 * self.d_model) * dtype_bytes
        return b

    def flops_per_token(self, context_len: int = 0) -> float:
        """Forward FLOPs per token: 2·N_active + attention quadratic term."""
        n = self.param_counts()["active"] - self.param_counts()["embedding"]
        f = 2.0 * n
        for _ in self.attention_layers:
            ctx = min(context_len, self.attn_window) if self.attn_window else context_len
            f += 2 * 2 * self.num_heads * self.qk_head_dim * ctx  # qk^T and ·v
        return f

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 4 if self.rglru is None else 6),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32 if self.num_heads else 0,
            d_ff=256,
            vocab_size=512,
        )
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
            kw["num_kv_heads"] = kw["num_heads"]
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=8, top_k=2, expert_d_ff=64,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                shared_d_ff=64 if self.moe.num_shared_experts else 0,
                first_k_dense=min(self.moe.first_k_dense, 1),
                dense_d_ff=256 if self.moe.first_k_dense else 0)
        if self.rglru is not None:
            kw["rglru"] = dataclasses.replace(self.rglru, lru_width=128, num_rglru_heads=2)
            kw["num_kv_heads"] = 1
            kw["attn_window"] = 0 if not self.attn_window else 64
        if self.rwkv is not None:
            kw["rwkv"] = dataclasses.replace(self.rwkv, head_size=32, decay_lora_rank=16,
                                             tokenshift_lora_rank=8)
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned; every (arch × shape) is one dry-run cell)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic decode; everything else is universal
    for the (decoder-only) assigned pool."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


# ---------------------------------------------------------------------------
# Target-hardware profiles (roofline constants; v5e is the assigned target)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    link_bw: float             # bytes/s per ICI link
    hbm_bytes: float
    # serving-simulation extras
    kernel_overhead_s: float = 30e-6   # fixed per-launch overhead (paper's c0)


HARDWARE = {
    "tpu_v5e": HardwareProfile("tpu_v5e", 197e12, 819e9, 50e9, 16e9),
    # Paper GPUs (used by the fig9 hardware ablation simulator only)
    "l40s": HardwareProfile("l40s", 181e12, 864e9, 32e9, 46e9, kernel_overhead_s=20e-6),
    "a100": HardwareProfile("a100", 312e12, 1555e9, 300e9, 40e9, kernel_overhead_s=15e-6),
    "h100": HardwareProfile("h100", 989e12, 3350e9, 450e9, 80e9, kernel_overhead_s=12e-6),
}

GBPS = 1e9 / 8  # bytes/s per Gbps

# Paper's studied I/O bandwidths (bytes/s)
IO_BANDWIDTHS = {"10Gbps": 10 * GBPS, "40Gbps": 40 * GBPS, "80Gbps": 80 * GBPS}
