"""Tiered KV store: HBM / host DRAM / remote, with bandwidth + capacity model.

On a real v5e fleet the tiers are per-chip HBM (819 GB/s), host DRAM over
DMA, and a remote disaggregated store over DCN (the paper's 10–80 Gbps
regime).  Here the store tracks placement, enforces capacities with LRU
spill, and reports the channel bandwidth restoration I/O sees for a given
request — which is what the CacheFlow cost model and simulator consume.

Placement is per *request* payload (KV bytes + boundary activations), the
granularity the paper's storage tier operates at.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional

TIER_ORDER = ("hbm", "host", "remote")


@dataclass
class Tier:
    name: str
    bandwidth: float               # bytes/s toward HBM
    capacity: float                # bytes
    used: float = 0.0
    lru: "OrderedDict[str, int]" = field(default_factory=OrderedDict)


class TieredKVStore:
    def __init__(self, *, hbm_bw: float = 819e9, hbm_cap: float = 4e9,
                 host_bw: float = 100e9, host_cap: float = 200e9,
                 remote_bw: float = 10e9 / 8, remote_cap: float = 100e12,
                 io_channels: int = 1):
        self.tiers: Dict[str, Tier] = {
            "hbm": Tier("hbm", hbm_bw, hbm_cap),
            "host": Tier("host", host_bw, host_cap),
            "remote": Tier("remote", remote_bw, remote_cap),
        }
        self.io_channels = io_channels
        self.placement: Dict[str, str] = {}   # rid -> tier name

    # ------------------------------------------------------------------
    def put(self, rid: str, nbytes: int, tier: str = "host"):
        """Store a request's KV payload, spilling LRU entries downward."""
        self._evict_for(tier, nbytes)
        t = self.tiers[tier]
        t.lru[rid] = nbytes
        t.used += nbytes
        self.placement[rid] = tier

    def _evict_for(self, tier: str, nbytes: int):
        t = self.tiers[tier]
        order = list(TIER_ORDER)
        below = order[order.index(tier) + 1] if tier != "remote" else None
        while t.used + nbytes > t.capacity and t.lru:
            victim, vbytes = t.lru.popitem(last=False)
            t.used -= vbytes
            if below is not None:
                self.put(victim, vbytes, below)
            else:
                self.placement.pop(victim, None)

    def touch(self, rid: str):
        tier = self.placement.get(rid)
        if tier:
            t = self.tiers[tier]
            if rid in t.lru:
                t.lru.move_to_end(rid)

    def tier_of(self, rid: str) -> Optional[str]:
        return self.placement.get(rid)

    def bandwidth_for(self, rid: str) -> float:
        """Channel bandwidth restoration I/O sees for this request's payload."""
        tier = self.placement.get(rid, "remote")
        return self.tiers[tier].bandwidth

    def promote(self, rid: str, to: str = "host"):
        tier = self.placement.get(rid)
        if tier is None or TIER_ORDER.index(tier) <= TIER_ORDER.index(to):
            return
        t = self.tiers[tier]
        nbytes = t.lru.pop(rid)
        t.used -= nbytes
        self.put(rid, nbytes, to)

    def evict(self, rid: str):
        tier = self.placement.pop(rid, None)
        if tier:
            t = self.tiers[tier]
            nbytes = t.lru.pop(rid, 0)
            t.used -= nbytes
