"""Tiered KV store: HBM / host DRAM / remote, with bandwidth + capacity model.

On a real v5e fleet the tiers are per-chip HBM (819 GB/s), host DRAM over
DMA, and a remote disaggregated store over DCN (the paper's 10–80 Gbps
regime).  This is the SIM-MODE facade: placement is per *request* payload
(KV bytes + boundary activations) and no real bytes move — the store
tracks placement, enforces capacities, and reports the channel bandwidth
restoration I/O sees for a given request, which is what the CacheFlow cost
model and simulator consume.  The materialized, chunk-granular store that
actually holds tensor bytes (real mode) is
:class:`repro.storage.chunkstore.ChunkStore`; both sit on the SAME
placement/accounting core (:mod:`repro.storage.placement`), so capacities,
recency, and the demotion cascade behave identically.

The cascade is correct when lower tiers are also full (see
``PlacementCore``): an entry larger than a tier's capacity skips to the
first tier that fits, demotion into a full tier recursively evicts there,
and only the bottom tier drops entries (counted, never silent).

``quant="int8"`` models the kv_quant compression of sub-HBM tiers: entries
below ``hbm`` occupy half their bytes and their transfers see 2× the
tier's nominal bandwidth (half the bytes on the wire).
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.storage.placement import PlacementCore, Tier

TIER_ORDER = ("hbm", "host", "remote")


class TieredKVStore:
    def __init__(self, *, hbm_bw: float = 819e9, hbm_cap: float = 4e9,
                 host_bw: float = 100e9, host_cap: float = 200e9,
                 remote_bw: float = 10e9 / 8, remote_cap: float = 100e12,
                 io_channels: int = 1, quant: str = "none"):
        if quant not in ("none", "int8"):
            raise ValueError(f"unknown quant mode {quant!r}")
        self.quant = quant
        self.core = PlacementCore(
            [Tier("hbm", hbm_bw, hbm_cap), Tier("host", host_bw, host_cap),
             Tier("remote", remote_bw, remote_cap)],
            size_fn=self._size)
        self.io_channels = io_channels
        self._raw: dict = {}            # rid -> nominal payload bytes
        self.io_hits = 0                # transfers skipped (HBM-resident)

    # ------------------------------------------------------------------
    def _size(self, rid: str, tier: str) -> float:
        nb = self._raw[rid]
        if self.quant == "int8" and tier != "hbm":
            return (nb + 1) // 2        # int8 halves the bf16 payload
        return nb

    @property
    def tiers(self):
        return self.core.tiers

    @property
    def placement(self):
        return self.core.placement

    # ------------------------------------------------------------------
    def put(self, rid: str, nbytes: int, tier: str = "host"):
        """Store a request's KV payload, demoting victims downward (the
        cascade never over-fills a tier; bottom-tier drops are counted)."""
        self._raw[rid] = nbytes
        self.core.put(rid, tier)

    def touch(self, rid: str):
        self.core.touch(rid)

    def tier_of(self, rid: str) -> Optional[str]:
        return self.core.tier_of(rid)

    def bandwidth_for(self, rid: str) -> float:
        """Channel bandwidth restoration I/O sees for this request's payload."""
        tier = self.core.tier_of(rid) or "remote"
        bw = self.core.tiers[tier].bandwidth
        if self.quant == "int8" and tier != "hbm":
            bw *= 2.0                   # half the bytes move per KV token
        return bw

    def promote(self, rid: str, to: str = "host"):
        self.core.promote(rid, to)

    def evict(self, rid: str):
        self.core.remove(rid)
        self._raw.pop(rid, None)

    # ------------------------------------------------------------------
    # Engine-core residency protocol: an HBM-resident payload needs no
    # restoration transfer at all — the engine skips the I/O channel.
    # ------------------------------------------------------------------
    def io_resident(self, rid: str, tokens: Tuple[int, int],
                    layers: Tuple[int, int]) -> bool:
        return self.core.tier_of(rid) == "hbm"

    def note_io_hit(self, rid: str, tokens: Tuple[int, int],
                    layers: Tuple[int, int]):
        self.io_hits += 1
