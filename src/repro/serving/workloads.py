"""Synthetic serving workloads shaped after the paper's three datasets (§4.1).

Length distributions are calibrated to the published dataset statistics
(paper Fig. 1a: high prevalence of multi-thousand-token reusable prefixes,
tails beyond 20k):

  * lmsys_chat — multi-turn ChatGPT traces: lognormal prefix lengths
    (median ≈ 2.5k, p95 ≈ 15k), short new turns.
  * wildchat   — open-domain, broader/multi-lingual: wider lognormal
    (median ≈ 1.5k, p95 ≈ 12k) with a 20% short-context mass.
  * swe_bench  — agentic coding: long shared repository contexts
    (10k–30k) reused across tool invocations (shared prefix_id), short
    tool-call suffixes.

Beyond the three datasets, ``bursty_priority`` is the SLO-pressure workload
the engine's preemption policies target: a steady background of long-prefix
batch requests (priority 0) punctuated by bursts of short urgent
interactive requests (priority 1, tight first-token deadlines) arriving
together — under a ``max_active`` cap the urgent burst queues behind long
restorations unless the engine preempts.

Deterministic in the seed; arrivals are Poisson.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.serving.request import Request

WORKLOADS = ("lmsys_chat", "wildchat", "swe_bench", "bursty_priority")


def generate(workload: str, n_requests: int, *, seed: int = 0,
             arrival_rate: float = 2.0, max_len: int = 32_768) -> List[Request]:
    if workload == "bursty_priority":
        return bursty_priority(n_requests, seed=seed,
                               arrival_rate=arrival_rate, max_len=max_len)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    reqs: List[Request] = []
    if workload == "lmsys_chat":
        prefix = np.minimum(rng.lognormal(np.log(2500), 0.9, n_requests), max_len)
        new = rng.integers(32, 512, n_requests)
        pid = [f"conv-{i}" for i in range(n_requests)]
    elif workload == "wildchat":
        prefix = np.minimum(rng.lognormal(np.log(1500), 1.1, n_requests), max_len)
        short = rng.random(n_requests) < 0.2
        prefix = np.where(short, rng.integers(64, 512, n_requests), prefix)
        new = rng.integers(32, 768, n_requests)
        pid = [f"conv-{i}" for i in range(n_requests)]
    elif workload == "swe_bench":
        n_repos = max(1, n_requests // 6)   # ~6 tool calls per repo context
        repo_len = rng.integers(10_000, min(30_000, max_len), n_repos)
        repo_of = rng.integers(0, n_repos, n_requests)
        prefix = repo_len[repo_of] + rng.integers(0, 2000, n_requests)
        prefix = np.minimum(prefix, max_len)
        new = rng.integers(16, 256, n_requests)
        pid = [f"repo-{repo_of[i]}" for i in range(n_requests)]
    else:
        raise ValueError(f"unknown workload {workload!r}; known: {WORKLOADS}")
    for i in range(n_requests):
        reqs.append(Request(
            request_id=f"{workload}-{i}", arrival=float(arrivals[i]),
            prefix_len=int(max(64, prefix[i])), new_len=int(new[i]),
            decode_len=int(rng.integers(16, 128)), prefix_id=pid[i]))
    return reqs


def bursty_priority(n_requests: int, *, seed: int = 0,
                    arrival_rate: float = 2.0, max_len: int = 32_768,
                    burst_every: float = 4.0, burst_size: int = 3,
                    urgent_deadline: float = 2.0) -> List[Request]:
    """Two-SLO-class admission-pressure workload (preemption target).

    ~2/3 of the requests are BACKGROUND (priority 0): Poisson arrivals,
    long lognormal prefixes (median ≈ 8k), loose deadlines.  The rest are
    URGENT (priority 1): short prefixes (256–1k) and short turns, arriving
    in simultaneous bursts of ``burst_size`` every ``burst_every`` seconds
    with a ``urgent_deadline``-second first-token SLO — the short-behind-
    long queueing pattern §3.3's batch awareness leaves on the table
    without preemption."""
    rng = np.random.default_rng(seed)
    # ~1/3 urgent (at least one); the last burst may be partial so the
    # function always returns EXACTLY n_requests requests
    n_urgent = min(n_requests, max(1, n_requests // 3))
    n_bg = n_requests - n_urgent
    reqs: List[Request] = []
    bg_arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_bg))
    bg_prefix = np.minimum(rng.lognormal(np.log(8000), 0.6, n_bg), max_len)
    for i in range(n_bg):
        reqs.append(Request(
            request_id=f"bg-{i}", arrival=float(bg_arrivals[i]),
            prefix_len=int(max(2048, bg_prefix[i])),
            new_len=int(rng.integers(32, 256)),
            decode_len=int(rng.integers(16, 128)),
            priority=0, deadline=float(bg_arrivals[i]) + 120.0,
            prefix_id=f"bg-{i}"))
    for j, start in enumerate(range(0, n_urgent, burst_size)):
        t = burst_every * (j + 1)
        for i in range(start, min(start + burst_size, n_urgent)):
            reqs.append(Request(
                request_id=f"hi-{i}", arrival=float(t),
                prefix_len=int(rng.integers(256, 1024)),
                new_len=int(rng.integers(16, 128)),
                decode_len=int(rng.integers(8, 32)),
                priority=1, deadline=float(t) + urgent_deadline,
                prefix_id=f"hi-{i}"))
    reqs.sort(key=lambda r: (r.arrival, r.request_id))
    return reqs


def fixed_length(n_requests: int, prefix_len: int, *, new_len: int = 128,
                 seed: int = 0, arrival_rate: float = 100.0) -> List[Request]:
    """Uniform-length batch (paper Fig. 6 / Fig. 10 style ablations)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    return [Request(request_id=f"fix-{i}", arrival=float(arrivals[i]),
                    prefix_len=prefix_len, new_len=new_len) for i in range(n_requests)]
