"""Synthetic serving workloads shaped after the paper's three datasets (§4.1).

Length distributions are calibrated to the published dataset statistics
(paper Fig. 1a: high prevalence of multi-thousand-token reusable prefixes,
tails beyond 20k):

  * lmsys_chat — multi-turn ChatGPT traces: lognormal prefix lengths
    (median ≈ 2.5k, p95 ≈ 15k), short new turns.
  * wildchat   — open-domain, broader/multi-lingual: wider lognormal
    (median ≈ 1.5k, p95 ≈ 12k) with a 20% short-context mass.
  * swe_bench  — agentic coding: long shared repository contexts
    (10k–30k) reused across tool invocations (shared prefix_id), short
    tool-call suffixes.

Beyond the three datasets, ``bursty_priority`` is the SLO-pressure workload
the engine's preemption policies target: a steady background of long-prefix
batch requests (priority 0) punctuated by bursts of short urgent
interactive requests (priority 1, tight first-token deadlines) arriving
together — under a ``max_active`` cap the urgent burst queues behind long
restorations unless the engine preempts.

``multi_tenant`` is the CONTINUOUS-BATCHING workload (DESIGN.md §11): a
sustained production-shaped stream mixing Zipf prefix popularity (a small
catalog of shared contexts absorbs most traffic, so the KV store's reuse
tiers matter), a diurnal arrival-rate envelope (the steady state the
benchmark measures sits between the ramp-up and the trough) and three SLO
classes (interactive / standard / batch) with distinct priorities and
first-token deadlines.

``agentic_tree`` is the SESSION-FORKING workload (DESIGN.md §12): tree
search over actions — each tree has one PARENT request carrying a long
agent context, then K speculative BRANCHES forked from the live parent
(``meta["fork_of"]``) moments later, each trying a different short action
suffix.  Branches share the parent's entire prefix: with paged-block CoW
they reach first token with ~zero restoration bytes (the fork aliases the
parent's device blocks) instead of re-restoring the full context K times.

Deterministic in the seed; arrivals are Poisson.
"""
from __future__ import annotations

import math
from typing import List

import numpy as np

from repro.serving.request import Request

WORKLOADS = ("lmsys_chat", "wildchat", "swe_bench", "bursty_priority",
             "multi_tenant", "agentic_tree")


def generate(workload: str, n_requests: int, *, seed: int = 0,
             arrival_rate: float = 2.0, max_len: int = 32_768) -> List[Request]:
    if workload == "bursty_priority":
        return bursty_priority(n_requests, seed=seed,
                               arrival_rate=arrival_rate, max_len=max_len)
    if workload == "multi_tenant":
        return multi_tenant(n_requests, seed=seed,
                            arrival_rate=arrival_rate, max_len=max_len)
    if workload == "agentic_tree":
        return agentic_tree(n_requests, seed=seed,
                            arrival_rate=arrival_rate, max_len=max_len)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    reqs: List[Request] = []
    if workload == "lmsys_chat":
        prefix = np.minimum(rng.lognormal(np.log(2500), 0.9, n_requests), max_len)
        new = rng.integers(32, 512, n_requests)
        pid = [f"conv-{i}" for i in range(n_requests)]
    elif workload == "wildchat":
        prefix = np.minimum(rng.lognormal(np.log(1500), 1.1, n_requests), max_len)
        short = rng.random(n_requests) < 0.2
        prefix = np.where(short, rng.integers(64, 512, n_requests), prefix)
        new = rng.integers(32, 768, n_requests)
        pid = [f"conv-{i}" for i in range(n_requests)]
    elif workload == "swe_bench":
        n_repos = max(1, n_requests // 6)   # ~6 tool calls per repo context
        repo_len = rng.integers(10_000, min(30_000, max_len), n_repos)
        repo_of = rng.integers(0, n_repos, n_requests)
        prefix = repo_len[repo_of] + rng.integers(0, 2000, n_requests)
        prefix = np.minimum(prefix, max_len)
        new = rng.integers(16, 256, n_requests)
        pid = [f"repo-{repo_of[i]}" for i in range(n_requests)]
    else:
        raise ValueError(f"unknown workload {workload!r}; known: {WORKLOADS}")
    for i in range(n_requests):
        reqs.append(Request(
            request_id=f"{workload}-{i}", arrival=float(arrivals[i]),
            prefix_len=int(max(64, prefix[i])), new_len=int(new[i]),
            decode_len=int(rng.integers(16, 128)), prefix_id=pid[i]))
    return reqs


def bursty_priority(n_requests: int, *, seed: int = 0,
                    arrival_rate: float = 2.0, max_len: int = 32_768,
                    burst_every: float = 4.0, burst_size: int = 3,
                    urgent_deadline: float = 2.0) -> List[Request]:
    """Two-SLO-class admission-pressure workload (preemption target).

    ~2/3 of the requests are BACKGROUND (priority 0): Poisson arrivals,
    long lognormal prefixes (median ≈ 8k), loose deadlines.  The rest are
    URGENT (priority 1): short prefixes (256–1k) and short turns, arriving
    in simultaneous bursts of ``burst_size`` every ``burst_every`` seconds
    with a ``urgent_deadline``-second first-token SLO — the short-behind-
    long queueing pattern §3.3's batch awareness leaves on the table
    without preemption."""
    rng = np.random.default_rng(seed)
    # ~1/3 urgent (at least one); the last burst may be partial so the
    # function always returns EXACTLY n_requests requests
    n_urgent = min(n_requests, max(1, n_requests // 3))
    n_bg = n_requests - n_urgent
    reqs: List[Request] = []
    bg_arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_bg))
    bg_prefix = np.minimum(rng.lognormal(np.log(8000), 0.6, n_bg), max_len)
    for i in range(n_bg):
        reqs.append(Request(
            request_id=f"bg-{i}", arrival=float(bg_arrivals[i]),
            prefix_len=int(max(2048, bg_prefix[i])),
            new_len=int(rng.integers(32, 256)),
            decode_len=int(rng.integers(16, 128)),
            priority=0, deadline=float(bg_arrivals[i]) + 120.0,
            prefix_id=f"bg-{i}"))
    for j, start in enumerate(range(0, n_urgent, burst_size)):
        t = burst_every * (j + 1)
        for i in range(start, min(start + burst_size, n_urgent)):
            reqs.append(Request(
                request_id=f"hi-{i}", arrival=float(t),
                prefix_len=int(rng.integers(256, 1024)),
                new_len=int(rng.integers(16, 128)),
                decode_len=int(rng.integers(8, 32)),
                priority=1, deadline=float(t) + urgent_deadline,
                prefix_id=f"hi-{i}"))
    reqs.sort(key=lambda r: (r.arrival, r.request_id))
    return reqs


def multi_tenant(n_requests: int, *, seed: int = 0, arrival_rate: float = 2.0,
                 max_len: int = 32_768, n_prefixes: int = 0,
                 zipf_s: float = 1.1, diurnal_period: float = 60.0,
                 diurnal_depth: float = 0.6) -> List[Request]:
    """Sustained multi-tenant stream for continuous-batching studies.

    Three production-shaped dimensions:

      * **Zipf prefix popularity** — requests draw their shared context
        from a catalog of ``n_prefixes`` prefixes (default ``≈ n/4``) with
        Zipf(``zipf_s``) popularity: the head prefixes recur constantly
        (hot in the KV store after first restoration; prefetch and reuse
        tiers pay off), the tail is effectively cold.  Each catalog entry
        has a FIXED length (lognormal, median ≈ 4k) so repeat hits are
        true reuse.
      * **Diurnal arrival envelope** — a thinned Poisson process whose
        instantaneous rate follows ``rate·(1 - depth·(1+cos(2πt/T))/2)``:
        peaks at ``arrival_rate``, troughs at ``rate·(1-depth)``.  The
        steady-state window the throughput benchmark measures excludes the
        empty-device ramp; the trough/peak alternation keeps admission
        pressure time-varying the way real traffic is.
      * **Mixed SLO classes** — ~30% interactive (priority 2, first-token
        deadline arrival+2s, short turns), ~50% standard (priority 1,
        +10s), ~20% batch (priority 0, no deadline, long decode) — the mix
        the priority-aware I/O dispatch key orders a congested channel by.

    Deterministic in the seed (thinning uses its own substream).
    """
    rng = np.random.default_rng(seed)
    n_prefixes = n_prefixes or max(4, n_requests // 4)
    # fixed-length catalog: popularity rank ~ Zipf, length iid lognormal
    catalog_len = np.minimum(
        rng.lognormal(np.log(4000), 0.8, n_prefixes), max_len)
    catalog_len = np.maximum(catalog_len, 256).astype(np.int64)
    ranks = np.arange(1, n_prefixes + 1, dtype=np.float64)
    popularity = ranks ** (-zipf_s)
    popularity /= popularity.sum()

    # diurnal thinned Poisson: simulate at the PEAK rate, keep each arrival
    # with probability rate(t)/peak (Lewis–Shedler thinning)
    arrivals: List[float] = []
    t = 0.0
    while len(arrivals) < n_requests:
        t += rng.exponential(1.0 / arrival_rate)
        envelope = 1.0 - diurnal_depth * (
            1.0 + math.cos(2.0 * math.pi * t / diurnal_period)) / 2.0
        if rng.random() < envelope:
            arrivals.append(t)

    reqs: List[Request] = []
    classes = rng.choice(3, n_requests, p=[0.3, 0.5, 0.2])
    prefix_ids = rng.choice(n_prefixes, n_requests, p=popularity)
    for i in range(n_requests):
        pid = int(prefix_ids[i])
        a = arrivals[i]
        if classes[i] == 0:        # interactive
            prio, deadline = 2, a + 2.0
            new = int(rng.integers(16, 128))
            dec = int(rng.integers(8, 64))
        elif classes[i] == 1:      # standard
            prio, deadline = 1, a + 10.0
            new = int(rng.integers(32, 512))
            dec = int(rng.integers(16, 128))
        else:                      # batch
            prio, deadline = 0, math.inf
            new = int(rng.integers(64, 1024))
            dec = int(rng.integers(64, 256))
        reqs.append(Request(
            request_id=f"mt-{i}", arrival=float(a),
            prefix_len=int(catalog_len[pid]), new_len=new, decode_len=dec,
            priority=prio, deadline=float(deadline),
            prefix_id=f"prefix-{pid}"))
    return reqs


def agentic_tree(n_requests: int, *, seed: int = 0, arrival_rate: float = 2.0,
                 max_len: int = 32_768, branch_factor: int = 4,
                 think_gap: float = 0.25) -> List[Request]:
    """Agentic tree-search workload: speculative branches forked off live
    parent contexts.

    Requests come in TREES of ``1 + branch_factor``: the parent carries a
    long accumulated agent context (lognormal, median ≈ 6k — tool outputs,
    scratchpads, retrieved docs) and starts decoding; ``think_gap`` seconds
    later its K speculative branches arrive, each with the SAME prefix
    length, ``meta={"fork_of": parent_id}`` and a short action suffix —
    the serving engine forks them from the parent session (CoW block
    tables) instead of re-running/re-restoring the shared context.  The
    last tree may be partial so EXACTLY ``n_requests`` are returned; sim
    engines (no fork path) still see maximal prefix sharing via the
    tree-wide ``prefix_id``."""
    rng = np.random.default_rng(seed)
    tree = 1 + max(1, branch_factor)
    n_trees = -(-n_requests // tree)
    arrivals = np.cumsum(rng.exponential(tree / arrival_rate, n_trees))
    prefix = np.minimum(rng.lognormal(np.log(6000), 0.7, n_trees), max_len)
    reqs: List[Request] = []
    for t in range(n_trees):
        parent_id = f"tree{t}-root"
        plen = int(max(256, prefix[t]))
        reqs.append(Request(
            request_id=parent_id, arrival=float(arrivals[t]),
            prefix_len=plen, new_len=int(rng.integers(16, 128)),
            decode_len=int(rng.integers(16, 64)),
            prefix_id=f"tree-{t}"))
        for j in range(max(1, branch_factor)):
            if len(reqs) >= n_requests:
                break
            reqs.append(Request(
                request_id=f"tree{t}-b{j}",
                arrival=float(arrivals[t] + think_gap * (1 + j)
                              + rng.exponential(0.05)),
                prefix_len=plen, new_len=int(rng.integers(8, 64)),
                decode_len=int(rng.integers(4, 32)),
                prefix_id=f"tree-{t}",
                meta={"fork_of": parent_id}))
        if len(reqs) >= n_requests:
            break
    reqs = reqs[:n_requests]
    reqs.sort(key=lambda r: (r.arrival, r.request_id))
    return reqs


def fixed_length(n_requests: int, prefix_len: int, *, new_len: int = 128,
                 seed: int = 0, arrival_rate: float = 100.0) -> List[Request]:
    """Uniform-length batch (paper Fig. 6 / Fig. 10 style ablations)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests))
    return [Request(request_id=f"fix-{i}", arrival=float(arrivals[i]),
                    prefix_len=prefix_len, new_len=new_len) for i in range(n_requests)]
