from repro.serving.engine import RealServingEngine, ServingReport, SimServingEngine  # noqa: F401
from repro.serving.kvstore import TieredKVStore  # noqa: F401
from repro.storage import ChunkStore  # noqa: F401
from repro.serving.request import Phase, Request  # noqa: F401
from repro.serving.workloads import (WORKLOADS, agentic_tree,  # noqa: F401
                                     bursty_priority, fixed_length, generate,
                                     multi_tenant)
