"""TTFT / lifecycle / utilization metrics."""
from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Optional

import numpy as np


def percentiles(values: Iterable[float], ps=(50, 90, 99)) -> Dict[str, float]:
    """Percentile summary; empty inputs yield ``None`` values (NOT NaN —
    None serializes as standard-JSON ``null``, NaN is the non-standard
    token default ``json.dumps`` emits and most parsers reject)."""
    arr = np.asarray(sorted(values), np.float64)
    if arr.size == 0:
        return {f"p{p}": None for p in ps} | {"mean": None}
    out = {f"p{p}": float(np.percentile(arr, p)) for p in ps}
    out["mean"] = float(arr.mean())
    return out


def sanitize_json(obj):
    """Recursively replace non-finite floats (NaN/±Inf) with None so the
    structure serializes as strict JSON.  Every report writer pairs this
    with ``json.dumps(..., allow_nan=False)`` — the sanitizer makes the
    payload valid, ``allow_nan`` makes any future regression loud."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: sanitize_json(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize_json(v) for v in obj]
    return obj


def dumps_report(obj, indent: int = 1) -> str:
    """Strict-JSON report serialization: the single path every report
    writer (serve stdout, --metrics-out/--timeline-out, emit_bench) goes
    through, so no emitted file ever carries a bare ``NaN`` token."""
    return json.dumps(sanitize_json(obj), indent=indent, allow_nan=False)


def cdf(values: Iterable[float], n_points: int = 50) -> List[tuple]:
    arr = np.asarray(sorted(values), np.float64)
    if arr.size == 0:
        return []
    qs = np.linspace(0, 100, n_points)
    return [(float(np.percentile(arr, q)), q / 100.0) for q in qs]


def speedup(baseline: Dict[str, float], ours: Dict[str, float], key: str = "mean") -> float:
    return baseline[key] / max(ours[key], 1e-12)


def lifecycle_stats(ttfts: Dict[str, float],
                    e2e: Optional[Dict[str, float]] = None,
                    tpots: Optional[Dict[str, float]] = None,
                    total_tokens: int = 0,
                    makespan: float = 0.0, *,
                    arrivals: Optional[Dict[str, float]] = None,
                    finishes: Optional[Dict[str, float]] = None,
                    offered: int = 0) -> Dict[str, float]:
    """Whole-lifecycle serving summary: the classic TTFT percentiles plus
    end-to-end request latency, per-output-token time (TPOT — for a batched
    decode step this is also the time between tokens, TBT) and generation
    throughput over the run.

    Stream-safe: all rates derive from PER-REQUEST finish events, never from
    the engine's batch-close makespan.  Under continuous batching requests
    retire mid-flight and the offered stream may outlive the measured
    window, so ``makespan`` (which includes the drain tail of whatever
    happened to still be in flight) systematically understates throughput.
    When ``arrivals``/``finishes`` are given the denominator is the active
    serving span — first arrival to last completed finish — and the summary
    additionally reports ``completed``/``offered``/``requests_per_sec``.
    ``makespan`` is only the fallback denominator for legacy callers."""
    out = percentiles(ttfts.values())
    if e2e:
        ep = percentiles(e2e.values())
        out["e2e_mean"] = ep["mean"]
        out["e2e_p99"] = ep["p99"]
    if tpots:
        tp = percentiles(tpots.values())
        out["tpot_mean"] = tp["mean"]
        out["tpot_p99"] = tp["p99"]
    span = makespan
    if finishes:
        t0 = min(arrivals.values()) if arrivals else 0.0
        span = max(finishes.values()) - t0
        out["completed"] = len(finishes)
        out["offered"] = offered or (len(arrivals) if arrivals
                                     else len(finishes))
        if span > 0:
            out["requests_per_sec"] = len(finishes) / span
    if total_tokens and span > 0:
        out["tokens_per_sec"] = total_tokens / span
    return out


def sustained_throughput(arrivals: Dict[str, float],
                         finishes: Dict[str, float],
                         warmup: float = 0.0,
                         drain: float = 0.0) -> Dict[str, float]:
    """Steady-state completion rate over a trimmed measurement window.

    Continuous-batching throughput is only meaningful at steady state: the
    first requests see an empty device (warmup bias) and the last ones see
    a draining queue (no fresh arrivals competing).  The window keeps
    completions with ``warmup <= finish <= horizon - drain`` where the
    horizon is the last arrival; the rate divides by the window length.
    Returns ``{"window", "completed_in_window", "sustained_rps"}`` (zeros
    when the window is empty or degenerate)."""
    if not finishes:
        return {"window": 0.0, "completed_in_window": 0, "sustained_rps": 0.0}
    horizon = max(arrivals.values()) if arrivals else max(finishes.values())
    lo, hi = warmup, horizon - drain
    if hi <= lo:
        lo, hi = 0.0, max(finishes.values())
    done = sum(1 for t in finishes.values() if lo <= t <= hi)
    window = hi - lo
    return {"window": window, "completed_in_window": done,
            "sustained_rps": done / window if window > 0 else 0.0}
