"""TTFT / lifecycle / utilization metrics."""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np


def percentiles(values: Iterable[float], ps=(50, 90, 99)) -> Dict[str, float]:
    arr = np.asarray(sorted(values), np.float64)
    if arr.size == 0:
        return {f"p{p}": float("nan") for p in ps} | {"mean": float("nan")}
    out = {f"p{p}": float(np.percentile(arr, p)) for p in ps}
    out["mean"] = float(arr.mean())
    return out


def cdf(values: Iterable[float], n_points: int = 50) -> List[tuple]:
    arr = np.asarray(sorted(values), np.float64)
    if arr.size == 0:
        return []
    qs = np.linspace(0, 100, n_points)
    return [(float(np.percentile(arr, q)), q / 100.0) for q in qs]


def speedup(baseline: Dict[str, float], ours: Dict[str, float], key: str = "mean") -> float:
    return baseline[key] / max(ours[key], 1e-12)


def lifecycle_stats(ttfts: Dict[str, float],
                    e2e: Optional[Dict[str, float]] = None,
                    tpots: Optional[Dict[str, float]] = None,
                    total_tokens: int = 0,
                    makespan: float = 0.0) -> Dict[str, float]:
    """Whole-lifecycle serving summary: the classic TTFT percentiles plus
    end-to-end request latency, per-output-token time (TPOT — for a batched
    decode step this is also the time between tokens, TBT) and generation
    throughput over the run."""
    out = percentiles(ttfts.values())
    if e2e:
        ep = percentiles(e2e.values())
        out["e2e_mean"] = ep["mean"]
        out["e2e_p99"] = ep["p99"]
    if tpots:
        tp = percentiles(tpots.values())
        out["tpot_mean"] = tp["mean"]
        out["tpot_p99"] = tp["p99"]
    if total_tokens and makespan > 0:
        out["tokens_per_sec"] = total_tokens / makespan
    return out
