"""TTFT / utilization metrics."""
from __future__ import annotations

from typing import Dict, Iterable, List

import numpy as np


def percentiles(values: Iterable[float], ps=(50, 90, 99)) -> Dict[str, float]:
    arr = np.asarray(sorted(values), np.float64)
    if arr.size == 0:
        return {f"p{p}": float("nan") for p in ps} | {"mean": float("nan")}
    out = {f"p{p}": float(np.percentile(arr, p)) for p in ps}
    out["mean"] = float(arr.mean())
    return out


def cdf(values: Iterable[float], n_points: int = 50) -> List[tuple]:
    arr = np.asarray(sorted(values), np.float64)
    if arr.size == 0:
        return []
    qs = np.linspace(0, 100, n_points)
    return [(float(np.percentile(arr, q)), q / 100.0) for q in qs]


def speedup(baseline: Dict[str, float], ours: Dict[str, float], key: str = "mean") -> float:
    return baseline[key] / max(ours[key], 1e-12)
