"""Serving request lifecycle."""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Optional


class Phase(enum.Enum):
    QUEUED = "queued"
    RESTORING = "restoring"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    FAILED = "failed"


@dataclass
class Request:
    request_id: str
    arrival: float
    prefix_len: int                # N_c — cached tokens to restore
    new_len: int                   # fresh suffix tokens to prefill
    decode_len: int = 32           # output tokens to generate
    prefix_id: Optional[str] = None  # shared-prefix key (agentic reuse)
    priority: int = 0              # SLO class; higher preempts lower
                                   # restorations under admission pressure
    deadline: float = math.inf     # wall-clock first-token SLO (EDF mode)
    phase: Phase = Phase.QUEUED
    # timestamps (filled by the engine)
    t_restore_start: Optional[float] = None
    t_restore_end: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    meta: dict = field(default_factory=dict)

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    @property
    def restore_secs(self) -> Optional[float]:
        if self.t_restore_end is None or self.t_restore_start is None:
            return None
        return self.t_restore_end - self.t_restore_start
