"""Serving engines: continuous batching with CacheFlow restoration.

Both engines are thin facades over the SAME shared event loop
(:class:`repro.core.engine_core.EngineCore`) — admission, per-stage compute,
shared I/O channels, failure injection and KV-store tier integration are
decided identically; only the backend differs:

  * ``SimServingEngine``  — ``SimBackend`` advances virtual time with the
    paper's hardware profiles; produces TTFT distributions, utilization and
    baseline comparisons at production scale (the paper's §4 experiments).
  * ``RealServingEngine`` — ``RealBackend`` executes the dispatched ops on
    this host (restoration → suffix prefill → batched decode), wall-clock
    timed and output-verified; the correctness anchor for the simulator's
    claims, including multi-request interleavings.

The whole first-token path runs INSIDE the engine loop: suffix prefill is a
scheduled op competing FCFS with other requests' restoration chunks, and
decode is a recurring batched step — so TTFT = wait + restoration +
*contended* suffix prefill, and the report additionally carries end-to-end
latency, TPOT/TBT and generation throughput.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import HardwareProfile, ModelConfig
from repro.core.baselines import make_baseline_plans, sim_kwargs
from repro.core.boundary import stage_bounds
from repro.core.cost_model import CostModel
from repro.core.engine_core import (EngineCore, EngineRequest, EngineResult,
                                    RealBackend, SimBackend,
                                    interleaving_dur_fn)
from repro.core.executor import RestorationExecutor
from repro.serving.kvstore import TieredKVStore
from repro.serving.metrics import lifecycle_stats, percentiles
from repro.serving.request import Phase, Request


@dataclass
class ServingReport:
    system: str
    ttfts: Dict[str, float]
    restore_secs: Dict[str, float]
    compute_busy: float
    io_busy: float
    stats: dict = field(default_factory=dict)
    e2e: Dict[str, float] = field(default_factory=dict)       # finish - arrival
    tpots: Dict[str, float] = field(default_factory=dict)     # per output token
    decode_busy: float = 0.0
    preemptions: Dict[str, int] = field(default_factory=dict)  # rid -> count
    finishes: Dict[str, float] = field(default_factory=dict)   # rid -> engine t
    arrivals: Dict[str, float] = field(default_factory=dict)   # rid -> engine t
    overlap_decode_restore: float = 0.0   # secs decode and restoration ran
                                          # concurrently (steady-state metric)
    sanitizer: Optional[dict] = None      # SanitizerCounters.as_dict() when
                                          # the run sanitized, else None
    telemetry: Optional[dict] = None      # Telemetry.snapshot() when the
                                          # run collected metrics, else None

    def __post_init__(self):
        if not self.stats:
            self.stats = percentiles(self.ttfts.values())


def _fill_lifecycle(requests: List[Request], res: EngineResult):
    """Map engine-clock lifecycle times back onto the Request objects and
    derive the per-request serving metrics.

    Stream-safe: completion comes from the engine's PER-REQUEST ``finish``
    events — a request that never retired (e.g. the run was truncated) is
    left un-finalized instead of being back-filled from restore completion,
    so downstream rates never count phantom completions."""
    ttfts, restore_secs, e2e, tpots = {}, {}, {}, {}
    arrivals, finishes = {}, {}
    total_tokens = 0
    for r in requests:
        rid = r.request_id
        arrivals[rid] = r.arrival
        fin = res.restore_finish.get(rid)
        if fin is None:
            continue
        start = res.restore_start.get(rid, r.arrival)
        r.t_restore_start, r.t_restore_end = start, fin
        restore_secs[rid] = fin - start
        ft = res.first_token.get(rid)
        if ft is not None:
            r.t_first_token = ft
            ttfts[rid] = ft - r.arrival
        done = res.finish.get(rid)
        if done is None:
            # restored but never retired — still mid-lifecycle
            continue
        r.t_done = done
        r.phase = Phase.DONE
        finishes[rid] = done
        e2e[rid] = done - r.arrival
        n_out = r.decode_len if r.decode_len > 0 else (1 if r.new_len else 0)
        total_tokens += n_out
        if ft is not None and n_out > 1:
            tpots[rid] = (done - ft) / (n_out - 1)
    return ttfts, restore_secs, e2e, tpots, total_tokens, arrivals, finishes


# ---------------------------------------------------------------------------
# Simulation mode
# ---------------------------------------------------------------------------


class SimServingEngine:
    def __init__(self, cfg: ModelConfig, hw: HardwareProfile, *,
                 io_bandwidth: float, system: str = "cacheflow",
                 stages: int = 1, io_channels: int = 1, mfu: float = 0.45,
                 num_chips: int = 1, chunk_size: int = 512,
                 l_delta: Optional[int] = None, max_batch: int = 0,
                 kvstore: Optional[TieredKVStore] = None,
                 channel_slowdown=None, channel_fail_at=None,
                 preempt: str = "none", evict: bool = False,
                 kv_tier: str = "host", admission: str = "continuous",
                 prefetch: bool = False, decode_interference: float = 0.0,
                 sanitize: Optional[bool] = None, telemetry=None):
        self.cfg = cfg
        self.system = system
        self.stages = stages
        self.chunk_size = chunk_size
        self.cost = CostModel(cfg, hw, io_bandwidth, mfu=mfu, num_chips=num_chips,
                              io_channels=1,
                              decode_interference=decode_interference)
        self.l_delta = l_delta if l_delta is not None else self.cost.crossover_l_delta()
        self.io_channels = io_channels
        self.max_batch = max_batch
        self.kvstore = kvstore
        self.channel_slowdown = channel_slowdown
        self.channel_fail_at = channel_fail_at
        self.preempt = preempt
        self.evict = evict
        # which tier returning prefixes start in: "host" models warm reuse,
        # "remote" the paper's cold disaggregated-store regime where
        # restoration time (and hence admission pressure) is real
        self.kv_tier = kv_tier
        self.admission = admission
        self.prefetch = prefetch
        self.sanitize = sanitize
        self.telemetry = telemetry

    def _make_core(self) -> EngineCore:
        kw = sim_kwargs(self.system)
        return EngineCore(
            SimBackend(self.cost), stages=self.stages,
            io_channels=self.io_channels, max_active=self.max_batch,
            channel_slowdown=self.channel_slowdown,
            channel_fail_at=self.channel_fail_at,
            kvstore=self.kvstore, preempt=self.preempt, evict=self.evict,
            admission=self.admission, prefetch=self.prefetch,
            sanitize=self.sanitize, telemetry=self.telemetry, **kw)

    def run(self, requests: List[Request], trace=None) -> ServingReport:
        """Drive every request through its whole lifecycle (restore →
        contended suffix prefill → batched decode) on the shared loop.

        ``trace``: optional ``TraceRecorder`` capturing the schedule for
        deterministic replay (see :mod:`repro.core.trace`)."""
        bounds = (stage_bounds(self.cfg.num_layers, self.stages)
                  if self.stages > 1 else None)
        engine_reqs = []
        for r in requests:
            plans = make_baseline_plans(
                self.system, r.request_id, r.prefix_len,
                chunk_size=self.chunk_size, l_delta=self.l_delta,
                num_layers=self.cfg.num_layers, stage_bounds=bounds)
            engine_reqs.append(EngineRequest(r.request_id, r.prefix_len,
                                             arrival=r.arrival, plans=plans,
                                             new_len=r.new_len,
                                             decode_len=r.decode_len,
                                             priority=r.priority,
                                             deadline=r.deadline))
            if self.kvstore is not None:
                self.kvstore.put(r.request_id,
                                 r.prefix_len * self.cfg.kv_bytes_per_token(),
                                 tier=self.kv_tier)
        core = self._make_core()
        res = core.run(engine_reqs, trace=trace)
        san = core.last_sanitizer
        tel = core.last_telemetry
        ttfts, restore_secs, e2e, tpots, total, arrivals, finishes = \
            _fill_lifecycle(requests, res)
        return ServingReport(self.system, ttfts, restore_secs,
                             res.compute_busy, res.io_busy,
                             e2e=e2e, tpots=tpots, decode_busy=res.decode_busy,
                             preemptions=dict(res.preemptions),
                             arrivals=arrivals, finishes=finishes,
                             overlap_decode_restore=res.overlap_decode_restore,
                             sanitizer=(san.counters.as_dict()
                                        if san is not None else None),
                             telemetry=(tel.snapshot()
                                        if tel is not None else None),
                             stats=lifecycle_stats(
                                 ttfts, e2e, tpots, total, res.makespan,
                                 arrivals=arrivals, finishes=finishes,
                                 offered=len(requests)))


# ---------------------------------------------------------------------------
# Real mode (small models, wall clock, output-verified)
# ---------------------------------------------------------------------------


class RealServingEngine:
    def __init__(self, model, params, *, system: str = "cacheflow",
                 stages: int = 1, chunk_size: int = 16, l_delta: int = 64,
                 seed: int = 0, io_channels: int = 1, max_batch: int = 0,
                 kvstore: Optional[TieredKVStore] = None,
                 preempt: str = "none", evict: bool = False,
                 admission: str = "continuous", prefetch: bool = False,
                 datapath: str = "fused", sanitize: Optional[bool] = None,
                 telemetry=None):
        self.model = model
        self.params = params
        self.system = system
        self.stages = stages
        self.chunk_size = chunk_size
        self.l_delta = l_delta
        self.io_channels = io_channels
        self.max_batch = max_batch
        self.kvstore = kvstore
        self.preempt = preempt
        self.evict = evict
        self.admission = admission
        self.prefetch = prefetch
        self.sanitize = sanitize
        self.telemetry = telemetry
        # a MATERIALIZED store (repro.storage.ChunkStore) plugs in as both
        # the engine-core kvstore (residency/bandwidth/dedup-hit protocol)
        # and the executor's byte source: load ops then move real chunk
        # bytes out of its tiers instead of copying ground truth
        materialized = getattr(kvstore, "materialized", False)
        # "fused" (default) restores through core/datapath.py: per-channel
        # double-buffered transfer streams + one dequant-scatter launch per
        # load op; "legacy" keeps the per-chunk `.at[].set()` baseline.  A
        # prebuilt RestoreDatapath may be passed directly.
        dp = None
        if materialized and datapath not in (None, "legacy"):
            if datapath == "fused":
                from repro.core.datapath import RestoreDatapath
                dp = RestoreDatapath.for_channels(io_channels)
            else:
                dp = datapath
        self.datapath = dp
        self.executor = RestorationExecutor(
            model, params, chunk_size=chunk_size, stages=stages,
            chunk_store=kvstore if materialized else None, datapath=dp)
        self._rng = jax.random.PRNGKey(seed)

    def _inputs(self, n: int):
        cfg = self.model.cfg
        if cfg.input_mode == "tokens":
            return jax.random.randint(self._rng, (1, n), 0, cfg.vocab_size)
        return jax.random.normal(self._rng, (1, n, cfg.d_model), jnp.float32)

    def remember(self, r: Request):
        """Previous-turn prefill: persist KV + boundaries for the request."""
        self.executor.remember(r.request_id, self._inputs(r.prefix_len))
        if self.kvstore is not None and \
                not getattr(self.kvstore, "materialized", False):
            # the materialized store already holds the real chunk bytes
            # (executor.remember wrote them); only the sim-model store
            # needs a virtual whole-request placement
            self.kvstore.put(r.request_id,
                             r.prefix_len * self.model.cfg.kv_bytes_per_token())

    def fork(self, parent_rid: str, child_rid: str):
        """O(1) session fork: the child aliases the parent's stored prefix
        (shared arrays + chunk-chain refcount bumps + CoW block tables on
        device) instead of re-running prefill — how an agentic tree search
        speculates K branches off one live context.  Requests carrying
        ``meta={"fork_of": parent_id}`` take this path in :meth:`serve`."""
        return self.executor.fork(parent_rid, child_rid)

    def _make_plans(self, r: Request, bounds):
        cfg = self.model.cfg
        strategy = "layer" if cfg.rwkv is not None else None
        return make_baseline_plans(
            self.system, r.request_id, r.prefix_len,
            chunk_size=self.chunk_size,
            l_delta=self.l_delta if strategy is None else 10**9,
            num_layers=cfg.num_layers, stage_bounds=bounds)

    def serve(self, requests: List[Request], *, verify: bool = True,
              op_order: str = "measured",
              rng: Optional[np.random.Generator] = None,
              trace=None) -> ServingReport:
        """Drive ALL requests through the shared engine core for their whole
        lifecycle: concurrent restoration (continuous batching), per-stage
        suffix prefill competing FCFS with restoration chunks, and recurring
        batched decode steps — every op executes on device.

        ``verify=True`` checks each restored cache against its full-prefill
        ground truth the moment restoration completes (before the suffix
        touches the cache); per-request first-token logits and greedy decode
        outputs are retrievable via ``self.executor.outputs(rid)``.

        op_order="measured" drives the schedule with real measured op
        durations; the other modes (see ``interleaving_dur_fn``) randomize
        the multi-request interleaving for correctness testing.

        Reported times are ENGINE-CLOCK times: measured per-op durations
        arranged on the engine's resource model, where compute, I/O and
        decode overlap as they would on parallel hardware — this host
        executes ops serially, so the true serial wall time for the whole
        batch is reported separately as ``stats["serve_wall"]``.

        ``trace``: optional ``TraceRecorder`` capturing the lifecycle
        schedule for deterministic replay (see :mod:`repro.core.trace`)."""
        cfg = self.model.cfg
        bounds = (stage_bounds(cfg.num_layers, self.stages)
                  if self.stages > 1 else None)
        engine_reqs = []
        for r in requests:
            if r.request_id not in self.executor.store:
                parent = r.meta.get("fork_of") if r.meta else None
                if parent is not None and parent in self.executor.store:
                    if self.executor.store.get(parent).n_tokens != r.prefix_len:
                        raise ValueError(
                            f"fork {r.request_id}: prefix_len {r.prefix_len} "
                            f"!= parent {parent} stored length "
                            f"{self.executor.store.get(parent).n_tokens}")
                    self.fork(parent, r.request_id)
                else:
                    self.remember(r)
            r.phase = Phase.RESTORING
            if r.new_len > 0 or r.decode_len > 0:
                suffix = self._inputs(r.new_len) if r.new_len > 0 else None
                self.executor.set_suffix(r.request_id, suffix,
                                         decode_len=r.decode_len)
            engine_reqs.append(EngineRequest(r.request_id, r.prefix_len,
                                             arrival=r.arrival,
                                             plans=self._make_plans(r, bounds),
                                             new_len=r.new_len,
                                             decode_len=r.decode_len,
                                             priority=r.priority,
                                             deadline=r.deadline))
        # a quantized chunk store's restored KV carries its documented int8
        # error on top of the chunked-recompute tolerance
        atol = None
        if getattr(self.kvstore, "materialized", False) \
                and self.kvstore.quant != "none":
            atol = 2e-2 + self.kvstore.quant_tolerance()
        backend = RealBackend(self.executor,
                              dur_fn=interleaving_dur_fn(op_order, rng),
                              verify=verify, verify_atol=atol)
        core = EngineCore(backend, stages=self.stages,
                          io_channels=self.io_channels,
                          max_active=self.max_batch, kvstore=self.kvstore,
                          preempt=self.preempt, evict=self.evict,
                          admission=self.admission, prefetch=self.prefetch,
                          sanitize=self.sanitize, telemetry=self.telemetry,
                          strict=True)
        t0 = time.perf_counter()
        res = core.run(engine_reqs, trace=trace)
        serve_wall = time.perf_counter() - t0
        san = core.last_sanitizer
        tel = core.last_telemetry
        ttfts, restore_secs, e2e, tpots, total, arrivals, finishes = \
            _fill_lifecycle(requests, res)
        for r in requests:
            if r.new_len > 0:
                out = self.executor.outputs(r.request_id)
                assert np.isfinite(np.asarray(out["first_logits"])).all()
        return ServingReport(self.system, ttfts, restore_secs,
                             res.compute_busy, res.io_busy,
                             e2e=e2e, tpots=tpots, decode_busy=res.decode_busy,
                             preemptions=dict(res.preemptions),
                             arrivals=arrivals, finishes=finishes,
                             overlap_decode_restore=res.overlap_decode_restore,
                             sanitizer=(san.counters.as_dict()
                                        if san is not None else None),
                             telemetry=(tel.snapshot()
                                        if tel is not None else None),
                             stats=lifecycle_stats(
                                 ttfts, e2e, tpots, total, res.makespan,
                                 arrivals=arrivals, finishes=finishes,
                                 offered=len(requests))
                             | {"serve_wall": serve_wall})
