"""Serving engines: continuous batching with CacheFlow restoration.

Both engines are thin facades over the SAME shared event loop
(:class:`repro.core.engine_core.EngineCore`) — admission, per-stage compute,
shared I/O channels, failure injection and KV-store tier integration are
decided identically; only the backend differs:

  * ``SimServingEngine``  — ``SimBackend`` advances virtual time with the
    paper's hardware profiles; produces TTFT distributions, utilization and
    baseline comparisons at production scale (the paper's §4 experiments).
  * ``RealServingEngine`` — ``RealBackend`` executes the dispatched ops on
    this host (restoration executor → suffix prefill), wall-clock timed and
    output-verified; the correctness anchor for the simulator's claims,
    including multi-request interleavings.

TTFT = wait + restoration + suffix prefill (the first output token comes out
of the suffix prefill step).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import HardwareProfile, ModelConfig
from repro.core.baselines import make_baseline_plans, sim_kwargs
from repro.core.boundary import stage_bounds
from repro.core.cost_model import CostModel
from repro.core.engine_core import (EngineCore, EngineRequest, RealBackend,
                                    SimBackend, interleaving_dur_fn)
from repro.core.executor import RestorationExecutor
from repro.serving.kvstore import TieredKVStore
from repro.serving.metrics import percentiles
from repro.serving.request import Phase, Request


@dataclass
class ServingReport:
    system: str
    ttfts: Dict[str, float]
    restore_secs: Dict[str, float]
    compute_busy: float
    io_busy: float
    stats: dict = field(default_factory=dict)

    def __post_init__(self):
        if not self.stats:
            self.stats = percentiles(self.ttfts.values())


# ---------------------------------------------------------------------------
# Simulation mode
# ---------------------------------------------------------------------------


class SimServingEngine:
    def __init__(self, cfg: ModelConfig, hw: HardwareProfile, *,
                 io_bandwidth: float, system: str = "cacheflow",
                 stages: int = 1, io_channels: int = 1, mfu: float = 0.45,
                 num_chips: int = 1, chunk_size: int = 512,
                 l_delta: Optional[int] = None, max_batch: int = 0,
                 kvstore: Optional[TieredKVStore] = None,
                 channel_slowdown=None, channel_fail_at=None):
        self.cfg = cfg
        self.system = system
        self.stages = stages
        self.chunk_size = chunk_size
        self.cost = CostModel(cfg, hw, io_bandwidth, mfu=mfu, num_chips=num_chips,
                              io_channels=1)
        self.l_delta = l_delta if l_delta is not None else self.cost.crossover_l_delta()
        self.io_channels = io_channels
        self.max_batch = max_batch
        self.kvstore = kvstore
        self.channel_slowdown = channel_slowdown
        self.channel_fail_at = channel_fail_at

    def _make_core(self) -> EngineCore:
        kw = sim_kwargs(self.system)
        return EngineCore(
            SimBackend(self.cost), stages=self.stages,
            io_channels=self.io_channels, max_active=self.max_batch,
            channel_slowdown=self.channel_slowdown,
            channel_fail_at=self.channel_fail_at,
            kvstore=self.kvstore, **kw)

    def run(self, requests: List[Request], trace=None) -> ServingReport:
        """``trace``: optional ``TraceRecorder`` capturing the restoration
        schedule for deterministic replay (see :mod:`repro.core.trace`)."""
        bounds = (stage_bounds(self.cfg.num_layers, self.stages)
                  if self.stages > 1 else None)
        engine_reqs = []
        for r in requests:
            plans = make_baseline_plans(
                self.system, r.request_id, r.prefix_len,
                chunk_size=self.chunk_size, l_delta=self.l_delta,
                num_layers=self.cfg.num_layers, stage_bounds=bounds)
            engine_reqs.append(EngineRequest(r.request_id, r.prefix_len,
                                             arrival=r.arrival, plans=plans))
            if self.kvstore is not None:
                self.kvstore.put(r.request_id,
                                 r.prefix_len * self.cfg.kv_bytes_per_token())
        res = self._make_core().run(engine_reqs, trace=trace)
        ttfts, restore_secs = {}, {}
        for r in requests:
            fin = res.restore_finish.get(r.request_id)
            if fin is None:
                continue
            suffix = self.cost.t_comp_range(r.prefix_len, r.prefix_len + r.new_len,
                                            chunks=1)
            r.t_restore_start = res.restore_start.get(r.request_id, r.arrival)
            r.t_restore_end = fin
            r.t_first_token = fin + suffix
            r.phase = Phase.DECODE
            ttfts[r.request_id] = r.t_first_token - r.arrival
            restore_secs[r.request_id] = fin - r.t_restore_start
        return ServingReport(self.system, ttfts, restore_secs,
                             res.compute_busy, res.io_busy)


# ---------------------------------------------------------------------------
# Real mode (small models, wall clock, output-verified)
# ---------------------------------------------------------------------------


class RealServingEngine:
    def __init__(self, model, params, *, system: str = "cacheflow",
                 stages: int = 1, chunk_size: int = 16, l_delta: int = 64,
                 seed: int = 0, io_channels: int = 1, max_batch: int = 0,
                 kvstore: Optional[TieredKVStore] = None):
        self.model = model
        self.params = params
        self.system = system
        self.stages = stages
        self.chunk_size = chunk_size
        self.l_delta = l_delta
        self.io_channels = io_channels
        self.max_batch = max_batch
        self.kvstore = kvstore
        self.executor = RestorationExecutor(model, params, chunk_size=chunk_size,
                                            stages=stages)
        self._rng = jax.random.PRNGKey(seed)

    def _inputs(self, n: int):
        cfg = self.model.cfg
        if cfg.input_mode == "tokens":
            return jax.random.randint(self._rng, (1, n), 0, cfg.vocab_size)
        return jax.random.normal(self._rng, (1, n, cfg.d_model), jnp.float32)

    def remember(self, r: Request):
        """Previous-turn prefill: persist KV + boundaries for the request."""
        self.executor.remember(r.request_id, self._inputs(r.prefix_len))
        if self.kvstore is not None:
            self.kvstore.put(r.request_id,
                             r.prefix_len * self.model.cfg.kv_bytes_per_token())

    def _make_plans(self, r: Request, bounds):
        cfg = self.model.cfg
        strategy = "layer" if cfg.rwkv is not None else None
        return make_baseline_plans(
            self.system, r.request_id, r.prefix_len,
            chunk_size=self.chunk_size,
            l_delta=self.l_delta if strategy is None else 10**9,
            num_layers=cfg.num_layers, stage_bounds=bounds)

    def serve(self, requests: List[Request], *, verify: bool = True,
              op_order: str = "measured",
              rng: Optional[np.random.Generator] = None,
              trace=None) -> ServingReport:
        """Restore ALL requests concurrently through the shared engine core
        (continuous batching), then verify + suffix-prefill each.

        op_order="measured" drives the schedule with real measured op
        durations; the other modes (see ``interleaving_dur_fn``) randomize
        the multi-request interleaving for correctness testing.

        Reported ``ttfts`` are ENGINE-CLOCK times: measured per-op durations
        arranged on the engine's resource model, where compute and I/O
        overlap as they would on parallel hardware — this host executes ops
        serially, so the true serial wall time for the whole batch is
        reported separately as ``stats["restore_wall"]``.

        ``trace``: optional ``TraceRecorder`` capturing the restoration
        schedule for deterministic replay (see :mod:`repro.core.trace`)."""
        cfg = self.model.cfg
        bounds = (stage_bounds(cfg.num_layers, self.stages)
                  if self.stages > 1 else None)
        engine_reqs = []
        for r in requests:
            if r.request_id not in self.executor.store:
                self.remember(r)
            r.phase = Phase.RESTORING
            engine_reqs.append(EngineRequest(r.request_id, r.prefix_len,
                                             arrival=r.arrival,
                                             plans=self._make_plans(r, bounds)))
        backend = RealBackend(self.executor,
                              dur_fn=interleaving_dur_fn(op_order, rng))
        core = EngineCore(backend, stages=self.stages,
                          io_channels=self.io_channels,
                          max_active=self.max_batch, kvstore=self.kvstore,
                          strict=True)
        t0 = time.perf_counter()
        res = core.run(engine_reqs, trace=trace)
        restore_wall = time.perf_counter() - t0
        ttfts, restore_secs = {}, {}
        for r in requests:
            if verify:
                self.executor.verify(r.request_id)  # raises on any mismatch
            r.phase = Phase.PREFILL
            tp = time.perf_counter()
            logits = self.executor.first_token_logits(
                r.request_id, self._inputs(r.new_len))
            jax.block_until_ready(logits)
            prefill_wall = time.perf_counter() - tp
            assert np.isfinite(np.asarray(logits)).all()
            fin = res.restore_finish[r.request_id]
            start = res.restore_start.get(r.request_id, r.arrival)
            r.t_restore_start, r.t_restore_end = start, fin
            restore_secs[r.request_id] = fin - start
            # engine-clock queue+restore (measured op durations) + real prefill
            ttfts[r.request_id] = (fin - r.arrival) + prefill_wall
            r.t_first_token = r.arrival + ttfts[r.request_id]
            r.phase = Phase.DONE
        return ServingReport(self.system, ttfts, restore_secs,
                             res.compute_busy, res.io_busy,
                             stats=percentiles(ttfts.values())
                             | {"restore_wall": restore_wall})
