from repro.storage.chunkstore import (CHUNK_TIERS, ChunkStore,  # noqa: F401
                                      chunk_hash_chain)
from repro.storage.placement import PlacementCore, Tier  # noqa: F401
