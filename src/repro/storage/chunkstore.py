"""Materialized, content-addressed, chunk-granular KV storage.

Unlike the sim-mode :class:`~repro.serving.kvstore.TieredKVStore` (a
bandwidth/capacity *model* over whole-request placeholders), this store
actually holds tensor bytes.  A stored chunk is the attention-KV slice
(k/v or MLA ckv, plus kpos) of one ``chunk_size``-token span of a prefix,
keyed by a prefix-chained content hash::

    h_0 = sha256(salt)            h_i = sha256(h_{i-1} || tokens_i)

so chunk ``i`` names the KV of tokens [i·C, (i+1)·C) *given its entire
prefix* — exactly the dependence structure of causal attention.  Two
requests sharing a prefix hash to the same chunks and dedup to ONE stored
copy with a refcount (vLLM-style prefix caching, here across the storage
tiers of the CacheFlow restoration path).

Tiers (placement/accounting shared with the sim store via
:class:`~repro.storage.placement.PlacementCore`):

  * ``hbm``  — a block in the shared device-side
    :class:`~repro.models.kvcache.BlockPool` (``chunk_size`` tokens ==
    one block): requests sharing a prefix alias the SAME physical block
    on device, and the restoration executor's per-request
    ``PagedKVCache`` tables map these blocks directly (the load ops copy
    straight out of the pool view; a chunk resident here costs NO
    transfer — the engine core skips the I/O channel entirely, a *dedup
    hit*).  ``fork_request`` forks a whole chain O(1) by refcount bumps;
  * ``host`` — DRAM numpy buffers; with ``quant="int8"`` the chunk is
    stored per-channel int8-quantized (``kernels/kv_quant``), so demotion
    compresses and promotion dequantizes — transfers move ~half the bytes;
  * ``disk`` — serialized ``.npz`` bytes, written under ``store_dir`` when
    given (a real on-disk tier) or held as in-memory blobs otherwise.

Eviction is benefit-aware: the victim is the chunk with the least
restoration benefit per byte — ``refcount × recompute-cost(t0,t1) /
nbytes`` (causal attention makes late chunks quadratically more expensive
to recompute, and shared chunks save that cost for every referent);
refcount-0 chunks go first.  Only the bottom tier drops bytes; a dropped
chunk is simply a future ``store miss`` and restoration falls back to
recompute/ground-truth.

Quantization is one-way per chunk: the int8 form becomes authoritative on
first demotion, and promotion to HBM keeps that sub-HBM encoding alive as
a *shadow* — a later demotion to a same-precision tier reuses the shadow
instead of re-encoding from the decoded bf16 view, so demote/promote
cycles are drift-free after the first quantization.  ``quant="none"``
round-trips bit-exactly through every tier — the restoration served from
this store then bit-matches the full-prefill reference.

The fused restoration datapath (``core/datapath.py``) consumes chunks in
their *stored* encoding via ``fetch_packed`` / ``fetch_range_packed`` —
int8 bytes + scales cross the host→device wire and are dequantized on
device by the ``kv_restore`` kernel — and lands the HBM pool block from
the already-staged device arrays via ``promote_staged`` (no second
host→device copy).  The legacy per-chunk ``fetch`` path decodes on the
host first; both paths share byte/hit/miss accounting exactly.
"""
from __future__ import annotations

import hashlib
import io
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.kv_quant import kv_dequantize, kv_quantize
from repro.models.kvcache import BlockPool
from repro.storage.placement import PlacementCore, Tier

CHUNK_TIERS = ("hbm", "host", "disk")
ATTN_FIELDS = ("k", "v", "ckv")


def chunk_hash_chain(inputs, chunk_size: int, salt: str = "") -> List[str]:
    """Prefix-chained content hashes of the token chunks of ``inputs``
    ((1, N) tokens or (1, N, D) embeddings)."""
    arr = np.ascontiguousarray(np.asarray(inputs))
    n = arr.shape[1]
    h = hashlib.sha256(salt.encode()).digest()
    keys = []
    for t0 in range(0, n, chunk_size):
        h = hashlib.sha256(h + arr[:, t0:t0 + chunk_size].tobytes()).digest()
        keys.append(h.hex())
    return keys


@dataclass
class _Chunk:
    tokens: Tuple[int, int]
    fields: Tuple[str, ...]           # float KV fields present (k/v or ckv)
    dtypes: Dict[str, object]
    raw_nbytes: int
    quant_nbytes: int
    refcount: int = 0
    # live representations; at most the placed tier's is authoritative
    reprs: dict = field(default_factory=dict)   # "hbm"|"host"|"disk" -> payload


class ChunkStore:
    """Chunk-granular KV store frontend over the shared placement core.

    Implements the engine-core kvstore protocol (``touch`` / ``promote`` /
    ``bandwidth_for`` / ``io_resident`` / ``note_io_hit``) keyed by request
    id, mapping each request to its chunk chain."""

    materialized = True               # serving engines skip the sim-put path

    def __init__(self, *, chunk_size: int = 16,
                 hbm_bw: float = 819e9, hbm_cap: float = 1 << 30,
                 host_bw: float = 100e9, host_cap: float = 1 << 33,
                 disk_bw: float = 10e9 / 8, disk_cap: float = 1 << 40,
                 quant: str = "none", store_dir: Optional[str] = None,
                 eviction: str = "benefit", default_tier: str = "host",
                 salt: str = ""):
        if quant not in ("none", "int8"):
            raise ValueError(f"unknown quant mode {quant!r}")
        if eviction not in ("benefit", "lru"):
            raise ValueError(f"unknown eviction policy {eviction!r}")
        if default_tier == "remote":          # TieredKVStore vocabulary
            default_tier = "disk"
        if default_tier not in CHUNK_TIERS:
            raise ValueError(f"unknown tier {default_tier!r}")
        self.chunk_size = chunk_size
        self.quant = quant
        self.store_dir = store_dir
        self.default_tier = default_tier
        self.salt = salt
        if store_dir:
            os.makedirs(store_dir, exist_ok=True)
        self.core = PlacementCore(
            [Tier("hbm", hbm_bw, hbm_cap), Tier("host", host_bw, host_cap),
             Tier("disk", disk_bw, disk_cap)],
            size_fn=self._size, move_fn=self._move, drop_fn=self._drop,
            victim_fn=self._benefit if eviction == "benefit" else None)
        # device-side block pool backing the hbm tier: one chunk == one
        # block, so an hbm repr is a block id and every request table
        # aliasing the chunk shares ONE physical copy (CoW on writes)
        self.pool = BlockPool(chunk_size)
        self.chunks: Dict[str, _Chunk] = {}
        # device payloads staged by the fused datapath, consumed by _move's
        # hbm branch so promotion reuses the bytes already on device
        self._staged_dev: Dict[str, dict] = {}
        self.requests: Dict[str, List[str]] = {}   # rid -> chunk key chain
        # accounting (benchmarks/tests read these)
        self.dedup_hits = 0
        self.bytes_deduped = 0
        self.forks = 0                   # O(1) session forks (fork_request)
        self.puts = 0
        self.fetches = 0                 # chunk transfers out of host/disk
        self.io_hits = 0                 # fetches served from the hbm view
        self.skipped_transfers = 0       # engine-level channel skips
        self.bytes_put = 0
        self.bytes_transferred = 0       # bytes moved toward HBM (post-quant)
        self.store_misses = 0
        self.max_scale = 0.0             # worst per-channel int8 scale seen

    # ------------------------------------------------------------------
    # Placement-core callbacks
    # ------------------------------------------------------------------
    def _size(self, key: str, tier: str) -> float:
        c = self.chunks[key]
        if tier != "hbm" and self.quant == "int8":
            return c.quant_nbytes
        return c.raw_nbytes

    def _benefit(self, key: str) -> float:
        """Restoration benefit density: recompute cost saved per stored
        byte.  Causal attention makes a chunk over [t0, t1) cost
        O(t1² − t0²) to recompute; every referent saves that."""
        c = self.chunks[key]
        t0, t1 = c.tokens
        return c.refcount * (t1 * t1 - t0 * t0 + (t1 - t0)) \
            / max(1, c.raw_nbytes)

    def _move(self, key: str, src: Optional[str], dst: str):
        c = self.chunks[key]
        if dst not in c.reprs:
            if dst == "hbm":
                # the hbm repr is a pool BLOCK ID (the store holds one pool
                # ref; request block tables aliasing the chunk hold more)
                staged = self._staged_dev.pop(key, None)
                c.reprs["hbm"] = self.pool.alloc(
                    staged if staged is not None
                    else self._decode_device(key))
            elif dst == "host":
                c.reprs["host"] = self._encode_host(key)
            else:
                c.reprs["disk"] = self._encode_disk(key)
        for t in (*CHUNK_TIERS, "raw"):
            if t == dst or t not in c.reprs:
                continue
            if dst == "hbm" and self.quant == "int8" and t in ("host",
                                                              "disk"):
                # keep the authoritative int8 encoding as a shadow across
                # the promote: demoting back to a same-precision tier
                # reuses it instead of requantizing the decoded bf16 view
                # (which drifted one LSB per demote/promote cycle)
                continue
            self._del_repr(key, t)

    def _drop(self, key: str, src: Optional[str]):
        c = self.chunks.pop(key, None)
        if c is not None:
            for t in list(c.reprs):
                self._del_repr_obj(c, t)
        # the key stays in request chains: fetching it later is a store
        # miss and restoration falls back to recompute/ground truth

    def _del_repr(self, key: str, tier: str):
        self._del_repr_obj(self.chunks[key], tier)

    def _del_repr_obj(self, c: _Chunk, tier: str):
        rep = c.reprs.pop(tier, None)
        if tier == "hbm" and rep is not None:
            # release the STORE's pool ref; the physical block outlives the
            # hbm placement while any request block table still aliases it
            # (demotion/eviction never invalidates a live table)
            self.pool.decref(rep)
        if tier == "disk" and isinstance(rep, str) and os.path.exists(rep):
            os.remove(rep)

    # ------------------------------------------------------------------
    # Representation codecs
    # ------------------------------------------------------------------
    def _host_payload(self, key: str) -> dict:
        """The chunk as its host-tier encoding: raw numpy (quant="none")
        or {"kpos", f: {"q", "scales"}} (quant="int8")."""
        c = self.chunks[key]
        if "host" in c.reprs:
            return c.reprs["host"]
        if "disk" in c.reprs:
            return self._read_disk(c.reprs["disk"], c)
        if "raw" in c.reprs:                 # staged put, not yet placed
            raw = c.reprs["raw"]
        else:
            dev = self.pool.read(c.reprs["hbm"])
            t0, t1 = c.tokens
            n = t1 - t0                      # strip block padding (tail chunk)
            raw = {f: np.asarray(dev[f][:, :, :n]) for f in c.fields}
            raw["kpos"] = np.asarray(dev["kpos"][:, :n])
        return self._quantize(raw) if self.quant == "int8" else raw

    def _encode_host(self, key: str) -> dict:
        return self._host_payload(key)

    def _quantize(self, raw: dict) -> dict:
        out = {"kpos": raw["kpos"]}
        for f, arr in raw.items():
            if f == "kpos":
                continue
            q, scales = kv_quantize(jnp.asarray(arr))
            self.max_scale = max(self.max_scale, float(jnp.max(scales)))
            out[f] = {"q": np.asarray(q), "scales": np.asarray(scales)}
        return out

    def _decode_device(self, key: str) -> dict:
        """The chunk as device arrays in its original dtypes (the HBM view
        restoration load ops copy from)."""
        c = self.chunks[key]
        if "raw" in c.reprs:
            # a freshly-put chunk landing straight in HBM must NOT round-
            # trip through the quantizer: quantization applies only to
            # sub-HBM encodings (first demotion makes the int8 form
            # authoritative — never before)
            raw = c.reprs["raw"]
            dev = {"kpos": jnp.asarray(raw["kpos"])}
            for f in c.fields:
                dev[f] = jnp.asarray(raw[f])
            return dev
        host = self._host_payload(key)
        dev = {"kpos": jnp.asarray(host["kpos"])}
        for f in c.fields:
            rep = host[f]
            if isinstance(rep, dict):              # quantized
                dev[f] = kv_dequantize(jnp.asarray(rep["q"]),
                                       jnp.asarray(rep["scales"]),
                                       dtype=c.dtypes[f])
            else:
                dev[f] = jnp.asarray(rep)
        return dev

    def _flatten_host(self, host: dict) -> dict:
        flat = {"kpos": host["kpos"]}
        for f, rep in host.items():
            if f == "kpos":
                continue
            if isinstance(rep, dict):
                flat[f + "__q"] = rep["q"]
                flat[f + "__scales"] = rep["scales"]
            else:
                flat[f + "__raw"] = rep
        return flat

    def _encode_disk(self, key: str):
        flat = self._flatten_host(self._host_payload(key))
        # bf16 has no numpy dtype: store a raw byte view + dtype tag
        packed = {}
        for k, a in flat.items():
            a = np.ascontiguousarray(np.asarray(a))
            packed[k] = a.view(np.uint8) if a.dtype.kind == "V" else a
        if self.store_dir:
            path = os.path.join(self.store_dir, key + ".npz")
            np.savez(path, **packed)
            return path
        buf = io.BytesIO()
        np.savez(buf, **packed)
        return buf.getvalue()

    def _read_disk(self, rep, c: _Chunk) -> dict:
        src = rep if isinstance(rep, str) else io.BytesIO(rep)
        with np.load(src) as z:
            flat = {k: z[k] for k in z.files}
        host = {"kpos": flat["kpos"]}
        for f in c.fields:
            if f + "__q" in flat:
                host[f] = {"q": flat[f + "__q"], "scales": flat[f + "__scales"]}
            else:
                arr = flat[f + "__raw"]
                dt = np.dtype(c.dtypes[f])
                if dt.kind == "V":       # bf16 was stored as a uint8 view
                    arr = arr.view(dt)
                host[f] = arr
        return host

    # ------------------------------------------------------------------
    # Request-facing API
    # ------------------------------------------------------------------
    def put_request(self, rid: str, inputs, cache: dict,
                    tier: Optional[str] = None) -> List[str]:
        """Store a request's prefix KV as content-addressed chunks; chunks
        another request already stored dedup to a refcount bump.  Returns
        the chunk key chain."""
        keys = chunk_hash_chain(inputs, self.chunk_size, self.salt)
        fields = tuple(f for f in ATTN_FIELDS if f in cache)
        if not fields:
            raise ValueError("cache has no attention KV fields to store")
        n = int(np.asarray(inputs).shape[1])
        if rid in self.requests:
            self.free_request(rid)
        for ci, key in enumerate(keys):
            t0, t1 = ci * self.chunk_size, min(n, (ci + 1) * self.chunk_size)
            c = self.chunks.get(key)
            if c is not None:
                c.refcount += 1
                self.dedup_hits += 1
                self.bytes_deduped += c.raw_nbytes
                self.core.touch(key)
                continue
            raw = {f: np.asarray(cache[f][:, :, t0:t1]) for f in fields}
            raw["kpos"] = np.asarray(cache["kpos"][:, t0:t1])
            raw_nb = sum(a.nbytes for a in raw.values())
            quant_nb = raw["kpos"].nbytes + sum(
                raw[f].size + raw[f].shape[-1] * 4 for f in fields)
            c = _Chunk((t0, t1), fields,
                       {f: cache[f].dtype for f in fields}, raw_nb, quant_nb,
                       refcount=1)
            # stage the exact payload; the placement's move_fn encodes it
            # for whatever tier the chunk actually lands in (quantization
            # only happens when a sub-HBM encoding is needed)
            c.reprs["raw"] = raw
            self.chunks[key] = c
            self.puts += 1
            self.bytes_put += raw_nb
            self.core.put(key, tier or self.default_tier)
        self.requests[rid] = keys
        return keys

    def fork_request(self, parent: str, child: str) -> List[str]:
        """O(1) session fork: the child references the parent's exact
        chunk chain — refcount bumps only, zero bytes staged, moved or
        copied.  Counted as dedup hits (the bytes the fork did NOT copy
        feed ``bytes_deduped``)."""
        keys = self.requests[parent]
        if child in self.requests:
            self.free_request(child)
        for key in keys:
            c = self.chunks.get(key)
            if c is None:
                continue                 # dropped chunk: future store miss
            c.refcount += 1
            self.dedup_hits += 1
            self.bytes_deduped += c.raw_nbytes
            self.core.touch(key)
        self.requests[child] = list(keys)
        self.forks += 1
        return list(keys)

    def free_request(self, rid: str):
        """Drop a request's reference to its chunks.  Chunks at refcount 0
        stay stored (prefix cache) but evict first (zero benefit)."""
        for key in self.requests.pop(rid, ()):
            c = self.chunks.get(key)
            if c is None:
                continue                 # already dropped from the bottom tier
            if c.refcount <= 0:
                raise AssertionError(f"negative refcount for chunk {key}")
            c.refcount -= 1

    def block_of(self, key: str) -> Optional[int]:
        """The pool block id backing an HBM-resident chunk (None when the
        chunk sits below HBM) — what request block tables alias."""
        c = self.chunks.get(key)
        if c is None or self.core.tier_of(key) != "hbm":
            return None
        return c.reprs["hbm"]

    def device_view(self, key: str) -> dict:
        """The HBM-resident chunk's fields as device array views, trimmed
        to the chunk's real token extent (tail blocks are zero-padded in
        the pool)."""
        c = self.chunks[key]
        dev = self.pool.read(c.reprs["hbm"])
        n = c.tokens[1] - c.tokens[0]
        out = {f: dev[f][:, :, :n] for f in c.fields}
        out["kpos"] = dev["kpos"][:, :n]
        return out

    def fetch(self, key: str) -> Optional[dict]:
        """The chunk as device arrays, promoting it to the HBM tier.  An
        already-resident chunk is a hit (no bytes transferred); a chunk in
        a lower tier transfers its (possibly quantized) stored bytes.
        Returns None (a store miss) if the chunk was dropped."""
        c = self.chunks.get(key)
        tier = self.core.tier_of(key)
        if c is None or tier is None:
            self.store_misses += 1
            return None
        if tier == "hbm":
            self.io_hits += 1
            self.core.touch(key)
            return self.device_view(key)
        self.fetches += 1
        self.bytes_transferred += self._size(key, tier)
        landed = self.core.promote(key, "hbm")
        if landed == "hbm":
            return self.device_view(key)
        # HBM tier can't hold it (oversized/cap pressure): ephemeral view
        return self._decode_device(key)

    def fetch_range(self, rid: str, t0: int, t1: int
                    ) -> Optional[List[Tuple[int, int, dict]]]:
        """Device payloads of every chunk overlapping tokens [t0, t1) —
        what a restoration load op copies into the live cache.  None if any
        chunk is missing (caller falls back to ground truth)."""
        keys = self.requests.get(rid)
        if keys is None:
            return None
        cs = self.chunk_size
        out = []
        for ci in range(t0 // cs, min(len(keys), -(-t1 // cs))):
            pay = self.fetch(keys[ci])
            if pay is None:
                return None
            c0, c1 = self.chunks[keys[ci]].tokens
            out.append((c0, c1, pay))
        return out

    def fetch_packed(self, key: str) -> Optional[Tuple[str, dict]]:
        """The chunk in its *stored* encoding, counting the transfer but
        not decoding: ``("hbm", device views)`` for a resident chunk (an
        io hit), else ``("int8"|"raw", host payload)`` — the fused
        datapath stages those bytes as-is and dequantizes on device, then
        lands the pool block via :meth:`promote_staged`.  Byte/hit/miss
        accounting is identical to :meth:`fetch`."""
        c = self.chunks.get(key)
        tier = self.core.tier_of(key)
        if c is None or tier is None:
            self.store_misses += 1
            return None
        if tier == "hbm":
            self.io_hits += 1
            self.core.touch(key)
            return "hbm", self.device_view(key)
        self.fetches += 1
        self.bytes_transferred += self._size(key, tier)
        form = "int8" if self.quant == "int8" else "raw"
        return form, self._host_payload(key)

    def fetch_range_packed(self, rid: str, t0: int, t1: int
                           ) -> Optional[List[Tuple[int, int, str, dict,
                                                    str]]]:
        """Packed (undecoded) payloads of every chunk overlapping tokens
        [t0, t1): a list of ``(c0, c1, form, payload, key)``.  None if any
        chunk is missing (caller falls back to ground truth)."""
        keys = self.requests.get(rid)
        if keys is None:
            return None
        cs = self.chunk_size
        out = []
        for ci in range(t0 // cs, min(len(keys), -(-t1 // cs))):
            got = self.fetch_packed(keys[ci])
            if got is None:
                return None
            c0, c1 = self.chunks[keys[ci]].tokens
            out.append((c0, c1, got[0], got[1], keys[ci]))
        return out

    def promote_staged(self, key: str, dev: dict) -> Optional[str]:
        """Land a fetched chunk in the HBM tier from the datapath's
        already-staged device arrays: ``_move``'s pool alloc consumes
        ``dev`` instead of decoding the host payload a second time, so a
        fused restore puts each chunk on the wire exactly once.  ``dev``
        must be the dequantized device payload trimmed to the chunk's real
        token extent."""
        if self.core.tier_of(key) == "hbm":
            return "hbm"
        self._staged_dev[key] = dev
        try:
            return self.core.promote(key, "hbm")
        finally:
            self._staged_dev.pop(key, None)

    # ------------------------------------------------------------------
    # Engine-core kvstore protocol (keyed by request id)
    # ------------------------------------------------------------------
    def touch(self, rid: str):
        for key in self.requests.get(rid, ()):
            self.core.touch(key)

    def promote(self, rid: str, to: str = "host"):
        if to == "remote":
            to = "disk"
        for key in self.requests.get(rid, ()):
            self.core.promote(key, to)

    def tier_of(self, rid: str) -> Optional[str]:
        """Worst (lowest) tier among the request's chunks."""
        worst = None
        for key in self.requests.get(rid, ()):
            t = self.core.tier_of(key)
            if t is None:
                return None              # a chunk is gone: treat as cold
            if worst is None or CHUNK_TIERS.index(t) > CHUNK_TIERS.index(worst):
                worst = t
        return worst

    def bandwidth_for(self, rid: str) -> float:
        tier = self.tier_of(rid) or "disk"
        bw = self.core.tiers[tier].bandwidth
        if self.quant == "int8" and tier != "hbm":
            bw *= 2.0                    # int8 halves the bytes on the wire
        return bw

    def io_resident(self, rid: str, tokens: Tuple[int, int],
                    layers: Tuple[int, int]) -> bool:
        """True iff every chunk overlapping the token span is HBM-resident
        — the transfer for this I/O unit can be skipped entirely."""
        keys = self.requests.get(rid)
        if not keys:
            return False
        cs = self.chunk_size
        t0, t1 = tokens
        for ci in range(t0 // cs, min(len(keys), -(-t1 // cs))):
            if self.core.tier_of(keys[ci]) != "hbm":
                return False
        return True

    def note_io_hit(self, rid: str, tokens: Tuple[int, int],
                    layers: Tuple[int, int]):
        self.skipped_transfers += 1

    def missing_fraction(self, rid: str, tokens: Tuple[int, int],
                         layers: Tuple[int, int]) -> float:
        """Bytes-weighted fraction of the I/O unit's blocks NOT already
        HBM-resident — block-granular residency for the engine core's
        partial-transfer pricing: a unit with some blocks on device only
        pays the interconnect for the missing ones (partial eviction no
        longer re-transfers from token 0)."""
        keys = self.requests.get(rid)
        if not keys:
            return 1.0
        cs = self.chunk_size
        t0, t1 = tokens
        tot = miss = 0
        for ci in range(t0 // cs, min(len(keys), -(-t1 // cs))):
            c = self.chunks.get(keys[ci])
            nb = c.raw_nbytes if c is not None else cs
            tot += nb
            if self.core.tier_of(keys[ci]) != "hbm":
                miss += nb
        return miss / tot if tot else 1.0

    # ------------------------------------------------------------------
    def quant_tolerance(self) -> float:
        """Documented bound on the restored-KV error under int8: 0.5·scale
        round-off + up to 0.5·scale from the bf16 re-cast of the decoded
        view, per channel — i.e. one max-magnitude scale."""
        return 0.0 if self.quant == "none" else self.max_scale + 1e-6

    def audit(self):
        self.core.audit()
        self.pool.audit()
        n_hbm = sum(1 for k in self.chunks
                    if self.core.tier_of(k) == "hbm")
        # every hbm-resident chunk pins exactly one store-side pool ref;
        # request block tables may pin more, never fewer
        assert self.pool.live_blocks() >= n_hbm, \
            (self.pool.live_blocks(), n_hbm)
        for rid, keys in self.requests.items():
            for key in keys:
                c = self.chunks.get(key)
                assert c is None or c.refcount >= 0, (rid, key)
