"""Tier placement & accounting core shared by every KV-storage frontend.

One state machine answers "which tier holds this entry?" for both the
sim-mode :class:`repro.serving.kvstore.TieredKVStore` (whole-request
payloads, virtual bytes) and the materialized
:class:`repro.storage.chunkstore.ChunkStore` (content-addressed chunks,
real tensor bytes).  The core owns capacities, recency, eviction order and
the demotion cascade; frontends own what an entry *is* (its bytes, its
per-tier encoding) through three callbacks:

  * ``size_fn(key, tier) -> int``   — entry size in ``tier`` (pure; lower
    tiers may store a compressed encoding, e.g. int8-quantized KV).
  * ``move_fn(key, src, dst)``      — re-encode the payload for ``dst``
    (``src is None`` on first insert).  Called exactly once per placement.
  * ``drop_fn(key, src)``           — the entry leaves the store entirely
    (bottom-tier eviction overflow).

Eviction is benefit-aware when ``victim_fn`` is given: the tier victim is
the entry with the SMALLEST ``victim_fn(key)`` (least restoration benefit
lost per byte evicted), recency breaking ties; without it, plain LRU.

Demotion cascades correctly when lower tiers are full (the historical
``TieredKVStore._evict_for`` could over-fill a tier or silently lose
entries):

  * an entry larger than a tier's whole capacity skips that tier and
    places in the first tier below that can hold it — no tier is ever
    filled past capacity;
  * a victim demoted into a full tier recursively evicts there;
  * only the bottom tier drops entries, and every drop is counted
    (``drops``) and surfaced to the frontend via ``drop_fn``.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence


@dataclass
class Tier:
    name: str
    bandwidth: float               # bytes/s toward HBM
    capacity: int                  # bytes
    used: int = 0
    # key -> nbytes in THIS tier's encoding; front = eviction candidate
    lru: "OrderedDict[str, int]" = field(default_factory=OrderedDict)

    def __post_init__(self):
        # byte accounting is EXACT integers: repeated float +=/-= drifts
        # over long continuous-batching runs and capacity checks go soft
        self.capacity = int(self.capacity)
        self.used = int(self.used)


class PlacementCore:
    def __init__(self, tiers: Sequence[Tier], *,
                 size_fn: Optional[Callable[[str, str], float]] = None,
                 move_fn: Optional[Callable[[str, Optional[str], str], None]] = None,
                 drop_fn: Optional[Callable[[str, Optional[str]], None]] = None,
                 victim_fn: Optional[Callable[[str], float]] = None):
        self.order: List[str] = [t.name for t in tiers]
        self.tiers: Dict[str, Tier] = {t.name: t for t in tiers}
        self.size_fn = size_fn
        self.move_fn = move_fn
        self.drop_fn = drop_fn
        self.victim_fn = victim_fn
        self.placement: Dict[str, str] = {}      # key -> tier name
        self._sizes: Dict[str, int] = {}         # key -> nominal (raw) nbytes
        # incremental recency index: key -> monotone stamp, bumped on every
        # insert/touch.  Within a tier, stamp order == LRU order, so the
        # benefit-aware victim scan breaks ties in O(1) per candidate
        # instead of rebuilding an O(n) position map per eviction (which
        # made demotion cascades under a full store quadratic).
        self._stamp: Dict[str, int] = {}
        self._seq = 0
        self.demotions = 0
        self.promotions = 0
        self.drops = 0

    # ------------------------------------------------------------------
    def _size(self, key: str, tier: str) -> int:
        if self.size_fn is not None:
            return int(self.size_fn(key, tier))
        return self._sizes[key]

    def _restamp(self, key: str):
        self._seq += 1
        self._stamp[key] = self._seq

    def _index(self, tier: str) -> int:
        return self.order.index(tier)

    # ------------------------------------------------------------------
    def put(self, key: str, tier: str, *, nbytes: Optional[float] = None
            ) -> Optional[str]:
        """Place ``key`` in ``tier`` or the first tier below it that can
        hold it (after eviction).  Returns the tier the entry actually
        landed in, or None if it fell off the bottom (dropped, counted)."""
        if nbytes is not None:
            self._sizes[key] = int(nbytes)
        src = self._detach(key)
        return self._place(key, self._index(tier), src)

    def _place(self, key: str, i: int, src: Optional[str]) -> Optional[str]:
        while i < len(self.order):
            t = self.tiers[self.order[i]]
            nb = self._size(key, t.name)
            if nb <= t.capacity and self._evict_for(i, nb):
                if self.move_fn is not None:
                    self.move_fn(key, src, t.name)
                t.lru[key] = nb
                t.used += nb
                self.placement[key] = t.name
                self._restamp(key)
                return t.name
            i += 1
        # fell off the bottom: the entry leaves the store (accounted)
        self.drops += 1
        self._sizes.pop(key, None)
        self._stamp.pop(key, None)
        if self.drop_fn is not None:
            self.drop_fn(key, src)
        return None

    def _evict_for(self, i: int, nbytes: float) -> bool:
        """Make room for ``nbytes`` in tier index ``i`` by demoting victims
        downward; returns False iff the tier cannot be made to fit (then the
        caller tries the next tier down — never over-fills this one)."""
        t = self.tiers[self.order[i]]
        while t.used + nbytes > t.capacity:
            victim = self._pick_victim(t)
            if victim is None:
                return False
            vb = t.lru.pop(victim)
            t.used -= vb
            del self.placement[victim]
            # count the demotion only if the victim LANDED somewhere below;
            # a victim that falls off the bottom is a drop (counted in
            # _place) and must not inflate both counters
            if self._place(victim, i + 1, t.name) is not None:
                self.demotions += 1
        return True

    def _pick_victim(self, t: Tier) -> Optional[str]:
        if not t.lru:
            return None
        if self.victim_fn is None:
            return next(iter(t.lru))
        # benefit-aware: least benefit first; the incremental recency stamp
        # breaks ties in LRU order without rebuilding a position map on
        # every eviction of a cascade
        return min(t.lru, key=lambda k: (self.victim_fn(k), self._stamp[k]))

    def _detach(self, key: str) -> Optional[str]:
        """Remove ``key`` from its current tier (accounting only); returns
        the tier it was in."""
        tier = self.placement.pop(key, None)
        if tier is not None:
            t = self.tiers[tier]
            t.used -= t.lru.pop(key)
        return tier

    # ------------------------------------------------------------------
    def touch(self, key: str):
        tier = self.placement.get(key)
        if tier is not None and key in self.tiers[tier].lru:
            self.tiers[tier].lru.move_to_end(key)
            self._restamp(key)

    def tier_of(self, key: str) -> Optional[str]:
        return self.placement.get(key)

    def promote(self, key: str, to: str) -> Optional[str]:
        """Move ``key`` UP to ``to`` (no-op if already at or above it).

        A promotion counts — and resets the entry's recency — only when the
        entry actually lands STRICTLY above its source tier.  If no tier in
        [to, src) can hold the entry (each would be skipped for capacity),
        the whole call is a pure no-op: the entry keeps its LRU position
        and ``promotions`` stays put.  (``_place`` never fails with side
        effects: a tier with ``nb <= capacity`` can always be evicted into
        fitting, so checking capacities up front is exact.)"""
        tier = self.placement.get(key)
        if tier is None or self._index(tier) <= self._index(to):
            return tier
        i_src = self._index(tier)
        if not any(self._size(key, self.order[i])
                   <= self.tiers[self.order[i]].capacity
                   for i in range(self._index(to), i_src)):
            return tier
        src = self._detach(key)
        self.promotions += 1
        return self._place(key, self._index(to), src)

    def remove(self, key: str) -> Optional[str]:
        """Forget ``key`` entirely (caller owns the payload); returns the
        tier it occupied."""
        tier = self._detach(key)
        self._sizes.pop(key, None)
        self._stamp.pop(key, None)
        return tier

    # ------------------------------------------------------------------
    def total_used(self) -> int:
        return sum(t.used for t in self.tiers.values())

    def audit(self):
        """Invariants every mutation must preserve: per-tier ``used``
        EXACTLY equals the sum of its entries (integer bytes — no float
        drift tolerance), no tier exceeds capacity, and the placement map
        mirrors tier membership exactly."""
        for t in self.tiers.values():
            assert t.used == sum(t.lru.values()), \
                f"{t.name}: used {t.used} != sum {sum(t.lru.values())}"
            assert t.used <= t.capacity, \
                f"{t.name}: over capacity ({t.used} > {t.capacity})"
            for k in t.lru:
                assert self.placement.get(k) == t.name, k
                assert k in self._stamp, k
        for k, tier in self.placement.items():
            assert k in self.tiers[tier].lru, k
