"""Tier placement & accounting core shared by every KV-storage frontend.

One state machine answers "which tier holds this entry?" for both the
sim-mode :class:`repro.serving.kvstore.TieredKVStore` (whole-request
payloads, virtual bytes) and the materialized
:class:`repro.storage.chunkstore.ChunkStore` (content-addressed chunks,
real tensor bytes).  The core owns capacities, recency, eviction order and
the demotion cascade; frontends own what an entry *is* (its bytes, its
per-tier encoding) through three callbacks:

  * ``size_fn(key, tier) -> int``   — entry size in ``tier`` (pure; lower
    tiers may store a compressed encoding, e.g. int8-quantized KV).
  * ``move_fn(key, src, dst)``      — re-encode the payload for ``dst``
    (``src is None`` on first insert).  Called exactly once per placement.
  * ``drop_fn(key, src)``           — the entry leaves the store entirely
    (bottom-tier eviction overflow).

Eviction is benefit-aware when ``victim_fn`` is given: the tier victim is
the entry with the SMALLEST ``victim_fn(key)`` (least restoration benefit
lost per byte evicted), recency breaking ties; without it, plain LRU.

Demotion cascades correctly when lower tiers are full (the historical
``TieredKVStore._evict_for`` could over-fill a tier or silently lose
entries):

  * an entry larger than a tier's whole capacity skips that tier and
    places in the first tier below that can hold it — no tier is ever
    filled past capacity;
  * a victim demoted into a full tier recursively evicts there;
  * only the bottom tier drops entries, and every drop is counted
    (``drops``) and surfaced to the frontend via ``drop_fn``.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence


@dataclass
class Tier:
    name: str
    bandwidth: float               # bytes/s toward HBM
    capacity: float                # bytes
    used: float = 0.0
    # key -> nbytes in THIS tier's encoding; front = eviction candidate
    lru: "OrderedDict[str, float]" = field(default_factory=OrderedDict)


class PlacementCore:
    def __init__(self, tiers: Sequence[Tier], *,
                 size_fn: Optional[Callable[[str, str], float]] = None,
                 move_fn: Optional[Callable[[str, Optional[str], str], None]] = None,
                 drop_fn: Optional[Callable[[str, Optional[str]], None]] = None,
                 victim_fn: Optional[Callable[[str], float]] = None):
        self.order: List[str] = [t.name for t in tiers]
        self.tiers: Dict[str, Tier] = {t.name: t for t in tiers}
        self.size_fn = size_fn
        self.move_fn = move_fn
        self.drop_fn = drop_fn
        self.victim_fn = victim_fn
        self.placement: Dict[str, str] = {}      # key -> tier name
        self._sizes: Dict[str, float] = {}       # key -> nominal (raw) nbytes
        self.demotions = 0
        self.promotions = 0
        self.drops = 0

    # ------------------------------------------------------------------
    def _size(self, key: str, tier: str) -> float:
        if self.size_fn is not None:
            return self.size_fn(key, tier)
        return self._sizes[key]

    def _index(self, tier: str) -> int:
        return self.order.index(tier)

    # ------------------------------------------------------------------
    def put(self, key: str, tier: str, *, nbytes: Optional[float] = None
            ) -> Optional[str]:
        """Place ``key`` in ``tier`` or the first tier below it that can
        hold it (after eviction).  Returns the tier the entry actually
        landed in, or None if it fell off the bottom (dropped, counted)."""
        if nbytes is not None:
            self._sizes[key] = nbytes
        src = self._detach(key)
        return self._place(key, self._index(tier), src)

    def _place(self, key: str, i: int, src: Optional[str]) -> Optional[str]:
        while i < len(self.order):
            t = self.tiers[self.order[i]]
            nb = self._size(key, t.name)
            if nb <= t.capacity and self._evict_for(i, nb):
                if self.move_fn is not None:
                    self.move_fn(key, src, t.name)
                t.lru[key] = nb
                t.used += nb
                self.placement[key] = t.name
                return t.name
            i += 1
        # fell off the bottom: the entry leaves the store (accounted)
        self.drops += 1
        self._sizes.pop(key, None)
        if self.drop_fn is not None:
            self.drop_fn(key, src)
        return None

    def _evict_for(self, i: int, nbytes: float) -> bool:
        """Make room for ``nbytes`` in tier index ``i`` by demoting victims
        downward; returns False iff the tier cannot be made to fit (then the
        caller tries the next tier down — never over-fills this one)."""
        t = self.tiers[self.order[i]]
        while t.used + nbytes > t.capacity:
            victim = self._pick_victim(t)
            if victim is None:
                return False
            vb = t.lru.pop(victim)
            t.used -= vb
            del self.placement[victim]
            self.demotions += 1
            self._place(victim, i + 1, t.name)
        return True

    def _pick_victim(self, t: Tier) -> Optional[str]:
        if not t.lru:
            return None
        if self.victim_fn is None:
            return next(iter(t.lru))
        # benefit-aware: least benefit first; LRU position breaks ties
        pos = {k: i for i, k in enumerate(t.lru)}
        return min(t.lru, key=lambda k: (self.victim_fn(k), pos[k]))

    def _detach(self, key: str) -> Optional[str]:
        """Remove ``key`` from its current tier (accounting only); returns
        the tier it was in."""
        tier = self.placement.pop(key, None)
        if tier is not None:
            t = self.tiers[tier]
            t.used -= t.lru.pop(key)
        return tier

    # ------------------------------------------------------------------
    def touch(self, key: str):
        tier = self.placement.get(key)
        if tier is not None and key in self.tiers[tier].lru:
            self.tiers[tier].lru.move_to_end(key)

    def tier_of(self, key: str) -> Optional[str]:
        return self.placement.get(key)

    def promote(self, key: str, to: str) -> Optional[str]:
        """Move ``key`` UP to ``to`` (no-op if already at or above it)."""
        tier = self.placement.get(key)
        if tier is None or self._index(tier) <= self._index(to):
            return tier
        src = self._detach(key)
        self.promotions += 1
        return self._place(key, self._index(to), src)

    def remove(self, key: str) -> Optional[str]:
        """Forget ``key`` entirely (caller owns the payload); returns the
        tier it occupied."""
        tier = self._detach(key)
        self._sizes.pop(key, None)
        return tier

    # ------------------------------------------------------------------
    def total_used(self) -> float:
        return sum(t.used for t in self.tiers.values())

    def audit(self):
        """Invariants every mutation must preserve: per-tier ``used``
        equals the sum of its entries, no tier exceeds capacity, and the
        placement map mirrors tier membership exactly."""
        for t in self.tiers.values():
            assert abs(t.used - sum(t.lru.values())) < 1e-6, \
                f"{t.name}: used {t.used} != sum {sum(t.lru.values())}"
            assert t.used <= t.capacity + 1e-6, \
                f"{t.name}: over capacity ({t.used} > {t.capacity})"
            for k in t.lru:
                assert self.placement.get(k) == t.name, k
        for k, tier in self.placement.items():
            assert k in self.tiers[tier].lru, k
