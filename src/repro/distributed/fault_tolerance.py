"""Fault tolerance: heartbeats, failure detection, restart coordination.

Scope of this module on a real fleet:
  * every host runs a ``Heartbeat`` reporter; the coordinator declares a host
    dead after ``timeout`` missed beats,
  * on failure during TRAINING: all hosts restart from the latest complete
    checkpoint manifest (atomic — see training/checkpoint.py) and the data
    pipeline resumes at the exact step (stateless addressing),
  * on failure during SERVING: in-flight restorations owned by the dead
    stage are re-queued — restoration ops are idempotent (content-addressed
    chunks), so re-execution is safe; the simulator's channel-failure
    injection exercises the same path,
  * stragglers: per-resource progress rates are tracked; resources slower
    than ``straggler_factor`` × median are flagged and (for I/O) deprioritised
    by the batch scheduler via a bandwidth override.

Here the coordinator is exercised in-process (tests + simulator); the
interfaces are what a GKE/Borg supervisor would call.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class HostState:
    host_id: int
    last_beat: float
    alive: bool = True


class FailureDetector:
    def __init__(self, num_hosts: int, timeout: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        now = clock()
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, now) for h in range(num_hosts)}

    def beat(self, host_id: int):
        st = self.hosts[host_id]
        st.last_beat = self.clock()
        st.alive = True

    def scan(self) -> List[int]:
        """Returns newly-dead host ids."""
        now = self.clock()
        dead = []
        for st in self.hosts.values():
            if st.alive and now - st.last_beat > self.timeout:
                st.alive = False
                dead.append(st.host_id)
        return dead

    def alive_hosts(self) -> List[int]:
        return [h for h, st in self.hosts.items() if st.alive]


@dataclass
class StragglerMonitor:
    """Flags resources whose measured rate falls below factor × median."""
    straggler_factor: float = 0.5
    rates: Dict[str, List[float]] = field(default_factory=dict)

    def report(self, resource: str, units_per_sec: float):
        self.rates.setdefault(resource, []).append(units_per_sec)

    def stragglers(self) -> List[str]:
        import statistics
        recent = {r: statistics.fmean(v[-5:]) for r, v in self.rates.items() if v}
        if len(recent) < 2:
            return []
        med = statistics.median(recent.values())
        return [r for r, v in recent.items() if v < self.straggler_factor * med]


class TrainingSupervisor:
    """Restart-from-checkpoint driver: run_fn(start_step) -> last_step.
    run_fn raises HostFailure to simulate a node loss; the supervisor
    restores and resumes. Used by tests and launch/train.py."""

    def __init__(self, ckpt_manager, max_restarts: int = 10):
        self.ckpt = ckpt_manager
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, run_fn: Callable[[Optional[int]], int]) -> int:
        while True:
            start = self.ckpt.latest_step()
            try:
                return run_fn(start)
            except HostFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                continue


class HostFailure(RuntimeError):
    pass
