"""Explicit-collective attention: sequence-sharded decode with LSE combine.

When kv-heads don't divide the "model" axis the decode cache is sharded
along its SEQUENCE dim.  Naive attention then all-gathers the whole cache
every layer (~GBs/step for a 123B × 32k × 128 cell).  This shard_map kernel
instead computes flash-style partial attention per sequence shard and
combines with log-sum-exp weights — the communication drops to the partial
accumulators: psum of (B, Hq, Dh) + two (B, Hq) rows, ~10⁴× less.

This is the TPU analogue of flash-decode split-K, and the restoration
chunk/decode hot path CacheFlow cares about (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.constraints import _ambient_mesh

NEG_INF = -1e30


def lse_decode_attention(q, k, v, kpos, q_pos, *, scale: float, window: int = 0,
                         seq_axis: str = "model", batch_axes=("pod", "data"),
                         tail=None):
    """q: (B,1,Hq,Dh); k/v: (B,S,Hkv,Dh) S-sharded over ``seq_axis``;
    kpos: (S,); q_pos: (B,1) positions. Returns (B,1,Hq,Dv).

    ``tail``: optional (tail_k, tail_v, tail_kpos) append buffer — small and
    replicated; it is merged LOCALLY on shard 0 (gated via axis_index) so the
    big cache never pays a resharding collective for the concat."""
    mesh = _ambient_mesh()
    if mesh is None or mesh.shape.get(seq_axis, 1) == 1:
        if tail is not None:
            k = jnp.concatenate([k, tail[0].astype(k.dtype)], axis=1)
            v = jnp.concatenate([v, tail[1].astype(v.dtype)], axis=1)
            kpos = jnp.concatenate([kpos, tail[2]])
        return _local_decode(q, k, v, kpos, q_pos, scale, window)
    bax = tuple(a for a in batch_axes if a in mesh.axis_names)
    b = q.shape[0]
    bspec = bax if (bax and b % _prod(mesh, bax) == 0 and b >= _prod(mesh, bax)) \
        else None

    def body(ql, kl, vl, kpl, qpl, *tl):
        if tl:
            tk, tv, tkp = tl
            on_first = (jax.lax.axis_index(seq_axis) == 0)
            tkp = jnp.where(on_first, tkp, -1)     # only shard 0 counts the tail
            kl = jnp.concatenate([kl, tk.astype(kl.dtype)], axis=1)
            vl = jnp.concatenate([vl, tv.astype(vl.dtype)], axis=1)
            kpl = jnp.concatenate([kpl, tkp])
        out, m, l = _partial_decode(ql, kl, vl, kpl, qpl, scale, window)
        m_g = jax.lax.pmax(m, seq_axis)
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, seq_axis)
        acc = jax.lax.psum(out * w[..., None], seq_axis)
        return (acc / jnp.maximum(l_g, 1e-30)[..., None]).astype(ql.dtype)

    in_specs = [P(bspec, None, None, None), P(bspec, seq_axis, None, None),
                P(bspec, seq_axis, None, None), P(seq_axis), P(bspec, None)]
    args = [q, k, v, kpos, q_pos]
    if tail is not None:
        in_specs += [P(bspec, None, None, None), P(bspec, None, None, None),
                     P(None)]
        args += list(tail)
    return jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=P(bspec, None, None, None))(*args)


def _prod(mesh, axes):
    out = 1
    for a in axes:
        out *= mesh.shape.get(a, 1)
    return out


def _partial_decode(q, k, v, kpos, q_pos, scale, window):
    """Local flash partials. q: (B,1,Hq,Dh); k/v: (B,Sl,Hk,Dh); kpos (Sl,).
    Returns (acc (B,1,Hq,Dv) UNNORMALISED, m (B,1,Hq), l (B,1,Hq))."""
    b, _, hq, dh = q.shape
    sl, hk = k.shape[1], k.shape[2]
    g = hq // hk
    qg = q.reshape(b, hk, g, dh).astype(jnp.float32)
    sc = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
    valid = (kpos >= 0)[None, :] & (kpos[None, :] <= q_pos)
    if window > 0:
        valid &= kpos[None, :] > q_pos - window
    sc = jnp.where(valid[:, None, None], sc, NEG_INF)
    m = sc.max(axis=-1)                                       # (B,Hk,G)
    p = jnp.exp(sc - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return (acc.reshape(b, 1, hq, v.shape[-1]),
            m.reshape(b, 1, hq), l.reshape(b, 1, hq))


def _local_decode(q, k, v, kpos, q_pos, scale, window):
    acc, m, l = _partial_decode(q, k, v, kpos, q_pos, scale, window)
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
