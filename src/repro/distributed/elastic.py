"""Elastic scaling: resume a checkpoint on a different mesh shape.

PartitionSpecs in ``sharding.py`` are written against logical axis NAMES, not
sizes, so the same spec tree re-places host-numpy checkpoint leaves onto any
mesh whose axis sizes divide the array dims.  Scaling 1 pod ↔ 2 pods (or
16×16 ↔ 8×8 in tests) is therefore: restore → device_put with the new mesh's
NamedShardings → continue.  No resharding pass is needed on disk.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding


def replace_on_mesh(host_tree, pspec_tree, mesh: Mesh):
    """Place a host-numpy pytree onto ``mesh`` with the given spec tree."""
    def put(arr, spec):
        return jax.device_put(arr, NamedSharding(mesh, spec))
    return jax.tree.map(put, host_tree, pspec_tree)


def validate_divisibility(tree, pspec_tree, mesh: Mesh) -> list:
    """Returns a list of (path, dim, axis) violations (empty = resharding ok)."""
    bad = []

    def check(path, arr, spec):
        for d, ax in enumerate(tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape.get(a, 1)
            if arr.shape[d] % size:
                bad.append((jax.tree_util.keystr(path), d, ax))

    jax.tree_util.tree_map_with_path(check, tree, pspec_tree)
    return bad
