from repro.distributed.sharding import (batch_axes, cache_pspecs, choose_mode,  # noqa: F401
                                        data_pspecs, opt_pspecs, param_pspecs,
                                        to_named)
from repro.distributed.fault_tolerance import (FailureDetector, HostFailure,  # noqa: F401
                                               StragglerMonitor, TrainingSupervisor)
from repro.distributed.elastic import replace_on_mesh, validate_divisibility  # noqa: F401
