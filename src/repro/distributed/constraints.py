"""Sharding-constraint helper usable from model code.

``constrain(x, spec)`` applies ``with_sharding_constraint`` against the
ambient mesh (the one the launcher traces under); axis names missing from
the mesh are stripped, and with no mesh (single-device tests) it is a no-op —
so model code can express distribution *hints* without depending on how it
is launched.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _ambient_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def constrain(x, *spec):
    """Constrain ``x`` to PartitionSpec(*spec) on the ambient mesh; missing
    axes are stripped and axes that don't divide the dim are dropped, so the
    same model code is valid on any mesh (or none)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def axsize(a):
        return mesh.shape.get(a, 1)

    def keep(entry, dim):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a in names)
        size = 1
        for a in kept:
            size *= axsize(a)
        if not kept or size == 0 or dim % size:
            return None
        return kept if len(kept) > 1 else kept[0]

    ndim = x.ndim
    entries = list(spec) + [None] * (ndim - len(spec))
    cleaned = P(*[keep(e, x.shape[i]) for i, e in enumerate(entries[:ndim])])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, cleaned))
