"""Sharding rules per (architecture × input shape × mesh).

Three modes:
  * ``train``     — FSDP over "data" (weights + optimizer state ZeRO-3-style)
                    + Megatron TP over "model"; batch over ("pod","data").
  * ``serve_tp``  — TP over "model" only; weights replicated over "data"
                    (small models: d_ff/heads/vocab sharded 16-way fits HBM).
  * ``serve_2d``  — 2D tensor parallelism: d_model over "data" AND
                    d_ff/heads/vocab over "model" (≥60B archs: 256-way weight
                    shard is required to fit 16 GB/chip).

Leaf rules are name-based over the model's param pytree; scan-stacked layers
(leading L axis) get a ``None`` prepended automatically.  All sharded dims
are exactly divisible for every assigned architecture on the 16×16 and
2×16×16 production meshes (validated by the dry-run).

KV-cache rule: batch over ("pod","data"); kv-heads over "model" when
divisible, otherwise the cache *sequence* dim is sharded over "model"
(sequence-parallel decode — see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.models.model import Model

# threshold above which serving needs 2D weight sharding (bf16 bytes / chip)
_SERVE_2D_PARAM_THRESHOLD = 60e9


def choose_mode(cfg: ModelConfig, shape: ShapeConfig) -> str:
    if shape.kind == "train":
        return "train"
    total = cfg.param_counts()["total"]
    return "serve_2d" if total > _SERVE_2D_PARAM_THRESHOLD else "serve_tp"


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

# name -> (train/2d spec, serve_tp spec); F = fsdp axis "data"
def _leaf_rule(name: str, parent: str, fsdp: Optional[str]):
    """Returns the PartitionSpec for the leaf's own (unstacked) dims."""
    expert = parent == "moe" and name in ("w_gate", "w_up", "w_down", "router")
    if expert:
        if name == "router":
            return P(fsdp, "model")
        if name in ("w_gate", "w_up"):
            return P("model", None, fsdp)       # (E, D, F)
        return P("model", fsdp, None)           # w_down (E, F, D)
    table = {
        "embed": P("model", fsdp),
        "unembed": P(fsdp, "model"),
        "wq": P(fsdp, "model"), "wk": P(fsdp, "model"), "wv": P(fsdp, "model"),
        "wo": P("model", fsdp),
        "bq": P("model"), "bk": P("model"), "bv": P("model"),
        "w_gate": P(fsdp, "model"), "w_up": P(fsdp, "model"),
        "w_down": P("model", fsdp),
        # MLA
        "wq_a": P(fsdp, None), "wq_b": P(None, "model"),
        "wkv_a": P(fsdp, None), "wkv_b": P(None, "model"),
        # RG-LRU
        "w_y": P(fsdp, "model"), "w_x": P(fsdp, "model"),
        "w_out": P("model", fsdp),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "gate_a": P(None, None, None), "gate_i": P(None, None, None),
        "gate_a_b": P("model"), "gate_i_b": P("model"), "lam": P("model"),
        # RWKV
        "w_r": P(fsdp, "model"), "w_k": P(fsdp, "model"), "w_v": P(fsdp, "model"),
        "w_g": P(fsdp, "model"), "w_o": P("model", fsdp),
        "cm_k": P(fsdp, "model"), "cm_v": P("model", fsdp), "cm_r": P(fsdp, "model"),
        "decay_w1": P(fsdp, None), "decay_w2": P(None, "model"),
        "mix_w1": P(fsdp, None), "mix_w2": P(None, None, "model"),
    }
    return table.get(name)  # None -> replicate (norms, small vectors)


def param_pspecs(model: Model, mode: str):
    """PartitionSpec pytree matching model.param_specs()."""
    fsdp = "data" if mode in ("train", "serve_2d") else None
    specs = model.param_specs()

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) or str(getattr(p, "idx", ""))
                 for p in path]
        name = names[-1]
        # norms keyed scale/bias live under norm subtrees
        parent = names[-2] if len(names) >= 2 else ""
        if name in ("scale", "bias"):
            spec = None
        else:
            look_parent = parent
            if parent not in ("moe",) and "moe" in names:
                look_parent = "moe"
            if name in ("w_gate", "w_up", "w_down") and "shared" in names:
                look_parent = "mlp"
            spec = _leaf_rule(name, look_parent, fsdp)
        base = spec if spec is not None else P()
        # pad to leaf rank: prepend None for the scan-stacked layer axis
        base_t = tuple(base)
        if len(base_t) < leaf.ndim:
            base_t = (None,) * (leaf.ndim - len(base_t)) + base_t
        elif len(base_t) > leaf.ndim:
            base_t = base_t[-leaf.ndim:]
        return P(*base_t)

    return jax.tree_util.tree_map_with_path(rule, specs)


def opt_pspecs(model: Model, mode: str):
    from repro.training.optimizer import OptState
    p = param_pspecs(model, mode)
    return OptState(P(), jax.tree.map(lambda s: s, p), jax.tree.map(lambda s: s, p))


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------


def data_pspecs(cfg: ModelConfig, mesh: Mesh, kind: str, batch: int):
    """Batch specs. train: {"tokens": (B, S+1)} or embeddings batch;
    prefill: inputs (B, S); decode: tokens (B,) (+ positions scalar).
    Batch dims smaller than the data axes replicate (long_500k B=1)."""
    bax = batch_axes(mesh)
    div = batch % max(1, _prod(mesh, bax)) == 0 and batch >= _prod(mesh, bax)
    baxes = bax if div else None
    b = P(baxes)
    if kind == "train":
        if cfg.input_mode == "tokens":
            return {"tokens": b}
        return {"embeddings": P(baxes, None, None), "labels": b}
    if kind == "prefill":
        return b if cfg.input_mode == "tokens" else P(baxes, None, None)
    # decode: one token per sequence
    return b if cfg.input_mode == "tokens" else P(baxes, None)


def cache_pspecs(model: Model, mesh: Mesh, batch: int, seq: int):
    """KV-cache specs for decode. See module docstring for the kv-head vs
    sequence sharding rule."""
    cfg = model.cfg
    model_size = mesh.shape.get("model", 1)
    bax = batch_axes(mesh)
    batch_div = batch % max(1, _prod(mesh, bax)) == 0 and batch >= _prod(mesh, bax)
    bspec = bax if batch_div else None
    specs = {}
    cache_shapes = jax.eval_shape(lambda: model.init_cache(batch, seq))
    for f, sds in cache_shapes.items():
        if f in ("k", "v"):
            hkv = cfg.num_kv_heads
            s_dim = sds.shape[2]
            if hkv % model_size == 0:
                specs[f] = P(None, bspec, None, "model", None)
            elif s_dim % model_size == 0:
                specs[f] = P(None, bspec, "model", None, None)
            else:
                specs[f] = P(None, bspec, None, None, None)
        elif f == "ckv":
            s_dim = sds.shape[2]
            specs[f] = P(None, bspec, "model" if s_dim % model_size == 0 else None, None)
        elif f == "kpos":
            specs[f] = P(None, None)
        elif f == "wkv":
            h = sds.shape[2]
            specs[f] = P(None, bspec, "model" if h % model_size == 0 else None, None, None)
        elif f in ("conv", "lru", "shift_tm", "shift_cm"):
            w = sds.shape[-1]
            specs[f] = P(*([None] * (sds.ndim - 1)), "model" if w % model_size == 0 else None)
        else:
            specs[f] = P(*([None] * sds.ndim))
    return specs


def _axsize(mesh: Mesh, a: str) -> int:
    return mesh.shape.get(a, 1)


def _prod(mesh: Mesh, axes) -> int:
    out = 1
    for a in axes:
        out *= _axsize(mesh, a)
    return out


def to_named(mesh: Mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def io_channel_devices(mesh: Optional[Mesh] = None,
                       io_channels: Optional[int] = None):
    """Physical device behind each restoration I/O channel.

    The engine core's ``io_channels`` contention model maps onto real
    transfer queues by pinning channel ``c`` to device ``devs[c % len]``:
    on a sharded mesh every physical device gets its own host→device fetch
    stream (the paper's third parallelism dimension executed for real);
    single-device hosts degenerate to N queues on one device, which still
    pipelines host staging against the dequant-scatter kernel."""
    devs = list(mesh.devices.flat) if mesh is not None else jax.devices()
    n = io_channels if io_channels is not None else len(devs)
    return [devs[c % len(devs)] for c in range(max(1, n))]
