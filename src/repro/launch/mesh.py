"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])
