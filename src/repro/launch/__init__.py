from repro.launch.mesh import make_local_mesh, make_production_mesh  # noqa: F401
