"""Trip-count-aware HLO cost extraction.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
program built on ``lax.scan`` (scan-over-layers, grad accumulation, flash
key-block scans, MoE group scans) under-reports FLOPs and collectives by the
loop trip counts.  This module parses the compiled per-device HLO text:

  * while trip counts come from ``backend_config known_trip_count`` (XLA
    annotates statically-known loops),
  * a call-graph DFS assigns every computation the product of enclosing trip
    counts,
  * dot FLOPs = 2 · prod(result dims) · prod(contracting dims) with operand
    shapes resolved through a per-computation symbol table (matmuls dominate;
    elementwise FLOPs are not counted — a slight underestimate),
  * collective bytes use ring-algorithm per-device accounting.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8}

_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_SYM = re.compile(r"%([\w\.\-]+)\s*=\s*\(?\s*(\w+)\[([\d,]*)\]")
_WHILE = re.compile(r"\bwhile\(.*?condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)')
_CALLS = re.compile(r"(?:calls|to_apply|condition|body)=%([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_DOT_LINE = re.compile(
    r"%[\w\.\-]+\s*=\s*(\w+)\[([\d,]*)\][^=]*?\bdot\(%([\w\.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLL = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[^\]]*\])(?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _prod(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _nbytes(dt: str, dims: str) -> int:
    return _prod(dims) * _DTYPE_BYTES.get(dt, 0)


def analyze(hlo: str) -> dict:
    # ---- split into computations, build symbol tables and call graph -----
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _HDR.match(line.strip())
        if m and line.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if raw.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)

    symbols: Dict[str, Dict[str, tuple]] = {}
    callees: Dict[str, set] = defaultdict(set)
    trip_of: Dict[str, int] = {}
    for name, lines in comps.items():
        tab = {}
        for line in lines:
            s = _SYM.search(line)
            if s:
                tab[s.group(1)] = (s.group(2), s.group(3))
            w = _WHILE.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                t = _TRIP.search(line)
                trip = int(t.group(1)) if t else 1
                trip_of[body] = trip
                trip_of[cond] = trip
                callees[name].update([cond, body])
            else:
                for c in _CALLS.findall(line):
                    callees[name].add(c)
                b = _BRANCHES.search(line)
                if b:
                    for c in re.split(r",\s*", b.group(1)):
                        callees[name].add(c.strip().lstrip("%"))
        # header params also define symbols (needed for dot operand lookup)
        symbols[name] = tab
    # add computation parameter shapes
    for raw in hlo.splitlines():
        m = _HDR.match(raw.strip())
        if m:
            name = m.group(1)
            for pm in re.finditer(r"([\w\.\-]+):\s*\(?\s*(\w+)\[([\d,]*)\]",
                                  raw):
                symbols[name].setdefault(pm.group(1), (pm.group(2), pm.group(3)))

    # ---- multipliers via DFS ---------------------------------------------
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth: int = 0):
        if depth > 64 or name not in comps or mult[name] >= m:
            return
        mult[name] = m
        for c in callees.get(name, ()):
            visit(c, m * trip_of.get(c, 1), depth + 1)

    if entry is None and comps:
        entry = next(iter(comps))
    if entry:
        visit(entry, 1.0)
    for name in comps:
        if mult[name] == 0.0:
            mult[name] = 1.0

    # ---- accumulate -------------------------------------------------------
    flops = 0.0
    coll: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for name, lines in comps.items():
        m = mult[name]
        tab = symbols[name]
        for line in lines:
            d = _DOT_LINE.search(line)
            if d:
                res_dt, res_dims, lhs_name = d.group(1), d.group(2), d.group(3)
                if res_dt in _DTYPE_BYTES:
                    contract = 1
                    lc = _LHS_CONTRACT.search(line)
                    lhs = tab.get(lhs_name)
                    if lc and lhs:
                        dims = [int(x) for x in lhs[1].split(",") if x]
                        for idx in (int(i) for i in lc.group(1).split(",") if i):
                            if idx < len(dims):
                                contract *= dims[idx]
                    flops += 2.0 * _prod(res_dims) * contract * m
                continue
            c = _COLL.search(line)
            if c:
                shape_str = c.group(1) or c.group(2)
                kind = c.group(3)
                r = sum(_nbytes(dt, dims) for dt, dims in _SHAPE.findall(shape_str))
                g = _GROUPS.search(line)
                n = int(g.group(2)) if g else 2
                if kind == "all-gather":
                    moved = r * (n - 1) / max(1, n)
                elif kind == "reduce-scatter":
                    moved = r * (n - 1)
                elif kind == "all-reduce":
                    moved = 2 * r * (n - 1) / max(1, n)
                elif kind == "all-to-all":
                    moved = r * (n - 1) / max(1, n)
                else:
                    moved = r
                coll[kind] += moved * m
                counts[kind] += 1
    return {
        "dot_flops": flops,
        "collective_bytes": dict(coll),
        "collective_total_bytes": sum(coll.values()),
        "collective_counts": dict(counts),
        "while_trip_counts": trip_of,
    }
