"""End-to-end training driver.

Real run (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --steps 100 --ckpt-dir /tmp/ckpt

Features exercised: deterministic sharded data, AdamW + schedule, remat,
checkpoint/restart (resume is automatic if the ckpt dir has a manifest),
simulated host failure (--fail-at-step) to demonstrate restart-from-manifest.
On a real fleet the same driver runs under the production mesh with the FSDP
+ TP shardings from repro.distributed.sharding (see launch/dryrun.py for the
compiled evidence).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.fault_tolerance import HostFailure, TrainingSupervisor
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.training import (AdamWConfig, CheckpointManager, DataConfig,
                            batch_at, embedding_batch_at, init_opt_state,
                            make_train_step)


def run(arch: str, *, reduced: bool, steps: int, ckpt_dir: str,
        global_batch: int = 8, seq_len: int = 64, ckpt_every: int = 20,
        fail_at_step: int = -1, peak_lr: float = 3e-3, log_every: int = 10):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat_policy="dots")
    opt_cfg = AdamWConfig(peak_lr=peak_lr, warmup_steps=max(2, steps // 20),
                          total_steps=steps)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                    global_batch=global_batch)
    ckpt = CheckpointManager(ckpt_dir, keep=3)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    supervisor = TrainingSupervisor(ckpt)

    def make_batch(s):
        if cfg.input_mode == "tokens":
            return batch_at(dc, s)
        return embedding_batch_at(dc, s, cfg.d_model)

    def session(start_step):
        params = model.init(jax.random.PRNGKey(0))
        opt_state = init_opt_state(params)
        first = 0
        if start_step is not None:
            first, restored = ckpt.restore({"params": params, "opt": opt_state})
            params, opt_state = restored["params"], restored["opt"]
            print(f"[restore] resumed from step {first}")
            first += 1
        t0 = time.time()
        for s in range(first, steps):
            if s == fail_at_step and supervisor.restarts == 0:
                print(f"[inject] host failure at step {s}")
                raise HostFailure(f"injected at step {s}")
            params, opt_state, metrics = step_fn(params, opt_state, make_batch(s))
            if s % log_every == 0 or s == steps - 1:
                print(f"step {s:5d} loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"({(time.time() - t0):.1f}s)")
            if s % ckpt_every == 0 or s == steps - 1:
                ckpt.save_async(s, {"params": params, "opt": opt_state})
        ckpt.wait()
        return steps - 1

    last = supervisor.run(session)
    print(f"[done] trained to step {last} (restarts: {supervisor.restarts})")
    return last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    args = ap.parse_args()
    run(args.arch, reduced=args.reduced, steps=args.steps, ckpt_dir=args.ckpt_dir,
        global_batch=args.global_batch, seq_len=args.seq_len,
        fail_at_step=args.fail_at_step, peak_lr=args.peak_lr)


if __name__ == "__main__":
    main()
