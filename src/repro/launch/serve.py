"""End-to-end serving driver.

Simulation at paper scale (default):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
      --workload swe_bench --requests 64 --system cacheflow --bandwidth 10Gbps

Real execution on a reduced model (CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --real \
      --requests 4 --system cacheflow
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core.baselines import BASELINES
from repro.models import build_model
from repro.serving import (RealServingEngine, Request, SimServingEngine,
                           TieredKVStore, generate)
from repro.serving.workloads import WORKLOADS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--workload", default="swe_bench", choices=list(WORKLOADS))
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--system", default="cacheflow", choices=list(BASELINES))
    ap.add_argument("--bandwidth", default="10Gbps", choices=list(IO_BANDWIDTHS))
    ap.add_argument("--hardware", default="tpu_v5e", choices=list(HARDWARE))
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--io-channels", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--real", action="store_true", help="run a reduced model for real")
    args = ap.parse_args()

    if args.real:
        cfg = get_config(args.arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = RealServingEngine(model, params, system=args.system,
                                stages=min(args.stages, 2), chunk_size=16,
                                max_batch=args.max_batch,
                                io_channels=args.io_channels)
        reqs = [Request(f"r{i}", 0.0, prefix_len=64 + 32 * i, new_len=16)
                for i in range(args.requests)]
        rep = eng.serve(reqs)
        print(json.dumps({"system": args.system, "mode": "real",
                          "ttft": rep.stats,
                          "compute_busy": round(rep.compute_busy, 3),
                          "io_busy": round(rep.io_busy, 3)}, indent=1))
        return

    cfg = get_config(args.arch)
    reqs = generate(args.workload, args.requests, seed=args.seed)
    store = TieredKVStore(remote_bw=IO_BANDWIDTHS[args.bandwidth])
    eng = SimServingEngine(cfg, HARDWARE[args.hardware],
                           io_bandwidth=IO_BANDWIDTHS[args.bandwidth],
                           system=args.system, stages=args.stages,
                           max_batch=args.max_batch, kvstore=store,
                           io_channels=args.io_channels)
    rep = eng.run(reqs)
    print(json.dumps({
        "system": args.system, "workload": args.workload,
        "bandwidth": args.bandwidth, "hardware": args.hardware,
        "stages": args.stages, "ttft": rep.stats,
        "compute_busy": round(rep.compute_busy, 3),
        "io_busy": round(rep.io_busy, 3)}, indent=1))


if __name__ == "__main__":
    main()
