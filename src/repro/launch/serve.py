"""End-to-end serving driver.

Simulation at paper scale (default):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
      --workload swe_bench --requests 64 --system cacheflow --bandwidth 10Gbps

Real execution on a reduced model (CPU): restoration is served from the
MATERIALIZED chunk-granular KV store (content-addressed dedup across
hbm/host/disk tiers; see DESIGN.md §10) — ``--kv-quant int8`` stores
sub-HBM tiers per-channel quantized, ``--store-dir`` materializes the disk
tier as .npz files, ``--evict`` drops (instead of parks) preempted caches
and restarts them from the store:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --real \
      --requests 4 --system cacheflow --kv-quant int8 --store-dir /tmp/kv

Schedule capture & replay (see repro/core/trace.py): ``--trace-out t.json``
records the restoration schedule of any run; ``--replay t.json`` re-executes
a captured schedule decision-for-decision with pinned durations —
analytically by default (bit-identical EngineResult), or on-device with
``--real`` (every dispatched op runs through a RestorationExecutor and each
restored cache is verified against full-prefill ground truth under the
captured interleaving).  On-device replay requires a trace whose geometry
fits the reduced model — capture it with ``--real --trace-out``; paper-scale
sim traces replay analytically.

Correctness tooling (see DESIGN.md §14): ``--sanitize`` (or
``CACHEFLOW_SANITIZE=1``) runs the engine under the runtime invariant
sanitizer and prints its counters in the report.  Captured traces lint
offline with
  PYTHONPATH=src python -m repro.analysis.lint_trace t.json
and the repo-specific static lint pass runs with
  PYTHONPATH=src python -m repro.analysis.codelint

Observability (see DESIGN.md §15): ``--telemetry`` (or
``CACHEFLOW_TELEMETRY=1``) collects the engine-wide metrics registry
(queue depth, batch sizes, gate outcomes, per-channel GB/s, tier
occupancy, per-request phase timestamps) into the report;
``--metrics-out m.json`` writes the snapshot to a file and
``--timeline-out t.json`` exports a Chrome trace-event timeline loadable
in https://ui.perfetto.dev.  Any captured trace renders offline with
  PYTHONPATH=src python -m repro.obs.timeline t.json
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.config import HARDWARE, IO_BANDWIDTHS
from repro.configs import get_config
from repro.core.baselines import BASELINES
from repro.core.trace import ScheduleTrace, TraceRecorder, replay_trace
from repro.models import build_model
from repro.serving import (ChunkStore, RealServingEngine, Request,
                           SimServingEngine, TieredKVStore, generate)
from repro.serving.metrics import dumps_report
from repro.serving.workloads import WORKLOADS


def _save_trace(rec: TraceRecorder, path: str, arch: str = None):
    if arch is not None:
        rec.trace.meta["arch"] = arch   # replay sanity check (--real)
    rec.trace.save(path)
    # stderr: stdout carries the JSON report (`serve ... > report.json`)
    print(f"# schedule trace ({len(rec.trace.events)} events) -> {path}",
          file=sys.stderr)


def _save_timeline(trace: ScheduleTrace, path: str, telemetry=None):
    """Export the run's Perfetto timeline from its captured trace."""
    from repro.obs.timeline import trace_to_chrome
    doc = trace_to_chrome(trace, telemetry=telemetry)
    with open(path, "w") as f:
        f.write(dumps_report(doc))
    print(f"# perfetto timeline ({len(doc['traceEvents'])} events) -> "
          f"{path} (open in https://ui.perfetto.dev)", file=sys.stderr)


def _save_metrics(telemetry: dict, path: str):
    with open(path, "w") as f:
        f.write(dumps_report(telemetry))
    print(f"# telemetry snapshot -> {path}", file=sys.stderr)


def _replay(args) -> None:
    trace = ScheduleTrace.load(args.replay)
    if not trace.requests:
        raise SystemExit(f"--replay: trace {args.replay} contains no requests")
    recorder = TraceRecorder() if args.trace_out else None
    if args.real:
        # Rebuild a reduced model, re-prefill every captured request so the
        # executor holds its ground truth, then execute the captured
        # schedule op-for-op with verification.
        from repro.core.executor import RestorationExecutor
        t_arch = trace.meta.get("arch")
        if t_arch is not None and t_arch != args.arch:
            raise SystemExit(
                f"--replay --real: trace was captured on arch '{t_arch}' "
                f"but --arch is '{args.arch}'; pass --arch {t_arch}")
        cfg = get_config(args.arch).reduced()
        # On-device replay needs a trace captured on this reduced-model
        # geometry (e.g. from `--real --trace-out`): a paper-scale sim
        # trace references layers this model does not have and prefixes a
        # CPU prefill cannot reproduce in reasonable time.
        max_layer = max(p["layer_hi"] for r in trace.requests
                        for p in r["plans"])
        max_tokens = max(r["n_tokens"] for r in trace.requests)
        if max_layer > cfg.num_layers or max_tokens > 4096:
            raise SystemExit(
                f"--replay --real: trace geometry (layers<= {max_layer}, "
                f"prefix<= {max_tokens} tokens) does not fit the reduced "
                f"'{args.arch}' model ({cfg.num_layers} layers); capture the "
                f"trace with `--real --trace-out` instead")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        chunks = {p["chunk_size"] for r in trace.requests for p in r["plans"]}
        if len(chunks) > 1:
            raise SystemExit(
                f"--replay --real: heterogeneous chunk sizes {sorted(chunks)} "
                f"in trace; one executor serves one chunk granularity")
        ex = RestorationExecutor(model, params, chunk_size=chunks.pop(),
                                 stages=trace.meta["stages"])
        rng = jax.random.PRNGKey(args.seed)
        for r in trace.requests:
            rng, key = jax.random.split(rng)   # distinct ground truth per rid
            n = r["n_tokens"]
            if cfg.input_mode == "tokens":
                inputs = jax.random.randint(key, (1, n), 0, cfg.vocab_size)
            else:
                inputs = jax.random.normal(key, (1, n, cfg.d_model))
            ex.remember(r["request_id"], inputs)
            # lifecycle traces (schema v2) also re-execute the captured
            # suffix prefill + decode steps on device
            new_len = r.get("new_len", 0)
            decode_len = r.get("decode_len", 0)
            if new_len > 0 or decode_len > 0:
                rng, key = jax.random.split(rng)
                if cfg.input_mode == "tokens":
                    suffix = jax.random.randint(key, (1, new_len), 0,
                                                cfg.vocab_size) if new_len else None
                else:
                    suffix = jax.random.normal(key, (1, new_len, cfg.d_model)) \
                        if new_len else None
                ex.set_suffix(r["request_id"], suffix, decode_len=decode_len)
        res = replay_trace(trace, ex, verify=True, trace_out=recorder)
        mode = "replay-real"
    else:
        res = replay_trace(trace, trace_out=recorder)
        captured = trace.captured_result()
        if captured is not None and res != captured:
            raise SystemExit(
                "--replay: analytic replay diverged from the captured "
                "EngineResult (trace edited or engine behavior changed)")
        mode = "replay-sim"
    if recorder is not None:
        # propagate the source capture's arch tag so a re-captured trace
        # keeps the --real arch sanity check armed
        _save_trace(recorder, args.trace_out, arch=trace.meta.get("arch"))
    if args.timeline_out:
        _save_timeline(recorder.trace if recorder is not None else trace,
                       args.timeline_out)
    print(dumps_report({
        "mode": mode, "trace": args.replay,
        "requests": len(trace.requests),
        "dispatches": len(trace.dispatches()),
        "prefills": len(trace.prefills()),
        "decode_steps": len(trace.decode_steps()),
        "makespan": res.makespan,
        "compute_busy": round(res.compute_busy, 3),
        "io_busy": round(res.io_busy, 3),
        "decode_busy": round(res.decode_busy, 3)}, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--workload", default="swe_bench", choices=list(WORKLOADS))
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--system", default="cacheflow", choices=list(BASELINES))
    ap.add_argument("--bandwidth", default="10Gbps", choices=list(IO_BANDWIDTHS))
    ap.add_argument("--hardware", default="tpu_v5e", choices=list(HARDWARE))
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--max-batch", "--max-active", dest="max_batch",
                    type=int, default=8,
                    help="continuous-batching admission cap (engine-core "
                         "max_active); 0 = unlimited")
    ap.add_argument("--io-channels", type=int, default=1)
    ap.add_argument("--decode-len", type=int, default=-1,
                    help="output tokens per request (lifecycle decode); "
                         "-1 keeps the workload-drawn lengths (sim) or "
                         "uses 8 (real)")
    ap.add_argument("--preempt", default="none",
                    choices=["none", "priority", "deadline"],
                    help="admission-pressure policy: suspend the least-"
                         "beneficial in-flight restoration for a more "
                         "urgent arrival (resumes on a freed slot)")
    ap.add_argument("--admission", default="continuous",
                    choices=["continuous", "gang"],
                    help="'continuous' streams arrivals into freed decode "
                         "slots mid-flight (restoration overlaps the live "
                         "decode batch); 'gang' is the run-to-completion "
                         "baseline — the next batch is admitted only when "
                         "the whole current batch retires")
    ap.add_argument("--prefetch", action="store_true",
                    help="promote queued requests' KV up a storage tier on "
                         "idle channel time (the admission queue is a known "
                         "lookahead window), so admission-time restoration "
                         "starts from the faster tier")
    ap.add_argument("--burst-size", type=int, default=3,
                    help="bursty_priority workload: urgent requests per burst")
    ap.add_argument("--burst-every", type=float, default=4.0,
                    help="bursty_priority workload: seconds between bursts")
    ap.add_argument("--kv-tier", default="host",
                    choices=["hbm", "host", "remote"],
                    help="tier returning prefixes start in: 'hbm' is "
                         "device-resident (restoration transfers are "
                         "skipped entirely as dedup/residency hits), "
                         "'host' models warm DRAM reuse, and 'remote' the "
                         "cold disaggregated store (the real-mode chunk "
                         "store maps it to its disk tier), where "
                         "restoration dominates and admission pressure "
                         "(and preemption) is real")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="per-channel int8 compression of sub-HBM tiers "
                         "(kernels/kv_quant): real mode stores quantized "
                         "chunk bytes and dequantizes on promotion; sim "
                         "mode halves stored bytes / doubles effective "
                         "transfer bandwidth")
    ap.add_argument("--store-dir", metavar="DIR",
                    help="real mode: materialize the chunk store's bottom "
                         "tier as .npz files under DIR (in-memory blobs "
                         "when omitted)")
    ap.add_argument("--datapath", default="fused",
                    choices=["fused", "legacy"],
                    help="real mode restoration data path: 'fused' moves "
                         "each load op's chunks as ONE packed (int8-"
                         "quantized when --kv-quant int8) staging buffer "
                         "through a per-channel double-buffered transfer "
                         "stream and scatters with a single fused dequant "
                         "kernel launch (core/datapath.py + "
                         "kernels/kv_restore); 'legacy' keeps the "
                         "per-chunk/per-layer/per-field .at[].set() "
                         "baseline")
    ap.add_argument("--evict", action="store_true",
                    help="eviction-mode preemption: drop the victim's "
                         "partially-restored cache (instead of parking "
                         "it) and restart restoration from the KV store "
                         "on re-admission — for when host memory is tight")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sanitize", action="store_true",
                    help="run the engine under the runtime sanitizer "
                         "(repro.analysis.sanitizer): every scheduling "
                         "event is checked against the engine's "
                         "concurrency invariants and the report prints "
                         "the sanitizer counters; equivalent to "
                         "CACHEFLOW_SANITIZE=1")
    ap.add_argument("--telemetry", action="store_true",
                    help="collect the engine-wide metrics registry "
                         "(repro.obs): queue depth, admitted/decode batch "
                         "sizes, benefit-gate outcomes, preempt/abort "
                         "counts, per-channel busy and measured GB/s, "
                         "storage-tier occupancy and per-request phase "
                         "timestamps; the report carries the snapshot "
                         "under 'telemetry'; equivalent to "
                         "CACHEFLOW_TELEMETRY=1")
    ap.add_argument("--metrics-out", metavar="PATH",
                    help="write the full telemetry snapshot (metrics + "
                         "gauge time series + per-request phase "
                         "transitions) to PATH as strict JSON; implies "
                         "--telemetry")
    ap.add_argument("--timeline-out", metavar="PATH",
                    help="export the run's schedule as Chrome trace-event "
                         "JSON loadable in https://ui.perfetto.dev — one "
                         "track per engine resource, per-request lifecycle "
                         "flow arrows, aborted-op markers and counter "
                         "tracks (queue depth, tier bytes, per-channel "
                         "bandwidth); works with --replay too")
    ap.add_argument("--real", action="store_true", help="run a reduced model for real")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="capture the restoration schedule to a JSON trace")
    ap.add_argument("--replay", metavar="PATH",
                    help="re-execute a captured trace (pinned durations) "
                         "instead of scheduling fresh; --real replays it "
                         "on-device with per-request cache verification")
    args = ap.parse_args()

    if args.admission == "gang" and args.preempt != "none":
        raise SystemExit("--admission gang is the run-to-completion "
                         "baseline: no mid-flight admission, so preemption "
                         "policies do not apply (drop --preempt)")

    if args.metrics_out:
        args.telemetry = True

    if args.replay:
        _replay(args)
        return

    # --timeline-out renders from a captured trace, so it implies capture
    # (recording is observation-only; the schedule is unchanged)
    recorder = TraceRecorder() if (args.trace_out or args.timeline_out) \
        else None

    if args.real:
        cfg = get_config(args.arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        # real mode restores from the MATERIALIZED chunk store: prefix KV
        # lives as content-addressed, deduplicated chunks across
        # hbm/host/disk tiers and load ops move its actual bytes
        store = None
        if not cfg.attn_window:
            store = ChunkStore(chunk_size=16, quant=args.kv_quant,
                               store_dir=args.store_dir,
                               default_tier=args.kv_tier)
        eng = RealServingEngine(model, params, system=args.system,
                                stages=min(args.stages, 2), chunk_size=16,
                                max_batch=args.max_batch,
                                io_channels=args.io_channels,
                                preempt=args.preempt, evict=args.evict,
                                admission=args.admission,
                                prefetch=args.prefetch,
                                kvstore=store, datapath=args.datapath,
                                sanitize=args.sanitize or None,
                                telemetry=args.telemetry or None)
        decode_len = args.decode_len if args.decode_len >= 0 else 8
        # with a preemption policy armed, stagger arrivals and mark every
        # other request urgent so admission pressure actually exercises it;
        # without one, keep the classic simultaneous-arrival smoke exactly
        if args.preempt != "none":
            reqs = [Request(f"r{i}", 0.1 * i, prefix_len=64 + 32 * i,
                            new_len=16, decode_len=decode_len, priority=i % 2,
                            deadline=0.1 * i + (2.0 if i % 2 else 120.0))
                    for i in range(args.requests)]
        else:
            reqs = [Request(f"r{i}", 0.0, prefix_len=64 + 32 * i, new_len=16,
                            decode_len=decode_len)
                    for i in range(args.requests)]
        rep = eng.serve(reqs, trace=recorder)
        if args.trace_out:
            _save_trace(recorder, args.trace_out, arch=args.arch)
        out = {"system": args.system, "mode": "real",
               "admission": args.admission,
               "lifecycle": rep.stats,
               "preemptions": sum(rep.preemptions.values()),
               "compute_busy": round(rep.compute_busy, 3),
               "io_busy": round(rep.io_busy, 3),
               "decode_busy": round(rep.decode_busy, 3),
               "overlap_decode_restore": round(rep.overlap_decode_restore, 3)}
        if rep.sanitizer is not None:
            out["sanitizer"] = rep.sanitizer
        if store is not None:
            out["storage"] = {
                "chunks": len(store.chunks), "dedup_hits": store.dedup_hits,
                "bytes_put": store.bytes_put,
                "bytes_transferred": store.bytes_transferred,
                "io_hits": store.io_hits,
                "skipped_transfers": store.skipped_transfers,
                "store_misses": store.store_misses,
                "forks": store.forks,
                "pool_blocks": store.pool.live_blocks(),
                "cow_copies": store.pool.cow_copies,
                "cow_bytes": store.pool.bytes_copied}
        if eng.datapath is not None:
            dp, ex = eng.datapath, eng.executor
            out["datapath"] = {
                "mode": args.datapath,
                "channels": len(dp.streams),
                "kernel_launches": dp.kernel_launches,
                "resident_copies": dp.resident_copies,
                "staged_puts": sum(s.puts for s in dp.streams),
                "staged_bytes": sum(s.bytes_staged for s in dp.streams),
                "fused_loads": ex.fused_loads,
                "legacy_loads": ex.legacy_loads,
                "load_dispatches": ex.load_dispatches,
                # measured host→device bytes/sec per engine channel (None
                # until a channel carries a measured transfer)
                "channel_gbps": [round(b / 1e9, 6) if b else None
                                 for b in dp.bandwidths()]}
        elif store is not None:
            out["datapath"] = {"mode": "legacy",
                               "load_dispatches":
                                   eng.executor.load_dispatches}
        _emit_outputs(out, rep, recorder, args)
        return

    cfg = get_config(args.arch)
    if args.workload == "bursty_priority":
        from repro.serving.workloads import bursty_priority
        reqs = bursty_priority(args.requests, seed=args.seed,
                               burst_size=args.burst_size,
                               burst_every=args.burst_every)
    else:
        reqs = generate(args.workload, args.requests, seed=args.seed)
    if args.decode_len >= 0:
        for r in reqs:
            r.decode_len = args.decode_len
    store = TieredKVStore(remote_bw=IO_BANDWIDTHS[args.bandwidth],
                          quant=args.kv_quant)
    eng = SimServingEngine(cfg, HARDWARE[args.hardware],
                           io_bandwidth=IO_BANDWIDTHS[args.bandwidth],
                           system=args.system, stages=args.stages,
                           max_batch=args.max_batch, kvstore=store,
                           io_channels=args.io_channels,
                           preempt=args.preempt, evict=args.evict,
                           kv_tier=args.kv_tier, admission=args.admission,
                           prefetch=args.prefetch,
                           sanitize=args.sanitize or None,
                           telemetry=args.telemetry or None)
    rep = eng.run(reqs, trace=recorder)
    if args.trace_out:
        _save_trace(recorder, args.trace_out, arch=args.arch)
    out = {
        "system": args.system, "workload": args.workload,
        "bandwidth": args.bandwidth, "hardware": args.hardware,
        "stages": args.stages, "preempt": args.preempt,
        "admission": args.admission,
        "lifecycle": rep.stats,
        "preemptions": sum(rep.preemptions.values()),
        "compute_busy": round(rep.compute_busy, 3),
        "io_busy": round(rep.io_busy, 3),
        "decode_busy": round(rep.decode_busy, 3),
        "overlap_decode_restore": round(rep.overlap_decode_restore, 3)}
    if rep.sanitizer is not None:
        out["sanitizer"] = rep.sanitizer
    _emit_outputs(out, rep, recorder, args)


def _emit_outputs(out: dict, rep, recorder, args):
    """Shared report/metrics/timeline emission for the sim and real paths.
    stdout gets the report (with the telemetry counters inlined when
    collected); the full snapshot and the Perfetto timeline go to their
    --*-out files."""
    if rep.telemetry is not None:
        # counters only on stdout — the gauge series and phase timelines
        # can be large; --metrics-out carries the full snapshot
        out["telemetry"] = {"counters": rep.telemetry["metrics"]["counters"]}
    if args.metrics_out:
        _save_metrics(rep.telemetry, args.metrics_out)
    if args.timeline_out:
        _save_timeline(recorder.trace, args.timeline_out,
                       telemetry=rep.telemetry)
    print(dumps_report(out))


if __name__ == "__main__":
    main()
