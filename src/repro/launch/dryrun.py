import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract the roofline terms.

For each cell this:
  1. builds the model + sharding rules for the mesh,
  2. jits the right step (train_step / prefill_step / decode_step) with
     explicit in/out shardings,
  3. ``.lower().compile()`` — success proves the distribution config is
     coherent (sharding divisibility, collectives, memory),
  4. records ``memory_analysis()`` (bytes/device), ``cost_analysis()``
     (FLOPs/bytes, per-device post-SPMD), and per-kind collective bytes
     parsed from the compiled HLO,
  5. writes one JSON per cell under benchmarks/results/dryrun/.

Run one cell:   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k
Run the sweep:  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
(the sweep shells out one subprocess per cell so XLA state never accumulates)
"""
import argparse
import json
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.config import SHAPES, ModelConfig, ShapeConfig, supports_shape
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed import sharding as shr
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training import AdamWConfig, init_opt_state, make_train_step
from repro.training.optimizer import OptState

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

# Serving MoE dispatch uses capacity-factor routing in the compiled plan
# (restoration-equality paths on real runs are dropless; see DESIGN.md).
_MOE_GROUPS = {"train": 16, "prefill": 16, "decode": 1}


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_mode == "tokens":
            return {"batch": {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}}
        return {"batch": {
            "embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}}
    if shape.kind == "prefill":
        if cfg.input_mode == "tokens":
            return {"inputs": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"inputs": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
    # decode: one new token against a seq_len cache
    if cfg.input_mode == "tokens":
        tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((b, cfg.d_model), jnp.bfloat16)
    return {"tokens": tok, "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# collective-byte extraction from compiled HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "u4": 1, "s4": 1}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+\[[^\]]*\])(?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved over links, by collective kind.

    Ring-algorithm accounting from the per-device (post-SPMD) module:
      all-gather R bytes result, group n: (n-1)/n · R
      reduce-scatter result R: (n-1) · R     (operand is n·R per device)
      all-reduce result R: 2(n-1)/n · R
      all-to-all result R: (n-1)/n · R
      collective-permute result R: R
    """
    out = {k: 0.0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3)
        r = _shape_bytes(shape_str)
        g = _GROUP_RE.search(line)
        n = int(g.group(2)) if g else 2
        if kind == "all-gather":
            moved = r * (n - 1) / max(1, n)
        elif kind == "reduce-scatter":
            moved = r * (n - 1)
        elif kind == "all-reduce":
            moved = 2 * r * (n - 1) / max(1, n)
        elif kind == "all-to-all":
            moved = r * (n - 1) / max(1, n)
        else:
            moved = r
        out[kind] += moved
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                mode_override=None, print_hlo: bool = False,
                decode_append: bool = False, restore_chunk: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not supports_shape(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": "full-attn @500k"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mode = mode_override or shr.choose_mode(cfg, shape)
    is_train = shape.kind == "train"
    model = build_model(
        cfg,
        param_dtype=jnp.float32 if is_train else jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        backend="flash",
        remat_policy="nothing" if is_train else "none",
        moe_groups=_MOE_GROUPS[shape.kind],
        moe_dropless=False)
    pspecs = shr.to_named(mesh, shr.param_pspecs(model, mode))
    specs = input_specs(cfg, shape)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            # microbatch = one sequence per (pod×data) batch shard: bounds
            # activation liveness while keeping the batch axes fully sharded
            shards = 1
            for a in shr.batch_axes(mesh):
                shards *= mesh.shape[a]
            accum = max(1, shape.global_batch // shards)
            step = make_train_step(model, opt_cfg, grad_accum=accum)
            params_sds = model.param_specs()
            opt_sds = jax.eval_shape(init_opt_state, params_sds)
            ospecs = shr.to_named(mesh, shr.opt_pspecs(model, mode))
            bspecs = shr.to_named(mesh, shr.data_pspecs(cfg, mesh, "train",
                                                        shape.global_batch))
            jitted = jax.jit(step,
                             in_shardings=(pspecs, ospecs, bspecs),
                             out_shardings=(pspecs, ospecs, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, specs["batch"])
        elif shape.kind == "prefill" and not restore_chunk:
            def prefill_step(params, inputs):
                return model.prefill(params, inputs)
            params_sds = model.param_specs()
            cache_specs = shr.to_named(mesh, shr.cache_pspecs(
                model, mesh, shape.global_batch, shape.seq_len))
            ispec = shr.to_named(mesh, shr.data_pspecs(cfg, mesh, "prefill",
                                                       shape.global_batch))
            jitted = jax.jit(prefill_step,
                             in_shardings=(pspecs, ispec),
                             out_shardings=(None, cache_specs))
            lowered = jitted.lower(params_sds, specs["inputs"])
        elif shape.kind == "prefill" and restore_chunk:
            # THE paper step: recompute-pointer chunk prefill against a
            # restored prefix cache (token-wise restoration at scale).
            C = 2048
            params_sds = model.param_specs()
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_specs = shr.to_named(mesh, shr.cache_pspecs(
                model, mesh, shape.global_batch, shape.seq_len))
            if cfg.input_mode == "tokens":
                chunk_sds = jax.ShapeDtypeStruct((shape.global_batch, C), jnp.int32)
            else:
                chunk_sds = jax.ShapeDtypeStruct(
                    (shape.global_batch, C, cfg.d_model), jnp.bfloat16)
            ispec = shr.to_named(mesh, shr.data_pspecs(cfg, mesh, "prefill",
                                                       shape.global_batch))

            def restore_chunk_step(params, chunk, cache, start_pos):
                return model.prefill_chunk(params, chunk, cache, start_pos)
            jitted = jax.jit(restore_chunk_step,
                             in_shardings=(pspecs, ispec, cache_specs, None),
                             out_shardings=(None, cache_specs),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_sds, chunk_sds, cache_sds,
                                   jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "decode" and decode_append and cfg.is_uniform:
            # §Perf optimisation: read-only cache + small append tail
            W = 64
            params_sds = model.param_specs()
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            tail_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, W))
            cache_specs = shr.to_named(mesh, shr.cache_pspecs(
                model, mesh, shape.global_batch, shape.seq_len))
            tail_specs = shr.to_named(mesh, shr.cache_pspecs(
                model, mesh, shape.global_batch, W))
            tspec = shr.to_named(mesh, shr.data_pspecs(cfg, mesh, "decode",
                                                       shape.global_batch))

            def decode_append_step(params, tokens, cache, tail, tail_len, pos):
                return model.decode_step_append(params, tokens, cache, tail,
                                                tail_len, pos)
            jitted = jax.jit(decode_append_step,
                             in_shardings=(pspecs, tspec, cache_specs,
                                           tail_specs, None, None),
                             out_shardings=(None, tail_specs),
                             donate_argnums=(3,))
            lowered = jitted.lower(params_sds, specs["tokens"], cache_sds,
                                   tail_sds, specs["pos"], specs["pos"])
        else:
            def decode_step(params, tokens, cache, pos):
                return model.decode_step(params, tokens, cache, pos)
            params_sds = model.param_specs()
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_specs = shr.to_named(mesh, shr.cache_pspecs(
                model, mesh, shape.global_batch, shape.seq_len))
            tspec = shr.to_named(mesh, shr.data_pspecs(cfg, mesh, "decode",
                                                       shape.global_batch))
            jitted = jax.jit(decode_step,
                             in_shardings=(pspecs, tspec, cache_specs, None),
                             out_shardings=(None, cache_specs),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_sds, specs["tokens"], cache_sds,
                                   specs["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)
    # trip-count-corrected accounting (cost_analysis counts while bodies once)
    from repro.launch.hlo_cost import analyze as hlo_analyze
    corrected = hlo_analyze(hlo)
    pc = cfg.param_counts()
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode, "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": float(ca.get("flops", -1.0)),
        "bytes_per_device": float(ca.get("bytes accessed", -1.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes,
        },
        "collectives": colls,
        "corrected": {
            "dot_flops_per_device": corrected["dot_flops"],
            "collective_bytes": corrected["collective_bytes"],
            "collective_total_bytes": corrected["collective_total_bytes"],
            "while_trip_counts": corrected["while_trip_counts"],
        },
        "params_total": pc["total"], "params_active": pc["active"],
        "params_embedding": pc["embedding"],
    }
    if print_hlo:
        result["hlo"] = hlo
    return result


def cells(multi_pod_mode: str):
    pods = {"single": [False], "multi": [True], "both": [False, True]}[multi_pod_mode]
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            for mp in pods:
                yield arch, shape_name, mp, supports_shape(cfg, shape)


def _result_path(arch: str, shape_name: str, multi_pod: bool) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh = "2x16x16" if multi_pod else "16x16"
    return os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--mode", default=None, help="override sharding mode")
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch, shape_name, mp, ok in cells(args.multi_pod):
            path = _result_path(arch, shape_name, mp)
            if os.path.exists(path) and not args.force:
                print(f"[cached] {path}")
                continue
            if not ok:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape_name,
                               "mesh": "2x16x16" if mp else "16x16",
                               "skipped": "full-attn @500k"}, f, indent=1)
                print(f"[skip]   {arch} × {shape_name} (full-attn @500k)")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name,
                   "--multi-pod", "multi" if mp else "single"]
            print(f"[run]    {arch} × {shape_name} × {'2x16x16' if mp else '16x16'}",
                  flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True,
                               env={**os.environ, "PYTHONPATH": "src"})
            if r.returncode != 0:
                failures.append((arch, shape_name, mp, r.stderr[-2000:]))
                print(r.stderr[-2000:])
        if failures:
            print(f"\n{len(failures)} FAILURES:")
            for a, s, mp, err in failures:
                print(f"  {a} × {s} × {'multi' if mp else 'single'}")
            sys.exit(1)
        print("\nall cells compiled OK")
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    mp = args.multi_pod == "multi"
    res = dryrun_cell(args.arch, args.shape, mp, mode_override=args.mode)
    path = _result_path(args.arch, args.shape, mp)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    print(json.dumps({k: v for k, v in res.items() if k != "hlo"}, indent=1))
    print(f"\nmemory_analysis: {res.get('memory')}")
    print(f"cost_analysis flops/device: {res.get('flops_per_device'):.3e}")


if __name__ == "__main__":
    main()
