"""Perfetto timeline export: engine schedules as Chrome trace-event JSON.

Converts an engine ``ops_log`` (plus, when available, the captured
``ScheduleTrace`` events and a telemetry snapshot) into the Chrome
trace-event format [1] that Perfetto (https://ui.perfetto.dev) and
chrome://tracing load directly:

  * one named track per engine resource (``comp*``, ``io*``, ``decode``),
    duration ("X") slices for every dispatched op,
  * per-request FLOW events stitching RESTORING -> PREFILL -> DECODE
    across tracks (follow a request's arrows through the schedule),
  * ``:aborted`` ops as instant ("i") markers at the abort point,
  * counter ("C") tracks: queue depth and active batch size (derived from
    the trace's admit/finish events), measured per-channel bandwidth at
    each I/O dispatch, and — when a telemetry snapshot rides along —
    storage-tier occupancy bytes (HBM et al.) over time.

Offline mode renders a timeline from ANY captured trace without
re-running the engine, so every golden/replay trace is viewable:

    PYTHONPATH=src python -m repro.obs.timeline trace.json [-o out.json]

[1] https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

US = 1e6    # trace-event timestamps are microseconds

#: op-desc tag -> slice category (colors the tracks by phase in Perfetto)
_TAG_CATS = {"c": "restore-compute", "l": "restore-io", "p": "prefill",
             "pf": "prefetch"}


def _desc_category(resource: str, desc: str) -> str:
    if resource == "decode":
        return "decode"
    tag = desc.rsplit(":", 1)[-1]
    if tag == "pf":
        return _TAG_CATS["pf"]
    return _TAG_CATS.get(tag[:1], "op")


def _resource_order(resource: str) -> Tuple[int, int]:
    """comp* first, then io*, then decode — stable track ordering."""
    for rank, prefix in ((0, "comp"), (1, "io")):
        if resource.startswith(prefix) and resource[len(prefix):].isdigit():
            return rank, int(resource[len(prefix):])
    return (2, 0)


def _desc_rids(resource: str, desc: str) -> List[str]:
    """Request ids an ops_log entry belongs to (decode slices are the
    whole batch, comma-joined)."""
    if resource == "decode":
        return desc.split(",")
    return [desc.rsplit(":", 1)[0]]


def ops_to_chrome(ops_log, *, events: Optional[list] = None,
                  requests: Optional[list] = None,
                  telemetry: Optional[dict] = None) -> dict:
    """Build the Chrome trace-event document from an engine ``ops_log``.

    ``events``/``requests`` are the captured ``ScheduleTrace`` event and
    request dict lists (optional — they add the queue-depth/active counter
    tracks); ``telemetry`` is a ``Telemetry.snapshot()`` dict (optional —
    it adds the storage-occupancy counter tracks)."""
    resources = sorted({r for _, _, r, _ in ops_log}, key=_resource_order)
    tids = {r: i for i, r in enumerate(resources)}
    out: List[dict] = [
        {"ph": "M", "pid": 0, "name": "process_name",
         "args": {"name": "cacheflow-engine"}}]
    for r, tid in tids.items():
        out.append({"ph": "M", "pid": 0, "tid": tid, "name": "thread_name",
                    "args": {"name": r}})
        out.append({"ph": "M", "pid": 0, "tid": tid,
                    "name": "thread_sort_index", "args": {"sort_index": tid}})

    # duration slices + aborted-op instant markers
    per_rid: Dict[str, List[tuple]] = {}
    for t0, t1, resource, desc in ops_log:
        tid = tids[resource]
        if desc.endswith(":aborted"):
            out.append({"ph": "i", "s": "t", "pid": 0, "tid": tid,
                        "ts": t1 * US, "name": desc,
                        "cat": "abort"})
            continue
        out.append({"ph": "X", "pid": 0, "tid": tid, "ts": t0 * US,
                    "dur": (t1 - t0) * US, "name": desc,
                    "cat": _desc_category(resource, desc)})
        for rid in _desc_rids(resource, desc):
            per_rid.setdefault(rid, []).append((t0, tid, resource))

    # per-request flow events: RESTORING -> PREFILL -> DECODE arrows.
    # Each anchor binds to the slice starting at (ts, tid); only the FIRST
    # decode slice per request is stitched (the recurring steps would just
    # repaint the same track).
    for flow_id, rid in enumerate(sorted(per_rid)):
        anchors, seen_decode = [], False
        for t0, tid, resource in sorted(per_rid[rid]):
            if resource == "decode":
                if seen_decode:
                    continue
                seen_decode = True
            anchors.append((t0, tid))
        if len(anchors) < 2:
            continue
        for i, (t0, tid) in enumerate(anchors):
            ph = "s" if i == 0 else ("f" if i == len(anchors) - 1 else "t")
            ev = {"ph": ph, "pid": 0, "tid": tid, "ts": t0 * US,
                  "id": flow_id, "cat": "lifecycle", "name": rid}
            if ph == "f":
                ev["bp"] = "e"      # bind the finish to the enclosing slice
            out.append(ev)

    out += _counter_events(events, requests, tids)
    out += _telemetry_counters(telemetry)
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"source": "repro.obs.timeline",
                          "resources": resources}}


def _counter_events(events, requests, tids) -> List[dict]:
    """Queue-depth / active-batch counter tracks from trace events, and
    per-channel measured bandwidth at each I/O dispatch."""
    out: List[dict] = []
    if events is None:
        return out
    edges: List[Tuple[float, int, int]] = []   # (t, d_queued, d_active)
    for r in requests or []:
        edges.append((r.get("arrival", 0.0), +1, 0))
    for e in events:
        kind = e.get("kind") if isinstance(e, dict) else e.kind
        t = e.get("t") if isinstance(e, dict) else e.t
        if kind == "admit":
            edges.append((t, -1, +1))
        elif kind == "finish":
            edges.append((t, 0, -1))
        elif kind == "preempt":
            edges.append((t, 0, -1))
        elif kind == "resume":
            edges.append((t, 0, +1))
        elif kind == "dispatch":
            res = e.get("resource") if isinstance(e, dict) else e.resource
            bw = e.get("bandwidth") if isinstance(e, dict) else e.bandwidth
            if bw and res and res.startswith("io") and res in tids:
                out.append({"ph": "C", "pid": 0, "ts": t * US,
                            "name": f"bandwidth_gbps:{res}",
                            "args": {"gbps": bw / 1e9}})
    queued = active = 0
    for t, dq, da in sorted(edges):
        queued += dq
        active += da
        out.append({"ph": "C", "pid": 0, "ts": t * US, "name": "queue_depth",
                    "args": {"queued": queued}})
        out.append({"ph": "C", "pid": 0, "ts": t * US,
                    "name": "active_requests", "args": {"active": active}})
    return out


def _telemetry_counters(telemetry) -> List[dict]:
    """Storage-occupancy counter tracks from a telemetry snapshot's gauge
    series (``storage.tier_used_bytes{tier=...}`` over engine time)."""
    out: List[dict] = []
    if not telemetry:
        return out
    gauges = telemetry.get("metrics", {}).get("gauges", {})
    for key, g in sorted(gauges.items()):
        if not key.startswith("storage.tier_used_bytes"):
            continue
        tier = key.split("tier=", 1)[-1].rstrip("}")
        for t, v in g.get("series", []):
            out.append({"ph": "C", "pid": 0, "ts": t * US,
                        "name": f"tier_bytes:{tier}", "args": {"bytes": v}})
    return out


def trace_to_chrome(trace, telemetry: Optional[dict] = None) -> dict:
    """Render a captured ``ScheduleTrace`` (any schema version) without
    re-running the engine.  Prefers the captured result's ``ops_log``;
    traces without one (hand-stripped) reconstruct slices from their
    pinned dispatch durations."""
    if trace.result and trace.result.get("ops_log"):
        ops_log = [tuple(e) for e in trace.result["ops_log"]]
    else:
        ops_log = []
        for e in trace.events:
            if e.kind == "dispatch" and e.duration is not None:
                op = e.op or {}
                tag = {"compute": "c", "load": "l", "prefill": "p",
                       "prefetch": "pf"}.get(op.get("kind"), "?")
                unit = "" if tag == "pf" else str(op.get("unit", ""))
                ops_log.append((e.t, e.t + e.duration, e.resource,
                                f"{op.get('request_id')}:{tag}{unit}"))
            elif e.kind == "decode_step" and e.duration is not None:
                ops_log.append((e.t, e.t + e.duration, "decode",
                                ",".join(e.requests or [])))
    events = [e.to_dict() for e in trace.events]
    return ops_to_chrome(ops_log, events=events, requests=trace.requests,
                         telemetry=telemetry)


def result_to_chrome(result, *, events=None, requests=None,
                     telemetry: Optional[dict] = None) -> dict:
    """Render a live ``EngineResult`` (no trace capture needed)."""
    return ops_to_chrome(result.ops_log, events=events, requests=requests,
                         telemetry=telemetry)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.timeline",
        description="Render a captured ScheduleTrace as Chrome trace-event "
                    "JSON (open the output in https://ui.perfetto.dev).")
    ap.add_argument("trace", help="ScheduleTrace JSON (serve --trace-out)")
    ap.add_argument("-o", "--out", default=None,
                    help="output path (default: <trace>.timeline.json)")
    args = ap.parse_args(argv)
    from repro.core.trace import ScheduleTrace
    trace = ScheduleTrace.load(args.trace)
    doc = trace_to_chrome(trace)
    out_path = args.out or (args.trace.rsplit(".json", 1)[0]
                            + ".timeline.json")
    with open(out_path, "w") as f:
        # allow_nan=False: the document must be standard JSON — Perfetto's
        # parser (rightly) rejects bare NaN/Infinity tokens
        json.dump(doc, f, indent=1, allow_nan=False)
    n = len(doc["traceEvents"])
    print(f"# timeline ({n} events, {len(trace.requests)} requests) -> "
          f"{out_path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
