"""CacheFlow observability layer (DESIGN.md §15).

``registry``  — catalog-enforced counters/gauges/histograms
(:data:`METRIC_CATALOG` is the single source of metric names; codelint
checks every literal against it).  ``telemetry`` — the opt-in
:class:`Telemetry` hook ``EngineCore`` drives (``telemetry=`` /
``CACHEFLOW_TELEMETRY=1`` / ``serve --telemetry``).  ``timeline`` — the
Perfetto/Chrome trace-event exporter
(``python -m repro.obs.timeline trace.json``).
"""
from repro.obs.registry import (METRIC_CATALOG, Counter, Gauge, Histogram,
                                MetricsRegistry)
from repro.obs.telemetry import Telemetry, telemetry_env_enabled
from repro.obs.timeline import (ops_to_chrome, result_to_chrome,
                                trace_to_chrome)

__all__ = [
    "METRIC_CATALOG", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Telemetry", "telemetry_env_enabled",
    "ops_to_chrome", "result_to_chrome", "trace_to_chrome",
]
