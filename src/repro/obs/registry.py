"""Engine-wide metrics registry (DESIGN.md §15).

Three primitive types, all driven by the ENGINE clock (virtual seconds in
sim, measured wall seconds in real mode — whatever ``EngineCore.run``'s
``now`` is):

  * :class:`Counter`   — monotone non-decreasing accumulator.
  * :class:`Gauge`     — last-value sample; when a timestamp is supplied the
    gauge additionally keeps its full ``(t, value)`` series, which is what
    the timeline exporter renders as Perfetto counter tracks.
  * :class:`Histogram` — fixed EXACT bucket boundaries declared in the
    catalog (never derived from data, so two runs' histograms always merge
    bucket-for-bucket); invariant: ``count == sum(bucket_counts)``.

Every metric name must be declared in :data:`METRIC_CATALOG` with its type,
label schema and owning layer — ``analysis/codelint.py`` statically checks
that every metric-name literal in the codebase is registered here (the same
pattern as the ``EVENT_KINDS`` trace-schema rule), and the registry enforces
the type and exact label keys at instantiation time.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

#: Exact-bucket boundaries shared by the latency histograms (seconds).
_LATENCY_BUCKETS = (0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
                    60.0, 120.0)
#: Batch-size histogram boundaries (requests per admitted/decode batch).
_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)

#: The central metric catalog: name -> {type, labels, layer[, buckets]}.
#: ``layer`` names the module that owns the signal (mirrors DESIGN.md §14's
#: invariant catalog).  This dict is a PURE LITERAL — codelint parses it
#: from the AST, so no computed keys.
METRIC_CATALOG = {
    # ---- engine core (core/engine_core.py) ----
    "engine.queue_depth": {
        "type": "gauge", "labels": (), "layer": "core/engine_core"},
    "engine.active_requests": {
        "type": "gauge", "labels": (), "layer": "core/engine_core"},
    "engine.admitted_batch_size": {
        "type": "histogram", "labels": (), "layer": "core/engine_core",
        "buckets": _BATCH_BUCKETS},
    "engine.decode_batch_size": {
        "type": "histogram", "labels": (), "layer": "core/engine_core",
        "buckets": _BATCH_BUCKETS},
    "engine.admissions_total": {
        "type": "counter", "labels": (), "layer": "core/engine_core"},
    "engine.preemptions_total": {
        "type": "counter", "labels": ("mode",), "layer": "core/engine_core"},
    "engine.aborts_total": {
        "type": "counter", "labels": ("resource",),
        "layer": "core/engine_core"},
    "engine.gate_outcomes_total": {
        "type": "counter", "labels": ("outcome",),
        "layer": "core/engine_core"},
    "engine.prefetch_gate_total": {
        "type": "counter", "labels": ("outcome",),
        "layer": "core/engine_core"},
    "engine.dispatches_total": {
        "type": "counter", "labels": ("kind",), "layer": "core/engine_core"},
    "engine.decode_steps_total": {
        "type": "counter", "labels": (), "layer": "core/engine_core"},
    "engine.resource_busy_seconds": {
        "type": "gauge", "labels": ("resource",),
        "layer": "core/engine_core"},
    "engine.ttft_seconds": {
        "type": "histogram", "labels": (), "layer": "core/engine_core",
        "buckets": _LATENCY_BUCKETS},
    "engine.restore_seconds": {
        "type": "histogram", "labels": (), "layer": "core/engine_core",
        "buckets": _LATENCY_BUCKETS},
    "engine.phase_transitions_total": {
        "type": "counter", "labels": ("phase",),
        "layer": "core/engine_core"},
    # ---- restoration data path (core/datapath.py) ----
    "datapath.channel_gbps": {
        "type": "gauge", "labels": ("channel",), "layer": "core/datapath"},
    "datapath.channel_bytes_total": {
        "type": "counter", "labels": ("channel",), "layer": "core/datapath"},
    "datapath.kernel_launches_total": {
        "type": "counter", "labels": (), "layer": "core/datapath"},
    # ---- storage tiers (storage/placement.py, storage/chunkstore.py) ----
    "storage.tier_used_bytes": {
        "type": "gauge", "labels": ("tier",), "layer": "storage/placement"},
    "storage.tier_capacity_bytes": {
        "type": "gauge", "labels": ("tier",), "layer": "storage/placement"},
    "storage.events_total": {
        "type": "counter", "labels": ("event",),
        "layer": "storage/chunkstore"},
    "storage.bytes_total": {
        "type": "counter", "labels": ("op",), "layer": "storage/chunkstore"},
}


def _label_key(name: str, labels: Dict[str, str]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone accumulator.  ``inc`` rejects negative deltas — a counter
    that can go down is a gauge wearing the wrong hat."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, delta: float = 1.0) -> None:
        if delta < 0:
            raise ValueError(
                f"counter {self.name}: negative increment {delta}")
        self.value += delta


class Gauge:
    """Last-value sample; ``set(v, t=...)`` additionally appends to the
    gauge's ``(t, value)`` series (the timeline exporter's counter-track
    source).  Timestamps are engine-clock seconds."""

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None
        self.series: List[Tuple[float, float]] = []

    def set(self, value: float, t: Optional[float] = None) -> None:
        self.value = float(value)
        if t is not None:
            self.series.append((float(t), float(value)))


class Histogram:
    """Fixed exact-boundary histogram: ``buckets`` are the declared upper
    bounds; observations land in the first bucket whose bound is >= value,
    or the overflow slot.  ``count == sum(bucket_counts)`` always."""

    def __init__(self, name: str, buckets: Iterable[float]):
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(buckets)
        if list(self.bounds) != sorted(self.bounds) or not self.bounds:
            raise ValueError(
                f"histogram {self.name}: buckets must be sorted, non-empty")
        # one slot per declared bound + the overflow slot
        self.bucket_counts: List[int] = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        i = 0
        while i < len(self.bounds) and value > self.bounds[i]:
            i += 1
        self.bucket_counts[i] += 1
        self.count += 1
        self.sum += float(value)


class MetricsRegistry:
    """Catalog-enforced metric factory.

    ``counter(name, **labels)`` / ``gauge(...)`` / ``histogram(...)`` return
    the live instance for that (name, labels) cell, creating it on first
    use.  The name must be declared in :data:`METRIC_CATALOG` with the
    matching type, and the label KEYS must equal the catalog's label schema
    exactly — silent cardinality drift is how metric layers rot."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind: str, labels: Dict[str, str]):
        spec = METRIC_CATALOG.get(name)
        if spec is None:
            raise KeyError(f"metric {name!r} is not in METRIC_CATALOG")
        if spec["type"] != kind:
            raise TypeError(f"metric {name!r} is a {spec['type']}, "
                            f"requested as {kind}")
        if tuple(sorted(labels)) != tuple(sorted(spec["labels"])):
            raise ValueError(
                f"metric {name!r}: labels {sorted(labels)} != declared "
                f"schema {sorted(spec['labels'])}")
        key = _label_key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            if kind == "counter":
                m = Counter(key)
            elif kind == "gauge":
                m = Gauge(key)
            else:
                m = Histogram(key, spec["buckets"])
            self._metrics[key] = m
        return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(name, "counter", labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(name, "gauge", labels)

    def histogram(self, name: str, **labels: str) -> Histogram:
        return self._get(name, "histogram", labels)

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-JSON view: the exposition format ``ServingReport.telemetry``
        and ``serve --metrics-out`` carry.  Gauge series ride along so the
        timeline exporter can render counter tracks offline."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for key in sorted(self._metrics):
            m = self._metrics[key]
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][key] = {
                    "value": m.value,
                    "series": [[t, v] for t, v in m.series]}
            else:
                out["histograms"][key] = {
                    "buckets": list(m.bounds),
                    "bucket_counts": list(m.bucket_counts),
                    "count": m.count, "sum": m.sum}
        return out
