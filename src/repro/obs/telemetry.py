"""Opt-in engine telemetry: the observation twin of the sanitizer.

``EngineCore(telemetry=True)`` (or ``CACHEFLOW_TELEMETRY=1`` in the
environment, or ``serve --telemetry``) attaches a :class:`Telemetry`
instance to the event loop.  Every hook in the engine is behind an
``if tel is not None`` guard, so the default-off path adds zero work —
and the hooks themselves are PURE OBSERVERS: they read loop state, never
mutate it, so a telemetry-enabled run is bit-identical to a disabled one
on ``EngineResult`` and ``ops_log`` (property-tested in
``tests/test_obs.py``).

What it collects, on the engine clock (virtual seconds in sim, measured
wall seconds in real mode):

  * queue depth / active batch size as ``(t, value)`` series,
  * admitted- and decode-batch-size histograms,
  * benefit-gate and prefetch-gate outcomes, preempt/evict/abort counts,
  * per-resource busy seconds and (real mode) measured per-channel GB/s
    from the fused datapath's ``TransferStream`` counters,
  * storage-tier occupancy bytes and the hit/miss/promote/demote counters
    from whichever KV store the engine runs against,
  * per-request phase-transition timestamps
    (arrive → admit → restored → first_token → finish, plus
    preempt/resume), the raw material for the timeline's flow events.

``snapshot()`` is the exposition API: a plain-JSON dict carried by
``ServingReport.telemetry``, written by ``serve --metrics-out`` and
consumed by the benchmarks.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

from repro.obs.registry import MetricsRegistry


def telemetry_env_enabled() -> bool:
    """The ``CACHEFLOW_TELEMETRY`` opt-in, same convention as the
    sanitizer's ``CACHEFLOW_SANITIZE``."""
    return os.environ.get(
        "CACHEFLOW_TELEMETRY", "0").lower() not in ("", "0", "false")


class Telemetry:
    """One engine run's metric collection.  Constructed fresh by
    ``EngineCore.run`` (or passed in pre-built); ``begin`` binds the core
    so run-end sweeps can read the KV store and datapath counters."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry or MetricsRegistry()
        self.core = None
        # rid -> [[t, phase], ...] in engine order; phases are the
        # lifecycle edges: arrive, admit, preempt, resume, restored,
        # first_token, finish
        self.phases: Dict[str, List[list]] = {}
        self._arrival: Dict[str, float] = {}
        self._admit_t: Dict[str, float] = {}

    # ------------------------------------------------------------------
    def begin(self, core) -> None:
        self.core = core

    def _phase(self, now: float, rid: str, phase: str) -> None:
        self.phases.setdefault(rid, []).append([now, phase])
        self.registry.counter(
            "engine.phase_transitions_total", phase=phase).inc()

    def _sample_queues(self, now: float, queued: int, active: int) -> None:
        self.registry.gauge("engine.queue_depth").set(queued, t=now)
        self.registry.gauge("engine.active_requests").set(active, t=now)

    def _sample_tiers(self, now: float) -> None:
        """Read-only tier-occupancy sample at a lifecycle edge (this is
        what the timeline renders as the HBM-bytes counter track)."""
        pc = getattr(self.core.kvstore, "core", None) if self.core else None
        tiers = getattr(pc, "tiers", None)
        if not tiers:
            return
        for name, tier in tiers.items():
            self.registry.gauge(
                "storage.tier_used_bytes", tier=name).set(tier.used, t=now)

    # ---- engine hooks (every call site is behind `if tel is not None`) --
    def on_arrive(self, now: float, rid: str, *, queued: int,
                  active: int) -> None:
        self._arrival[rid] = now
        self._phase(now, rid, "arrive")
        self._sample_queues(now, queued, active)

    def on_admit(self, now: float, rid: str, *, queued: int,
                 active: int) -> None:
        self._admit_t[rid] = now
        self._phase(now, rid, "admit")
        self.registry.counter("engine.admissions_total").inc()
        self.registry.histogram("engine.admitted_batch_size").observe(active)
        self._sample_queues(now, queued, active)
        self._sample_tiers(now)

    def on_dispatch(self, now: float, resource: str, op, dur: float) -> None:
        self.registry.counter("engine.dispatches_total", kind=op.kind).inc()

    def on_decode_dispatch(self, now: float, dur: float,
                           rids: List[str]) -> None:
        self.registry.counter("engine.decode_steps_total").inc()
        self.registry.histogram("engine.decode_batch_size").observe(len(rids))

    def on_gate(self, now: float, rid: str, allowed: bool) -> None:
        self.registry.counter(
            "engine.gate_outcomes_total",
            outcome="allowed" if allowed else "denied").inc()

    def on_prefetch_gate(self, now: float, rid: str, allowed: bool) -> None:
        self.registry.counter(
            "engine.prefetch_gate_total",
            outcome="allowed" if allowed else "denied").inc()

    def on_abort(self, now: float, resource: str, op) -> None:
        # resource label is the KIND (comp/io), not the instance — bounded
        # cardinality regardless of channel count
        kind = "io" if resource.startswith("io") else "comp"
        self.registry.counter("engine.aborts_total", resource=kind).inc()

    def on_preempt(self, now: float, rid: str, *, evict: bool,
                   aborted_ops: int) -> None:
        self.registry.counter(
            "engine.preemptions_total",
            mode="evict" if evict else "park").inc()
        if aborted_ops:
            # the victim's in-flight ops become waste the moment the claim
            # is released (their completion events just free the resource)
            self.registry.counter(
                "engine.aborts_total", resource="preempt").inc(aborted_ops)
        self._phase(now, rid, "preempt")
        self._sample_tiers(now)

    def on_resume(self, now: float, rid: str) -> None:
        self._phase(now, rid, "resume")

    def on_restore_done(self, now: float, rid: str) -> None:
        self._phase(now, rid, "restored")
        start = self._admit_t.get(rid)
        if start is not None:
            self.registry.histogram(
                "engine.restore_seconds").observe(now - start)
        self._sample_tiers(now)

    def on_first_token(self, now: float, rid: str) -> None:
        self._phase(now, rid, "first_token")
        arr = self._arrival.get(rid)
        if arr is not None:
            self.registry.histogram("engine.ttft_seconds").observe(now - arr)

    def on_finish(self, now: float, rid: str, *, queued: int,
                  active: int) -> None:
        self._phase(now, rid, "finish")
        self._sample_queues(now, queued, active)
        self._sample_tiers(now)

    # ------------------------------------------------------------------
    def on_run_end(self, result) -> None:
        """Run-end sweep: per-resource busy seconds from the ops log (a
        pure function of the result, so it matches the engine's own
        accounting), measured per-channel bandwidth from the datapath's
        transfer streams, and the storage layer's counters."""
        busy: Dict[str, float] = {}
        for t0, t1, resource, desc in result.ops_log:
            if not desc.endswith(":aborted"):
                busy[resource] = busy.get(resource, 0.0) + (t1 - t0)
        for resource in sorted(busy):
            self.registry.gauge(
                "engine.resource_busy_seconds",
                resource=resource).set(busy[resource])
        self._sweep_datapath()
        self._sweep_storage()

    def _sweep_datapath(self) -> None:
        """Real mode: the fused datapath's per-channel ``TransferStream``s
        carry measured bytes and seconds — the serve observable behind the
        paper's per-channel bandwidth claims."""
        dp = getattr(getattr(self.core, "backend", None), "executor", None)
        dp = getattr(dp, "datapath", None)
        if dp is None:
            return
        self.registry.counter(
            "datapath.kernel_launches_total").inc(dp.kernel_launches)
        for c, (stream, bw) in enumerate(zip(dp.streams, dp.bandwidths())):
            self.registry.counter(
                "datapath.channel_bytes_total",
                channel=str(c)).inc(stream.bytes_moved)
            if bw:
                self.registry.gauge(
                    "datapath.channel_gbps",
                    channel=str(c)).set(bw / 1e9)

    def _sweep_storage(self) -> None:
        ks = getattr(self.core, "kvstore", None)
        if ks is None:
            return
        events = self.registry.counter
        # shared placement core: tier occupancy + promote/demote/drop
        pc = getattr(ks, "core", None)
        tiers = getattr(pc, "tiers", None)
        if tiers:
            for name, tier in tiers.items():
                self.registry.gauge(
                    "storage.tier_used_bytes", tier=name).set(tier.used)
                self.registry.gauge(
                    "storage.tier_capacity_bytes",
                    tier=name).set(tier.capacity)
            events("storage.events_total",
                   event="promote").inc(pc.promotions)
            events("storage.events_total", event="demote").inc(pc.demotions)
            events("storage.events_total", event="drop").inc(pc.drops)
        for attr, label in (("io_hits", "hit"), ("store_misses", "miss"),
                            ("dedup_hits", "dedup_hit"), ("forks", "fork"),
                            ("fetches", "fetch"),
                            ("skipped_transfers", "skipped_transfer")):
            v = getattr(ks, attr, None)
            if v is not None:
                events("storage.events_total", event=label).inc(v)
        for attr, label in (("bytes_put", "put"),
                            ("bytes_transferred", "transferred")):
            v = getattr(ks, attr, None)
            if v is not None:
                events("storage.bytes_total", op=label).inc(v)

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """The exposition API: plain-JSON metrics + per-request phase
        timestamps.  Carried by ``ServingReport.telemetry``, written by
        ``serve --metrics-out``, consumed by the benchmarks and the
        timeline exporter's counter tracks."""
        return {"metrics": self.registry.snapshot(),
                "phases": {rid: [list(p) for p in edges]
                           for rid, edges in sorted(self.phases.items())}}
