"""Repo-specific static lint (AST-level), run by CI's lint job.

    python -m repro.analysis.codelint [--root PATH] [--json]

Five rules encoding conventions this repo has paid for breaking:

  * ``kernel-oracle``   — every ``kernels/<name>/kernel.py`` ships a
    ``ref.py`` NumPy/JAX oracle AND an interpret-mode parity test (a test
    file that names the kernel and exercises ``interpret``).  Pallas
    kernels without an oracle rot silently on TPU-only CI.
  * ``at-set-loop``     — no ``.at[...].set(...)`` inside a Python loop in
    the restore hot path (``core/datapath.py``, ``core/executor.py``):
    each call is a full-slab XLA copy, the exact O(chunks x layers x
    fields) storm the fused datapath exists to avoid.  Annotate deliberate
    legacy baselines with ``# codelint: allow(at-set-loop)`` on the call
    or the loop header line.
  * ``unseeded-rng``    — no wall-clock (``time.time()``) or unseeded
    global RNG (bare ``random`` module, ``np.random.<dist>`` singleton,
    argument-less ``np.random.default_rng()``) in ``core/`` or
    ``storage/`` modules: both feed trace capture, and traces must replay
    bit-identically.  ``time.perf_counter`` (pure profiling) and
    ``jax.random`` (explicit keys) are fine.
  * ``trace-kinds``     — every trace event kind emitted or matched in
    ``core/trace.py`` is registered in the ``EVENT_KINDS`` schema version
    table, so the offline linter and the upgrader agree on the schema.
  * ``metric-catalog``  — every metric name passed as a string literal to a
    ``.counter()`` / ``.gauge()`` / ``.histogram()`` call anywhere under
    ``src/repro`` is registered in ``obs/registry.py``'s
    ``METRIC_CATALOG`` (the registry also enforces this at runtime, but
    telemetry is opt-in, so an unregistered name would otherwise only
    explode in the rare telemetry-on run).

Each ``check_*`` function takes explicit paths so the mutation self-tests
can point them at synthetic files.
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import List, Sequence

ALLOW_PRAGMA = "# codelint: allow("

#: np.random module-singleton entry points that draw from unseeded global
#: state (calling these in trace-feeding code breaks replay determinism)
NP_GLOBAL_DISTS = {
    "rand", "randn", "randint", "random", "random_sample", "choice",
    "shuffle", "permutation", "normal", "uniform", "exponential", "poisson",
    "seed", "bytes",
}


@dataclass
class CodeLintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _allowed(lines: Sequence[str], rule: str, *linenos: int) -> bool:
    tag = f"{ALLOW_PRAGMA}{rule})"
    return any(0 < n <= len(lines) and tag in lines[n - 1] for n in linenos)


# ---------------------------------------------------------------------------
# kernel-oracle
# ---------------------------------------------------------------------------


def check_kernel_oracles(kernels_dir: Path,
                         tests_dir: Path) -> List[CodeLintFinding]:
    out: List[CodeLintFinding] = []
    if not kernels_dir.is_dir():
        return out
    test_texts = {p: p.read_text() for p in sorted(tests_dir.glob("test_*.py"))} \
        if tests_dir.is_dir() else {}
    for kernel in sorted(kernels_dir.glob("*/kernel.py")):
        name = kernel.parent.name
        if not (kernel.parent / "ref.py").exists():
            out.append(CodeLintFinding(
                "kernel-oracle", str(kernel), 1,
                f"kernel {name!r} has no ref.py oracle next to kernel.py"))
        if not any(name in txt and "interpret" in txt
                   for txt in test_texts.values()):
            out.append(CodeLintFinding(
                "kernel-oracle", str(kernel), 1,
                f"kernel {name!r} has no interpret-mode parity test (no "
                f"test_*.py mentions both {name!r} and 'interpret')"))
    return out


# ---------------------------------------------------------------------------
# at-set-loop
# ---------------------------------------------------------------------------


def _is_at_set_call(node: ast.AST) -> bool:
    """Matches ``<expr>.at[...].set(...)`` / ``.add(...)`` etc."""
    if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
        return False
    sub = node.func.value
    return (isinstance(sub, ast.Subscript)
            and isinstance(sub.value, ast.Attribute)
            and sub.value.attr == "at")


def check_at_set_loops(paths: Sequence[Path]) -> List[CodeLintFinding]:
    out: List[CodeLintFinding] = []
    for path in paths:
        if not path.exists():
            continue
        src = path.read_text()
        lines = src.splitlines()
        tree = ast.parse(src, filename=str(path))
        # map each offending call to ALL enclosing loops so the allow
        # pragma may sit on the call line or any loop header above it
        enclosing: dict = {}
        for loop in ast.walk(tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            for node in ast.walk(loop):
                if _is_at_set_call(node):
                    enclosing.setdefault(id(node), (node, []))[1].append(
                        loop.lineno)
        for node, loop_lines in enclosing.values():
            if not _allowed(lines, "at-set-loop", node.lineno, *loop_lines):
                out.append(CodeLintFinding(
                    "at-set-loop", str(path), node.lineno,
                    f".at[].{node.func.attr}() inside a loop (line "
                    f"{min(loop_lines)}) — a full-slab XLA copy per "
                    f"iteration; use the fused datapath or annotate "
                    f"'{ALLOW_PRAGMA}at-set-loop)'"))
    return out


# ---------------------------------------------------------------------------
# unseeded-rng
# ---------------------------------------------------------------------------


def check_unseeded_rng(paths: Sequence[Path]) -> List[CodeLintFinding]:
    out: List[CodeLintFinding] = []
    for path in paths:
        if not path.exists():
            continue
        src = path.read_text()
        lines = src.splitlines()
        tree = ast.parse(src, filename=str(path))
        # names the stdlib random module is bound to in this file
        random_names = set()
        numpy_names = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "random":
                        random_names.add(a.asname or "random")
                    elif a.name == "numpy":
                        numpy_names.add(a.asname or "numpy")
            elif isinstance(node, ast.ImportFrom) and node.module == "random":
                if not _allowed(lines, "unseeded-rng", node.lineno):
                    out.append(CodeLintFinding(
                        "unseeded-rng", str(path), node.lineno,
                        "from random import ... pulls unseeded global-state "
                        "RNG into trace-feeding code; use "
                        "np.random.default_rng(seed)"))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not isinstance(f, ast.Attribute):
                continue
            if _allowed(lines, "unseeded-rng", node.lineno):
                continue
            # time.time()
            if f.attr == "time" and isinstance(f.value, ast.Name) \
                    and f.value.id == "time":
                out.append(CodeLintFinding(
                    "unseeded-rng", str(path), node.lineno,
                    "time.time() is nondeterministic wall clock; engine "
                    "time must come from the simulated clock "
                    "(time.perf_counter is fine for pure profiling)"))
                continue
            # random.<fn>() on the stdlib module
            if isinstance(f.value, ast.Name) and f.value.id in random_names:
                out.append(CodeLintFinding(
                    "unseeded-rng", str(path), node.lineno,
                    f"random.{f.attr}() draws from unseeded global state; "
                    f"use np.random.default_rng(seed)"))
                continue
            # np.random.<...>
            mod = f.value
            if isinstance(mod, ast.Attribute) and mod.attr == "random" \
                    and isinstance(mod.value, ast.Name) \
                    and mod.value.id in (numpy_names | {"np"}):
                if f.attr == "default_rng" and not node.args \
                        and not node.keywords:
                    out.append(CodeLintFinding(
                        "unseeded-rng", str(path), node.lineno,
                        "np.random.default_rng() without a seed is "
                        "entropy-seeded; pass an explicit seed"))
                elif f.attr in NP_GLOBAL_DISTS:
                    out.append(CodeLintFinding(
                        "unseeded-rng", str(path), node.lineno,
                        f"np.random.{f.attr}() uses the unseeded global "
                        f"generator; use np.random.default_rng(seed)"))
    return out


# ---------------------------------------------------------------------------
# trace-kinds
# ---------------------------------------------------------------------------


def check_trace_kinds(trace_py: Path) -> List[CodeLintFinding]:
    out: List[CodeLintFinding] = []
    if not trace_py.exists():
        return [CodeLintFinding("trace-kinds", str(trace_py), 1,
                                "trace module not found")]
    tree = ast.parse(trace_py.read_text(), filename=str(trace_py))
    registered = None
    for node in ast.walk(tree):
        if isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and \
                node.target.id == "EVENT_KINDS" and node.value is not None:
            value = node.value
        elif isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "EVENT_KINDS"
                for t in node.targets):
            value = node.value
        else:
            continue
        if isinstance(value, ast.Dict):
            registered = {k.value for k in value.keys
                          if isinstance(k, ast.Constant)}
    if registered is None:
        return [CodeLintFinding(
            "trace-kinds", str(trace_py), 1,
            "no EVENT_KINDS literal dict found — the schema version table "
            "is gone")]
    for node in ast.walk(tree):
        # recorder emissions: _ev(kind="...") / TraceEvent(kind="...")
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "kind" and isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, str) \
                        and kw.value.value not in registered:
                    out.append(CodeLintFinding(
                        "trace-kinds", str(trace_py), node.lineno,
                        f"event kind {kw.value.value!r} emitted but not "
                        f"registered in EVENT_KINDS"))
        # consumers: <expr>.kind == "..." comparisons
        if isinstance(node, ast.Compare) and \
                isinstance(node.left, ast.Attribute) and \
                node.left.attr == "kind":
            for cmp in node.comparators:
                if isinstance(cmp, ast.Constant) \
                        and isinstance(cmp.value, str) \
                        and cmp.value not in registered:
                    out.append(CodeLintFinding(
                        "trace-kinds", str(trace_py), node.lineno,
                        f"event kind {cmp.value!r} matched but not "
                        f"registered in EVENT_KINDS"))
    return out


# ---------------------------------------------------------------------------
# metric-catalog
# ---------------------------------------------------------------------------

#: registry accessor methods whose first positional string argument is a
#: metric name (the scan keys on the METHOD name, so any registry-shaped
#: object — MetricsRegistry or a future facade — is covered)
METRIC_METHODS = {"counter", "gauge", "histogram"}


def _catalog_names(registry_py: Path):
    """Parse the ``METRIC_CATALOG`` literal dict's keys, or None if the
    assignment is missing/not a literal (mirrors EVENT_KINDS handling)."""
    tree = ast.parse(registry_py.read_text(), filename=str(registry_py))
    for node in ast.walk(tree):
        if isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and \
                node.target.id == "METRIC_CATALOG" and node.value is not None:
            value = node.value
        elif isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "METRIC_CATALOG"
                for t in node.targets):
            value = node.value
        else:
            continue
        if isinstance(value, ast.Dict):
            return {k.value for k in value.keys
                    if isinstance(k, ast.Constant)}
    return None


def check_metric_catalog(registry_py: Path,
                         paths: Sequence[Path]) -> List[CodeLintFinding]:
    if not registry_py.exists():
        return [CodeLintFinding("metric-catalog", str(registry_py), 1,
                                "metrics registry module not found")]
    registered = _catalog_names(registry_py)
    if registered is None:
        return [CodeLintFinding(
            "metric-catalog", str(registry_py), 1,
            "no METRIC_CATALOG literal dict found — the metric catalog "
            "is gone")]
    out: List[CodeLintFinding] = []
    for path in paths:
        if not path.exists():
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in METRIC_METHODS
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                continue
            name = node.args[0].value
            if name not in registered:
                out.append(CodeLintFinding(
                    "metric-catalog", str(path), node.lineno,
                    f"metric {name!r} used at a .{node.func.attr}() call "
                    f"but not registered in METRIC_CATALOG "
                    f"(obs/registry.py)"))
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def run_all(root: Path) -> List[CodeLintFinding]:
    src = root / "src" / "repro"
    findings: List[CodeLintFinding] = []
    findings += check_kernel_oracles(src / "kernels", root / "tests")
    findings += check_at_set_loops([src / "core" / "datapath.py",
                                    src / "core" / "executor.py"])
    rng_paths = sorted((src / "core").glob("*.py")) + \
        sorted((src / "storage").glob("*.py"))
    findings += check_unseeded_rng(rng_paths)
    findings += check_trace_kinds(src / "core" / "trace.py")
    findings += check_metric_catalog(src / "obs" / "registry.py",
                                     sorted(src.rglob("*.py")))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.codelint",
        description="Repo-specific AST lint (see module docstring).")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detect from this file)")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args(argv)
    root = Path(args.root) if args.root else \
        Path(__file__).resolve().parents[3]
    findings = run_all(root)
    if args.as_json:
        print(json.dumps([{"rule": f.rule, "path": f.path, "line": f.line,
                           "message": f.message} for f in findings]))
    elif findings:
        for f in findings:
            print(f)
        print(f"{len(findings)} finding(s)")
    else:
        print("codelint: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
