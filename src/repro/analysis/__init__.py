"""Correctness tooling for the CacheFlow engine (DESIGN.md §14).

Three detectors over the same invariant catalog, at three points in the
development loop:

  * :mod:`repro.analysis.sanitizer` — runtime: ``EngineCore(sanitize=True)``
    (or ``CACHEFLOW_SANITIZE=1``) checks every scheduling event against the
    engine's concurrency invariants and raises a structured
    :class:`~repro.analysis.sanitizer.SanitizerViolation` at the first
    drift, instead of letting it surface as a flaky benchmark.
  * :mod:`repro.analysis.trace_lint` — offline: lints any captured
    ``ScheduleTrace`` JSON (``python -m repro.analysis.lint_trace x.json``),
    including artifacts uploaded from failing CI runs.
  * :mod:`repro.analysis.codelint` — static: AST rules encoding repo
    conventions (``python -m repro.analysis.codelint``), run in CI's lint
    job.

Everything here is opt-in and dependency-free: the engine hot path never
imports this package unless sanitizing is enabled.
"""
from repro.analysis.sanitizer import EngineSanitizer, SanitizerViolation
from repro.analysis.trace_lint import LintFinding, lint_trace

__all__ = ["EngineSanitizer", "SanitizerViolation", "LintFinding",
           "lint_trace"]
