"""Runtime invariant sanitizer for the engine core (DESIGN.md §14).

``EngineCore(sanitize=True)`` (or ``CACHEFLOW_SANITIZE=1`` in the
environment) attaches an :class:`EngineSanitizer` to the event loop.  Every
hook is behind an ``if san is not None`` guard in the engine, so the
disabled path costs nothing; enabled, the sanitizer re-derives the loop's
bookkeeping independently and raises a structured
:class:`SanitizerViolation` the moment the engine's state departs from it.

Invariant classes checked (the catalog the last eight PRs established):

  * **two-pointer claims** — no restoration unit in flight on both pointers
    (or twice on one), no unit restored twice across abort/preempt/resume
    cycles (eviction legitimately resets a request's completed units).
  * **channel occupancy** — every resource (stage compute, I/O channel, the
    decode-batch resource) holds at most one op; completions/aborts only
    free a resource that op actually occupied.
  * **virtual time** — event times are monotone; each op completes at
    exactly ``dispatch_t + duration`` (bit-equal floats — the loop's heap
    arithmetic is deterministic); aborted-op rollback is exact: the
    sanitizer mirrors every busy-time add/subtract in engine order and the
    mirror must equal the engine's accounting bit-for-bit at run end.
  * **admission slots** — the active set never exceeds ``max_active``, no
    double admission, finishes/preemptions only remove requests that were
    admitted (conservation under continuous refill and preemption).
  * **block pool** — ``BlockPool.audit()`` refcount conservation, and every
    CoW ``copy()`` leaves the parent block's bytes bit-identical (checked
    by wrapping the pool's copy primitive while sanitizing).
  * **storage byte conservation** — ``ChunkStore.audit()`` /
    ``PlacementCore.audit()`` at every restore completion and at run end,
    so tier-transition accounting drift is caught at the event that caused
    it.
  * **trace schema** — events recorded while sanitizing must carry a
    ``kind`` registered in ``repro.core.trace.EVENT_KINDS``.

Violations carry the offending tail of the engine's ``ops_log`` so the
failing schedule window is in the exception, not just a counter.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.profiler import SanitizerCounters

#: ops_log entries attached to a violation (the schedule window that led
#: up to the failure).
WINDOW = 16


class SanitizerViolation(AssertionError):
    """An engine invariant broke.  ``check`` names the invariant class,
    ``t`` is the engine-clock instant, ``window`` the tail of the
    ``ops_log`` at the moment of failure."""

    def __init__(self, check: str, message: str, *, t: float = 0.0,
                 window: Optional[List[tuple]] = None):
        self.check = check
        self.t = t
        self.window = list(window or [])
        tail = "\n".join(f"    {w}" for w in self.window)
        super().__init__(
            f"[{check}] t={t:.6g}: {message}" +
            (f"\n  ops_log window:\n{tail}" if tail else ""))


class EngineSanitizer:
    """Independent re-derivation of the engine loop's bookkeeping.

    Constructed by ``EngineCore.run`` when sanitizing; ``bind`` hands it
    references to the loop's live accounting structures so run-end
    conservation checks compare against the engine's actual state (the
    mirror is maintained by the hooks in the same order the engine mutates
    its own, so float sums match bit-exactly)."""

    def __init__(self, core, counters: Optional[SanitizerCounters] = None):
        self.core = core
        self.counters = counters or SanitizerCounters()
        self.last_t = -math.inf
        self.ops_log: List[tuple] = []          # rebound in bind()
        # resource -> (op, desc) currently occupying it
        self.resource_busy: Dict[str, tuple] = {}
        # id(op) -> (resource, t_dispatch, duration) for exact-completion
        self.op_info: Dict[int, Tuple[str, float, float]] = {}
        # (rid, stage) -> {unit: "compute"|"load"} in-flight claims
        self.inflight: Dict[Tuple[str, int], Dict[int, str]] = {}
        # (rid, stage) -> completed unit set
        self.completed: Dict[Tuple[str, int], set] = {}
        self.active: set = set()
        self.suspended_set: set = set()
        self.finished: set = set()
        # (rid, stage) -> unit count, captured at admission (the plan
        # geometry the restore-completeness check needs)
        self.plan_units: Dict[Tuple[str, int], int] = {}
        # mirrors of the engine's busy accounting (same adds/subtracts in
        # the same order => bit-exact comparison at run end)
        self.busy_comp_mirror: Dict[int, float] = {}
        self.busy_io_mirror: Dict[int, float] = {}
        self.busy_decode_mirror = 0.0
        self._engine_busy = None     # (busy_comp, busy_io) references
        self._pool = None
        self._orig_copy = None

    # ------------------------------------------------------------------
    def bind(self, *, ops_log, busy_comp, busy_io):
        """Attach the engine loop's live structures (called once at run
        start, before any event)."""
        self.ops_log = ops_log
        self._engine_busy = (busy_comp, busy_io)
        self.busy_comp_mirror = {s: 0.0 for s in busy_comp}
        self.busy_io_mirror = {c: 0.0 for c in busy_io}
        pool = getattr(self.core.kvstore, "pool", None)
        if pool is not None:
            self._install_cow_check(pool)

    def _violate(self, check: str, message: str, t: Optional[float] = None):
        raise SanitizerViolation(
            check, message, t=self.last_t if t is None else t,
            window=self.ops_log[-WINDOW:])

    # -- block pool -----------------------------------------------------
    def _install_cow_check(self, pool):
        """Wrap the pool's CoW primitive: a ``copy(bid)`` must leave the
        parent block's bytes bit-identical (the whole point of CoW — a
        fork that mutates its parent corrupts every sibling)."""
        self._pool = pool
        self._orig_copy = pool.copy
        san = self

        def checked_copy(bid: int) -> int:
            # np.array(copy=True): asarray would alias a numpy-backed pool
            # and the snapshot would mutate along with the parent
            before = {f: np.array(v, copy=True)
                      for f, v in pool.read(bid).items()}
            new = san._orig_copy(bid)
            after = pool.read(bid)
            for f, b in before.items():
                if not np.array_equal(b, np.asarray(after[f])):
                    san._violate(
                        "cow-parent-mutated",
                        f"pool.copy({bid}) changed parent field {f!r}")
            for f, b in before.items():
                if not np.array_equal(b, np.asarray(pool.read(new)[f])):
                    san._violate(
                        "cow-copy-diverged",
                        f"pool.copy({bid}) -> {new}: field {f!r} does not "
                        f"match the parent bytes")
            san.counters.cow_checks += 1
            san._note_refcounts()
            return new

        pool.copy = checked_copy

    def _note_refcounts(self):
        if self._pool is not None and self._pool.refcounts:
            hw = max(self._pool.refcounts)
            if hw > self.counters.pool_refcount_hw:
                self.counters.pool_refcount_hw = hw

    def _audit_stores(self):
        """Byte-conservation audits at tier transitions: the materialized
        store (``ChunkStore.audit`` covers ``PlacementCore.audit`` +
        ``BlockPool.audit``), or the sim store's placement core directly."""
        ks = self.core.kvstore
        if ks is None:
            return
        target = ks if hasattr(ks, "audit") else getattr(ks, "core", None)
        if target is None or not hasattr(target, "audit"):
            return
        try:
            target.audit()
        except AssertionError as e:
            self._violate("store-audit", f"{type(ks).__name__} accounting "
                          f"drift: {e}")
        self.counters.audits += 1
        self._note_refcounts()

    # -- event hooks ----------------------------------------------------
    def on_event(self, now: float, kind: str):
        self.counters.events += 1
        if now < self.last_t:
            self._violate("time-monotonic",
                          f"event {kind!r} at t={now!r} precedes "
                          f"t={self.last_t!r}", t=now)
        self.last_t = now

    def on_dispatch(self, now: float, resource: str, op, dur: float):
        """A compute/load/prefill/prefetch op placed on ``resource``."""
        self.counters.dispatches += 1
        if dur < 0:
            self._violate("negative-duration",
                          f"{op.kind} op {op.request_id}:{op.unit} "
                          f"dispatched with duration {dur!r}")
        held = self.resource_busy.get(resource)
        if held is not None:
            self._violate("channel-occupancy",
                          f"{resource} already occupied by {held[1]} when "
                          f"{op.kind} {op.request_id}:{op.unit} dispatched")
        desc = f"{op.kind}:{op.request_id}:s{op.stage}:u{op.unit}"
        self.resource_busy[resource] = (op, desc)
        self.op_info[id(op)] = (resource, now, dur)
        self._mirror_add(resource, dur)
        if op.kind in ("compute", "load"):
            self.counters.claims += 1
            key = (op.request_id, op.stage)
            units = self.inflight.setdefault(key, {})
            other = units.get(op.unit)
            if other is not None:
                who = "both pointers" if other != op.kind \
                    else f"the {op.kind} pointer twice"
                self._violate("double-claim",
                              f"unit {op.unit} of {key} claimed by {who}")
            if op.unit in self.completed.get(key, ()):
                self._violate("double-restore",
                              f"unit {op.unit} of {key} re-dispatched after "
                              f"it was already restored")
            units[op.unit] = op.kind
        if op.kind != "prefetch" and op.request_id not in self.active:
            self._violate("inactive-dispatch",
                          f"{op.kind} op for {op.request_id} dispatched "
                          f"while not admitted")

    def on_decode_dispatch(self, now: float, dur: float, rids):
        self.counters.dispatches += 1
        held = self.resource_busy.get("decode")
        if held is not None:
            self._violate("channel-occupancy",
                          f"decode step over {list(rids)} dispatched while "
                          f"a step over {held[1]} is in flight")
        self.resource_busy["decode"] = (None, ",".join(rids))
        self.busy_decode_mirror += dur

    def on_decode_done(self, now: float):
        if "decode" not in self.resource_busy:
            self._violate("channel-occupancy",
                          "decode_done with no decode step in flight")
        del self.resource_busy["decode"]
        self.counters.completions += 1

    def on_complete(self, now: float, resource: str, op):
        """Non-aborted completion: the op frees its resource and, for
        restoration kinds, its unit moves from in-flight to restored."""
        self.counters.completions += 1
        self._free_resource(resource, op, "complete")
        info = self.op_info.pop(id(op), None)
        if info is not None:
            _, t0, dur = info
            if now != t0 + dur:
                self._violate("completion-time",
                              f"{op.kind} {op.request_id}:{op.unit} on "
                              f"{resource} completed at t={now!r}, expected "
                              f"dispatch {t0!r} + duration {dur!r}")
        if op.kind in ("compute", "load"):
            key = (op.request_id, op.stage)
            units = self.inflight.get(key, {})
            if units.get(op.unit) != op.kind:
                self._violate("unclaimed-complete",
                              f"{op.kind} completion for unit {op.unit} of "
                              f"{key} that is not in flight on that pointer")
            del units[op.unit]
            done = self.completed.setdefault(key, set())
            if op.unit in done:
                self._violate("double-restore",
                              f"unit {op.unit} of {key} restored twice")
            done.add(op.unit)

    def on_abort(self, now: float, resource: str, op, *,
                 rolled_back: Optional[float] = None,
                 release_claim: bool = False):
        """An aborted op frees its resource.  ``rolled_back`` mirrors the
        engine subtracting the op's duration from the resource's busy time
        at THIS moment (channel failure / prefetch cancel); preempt-mode
        rollback already happened in :meth:`on_preempt`.  ``release_claim``
        returns the unit to the claimable pool (channel failure — the unit
        reschedules; preemption released claims at suspend time)."""
        self.counters.aborts += 1
        self._free_resource(resource, op, "abort")
        self.op_info.pop(id(op), None)
        if rolled_back is not None:
            self._mirror_add(resource, -rolled_back)
        if release_claim and op.kind in ("compute", "load"):
            self.inflight.get((op.request_id, op.stage), {}).pop(op.unit,
                                                                 None)

    def _free_resource(self, resource: str, op, what: str):
        held = self.resource_busy.get(resource)
        if held is None or held[0] is not op:
            desc = held[1] if held else "nothing"
            self._violate("channel-occupancy",
                          f"{what} of {op.kind} {op.request_id}:{op.unit} "
                          f"on {resource}, but {resource} holds {desc}")
        del self.resource_busy[resource]

    def _mirror_add(self, resource: str, dur: float):
        if resource.startswith("io"):
            self.busy_io_mirror[int(resource[2:])] += dur
        elif resource.startswith("comp"):
            self.busy_comp_mirror[int(resource[4:])] += dur

    # -- admission / preemption ----------------------------------------
    def on_admit(self, now: float, req):
        """``req`` is the EngineRequest being admitted (its plan geometry
        feeds the restore-completeness check)."""
        rid = req.request_id
        self.counters.admits += 1
        if rid in self.active:
            self._violate("slot-conservation",
                          f"{rid} admitted while already active")
        self.active.add(rid)
        self.suspended_set.discard(rid)
        for p in req.plans:
            self.plan_units[(rid, p.stage)] = p.plan.n_units
        self.note_active(len(self.active))
        if self.core.max_active and len(self.active) > self.core.max_active:
            self._violate("slot-overflow",
                          f"active batch {len(self.active)} exceeds "
                          f"max_active {self.core.max_active} "
                          f"(admitting {rid})")

    def on_suspend(self, now: float, rid: str, aborted_recs, evict: bool):
        """Preemption: mirror the engine's exact busy-time rollback for
        each in-flight op and release the sanitizer's claim state (evict
        additionally forgets completed units — the plans reset)."""
        self.counters.preemptions += 1
        if rid not in self.active:
            self._violate("slot-conservation",
                          f"preempt of {rid} which is not active")
        self.active.discard(rid)
        self.suspended_set.add(rid)
        for op, resource, dur, _li in aborted_recs:
            self._mirror_add(resource, -dur)
            # the resource stays physically occupied until the op's
            # completion event fires as an abort; only the claim releases
            self.inflight.get((op.request_id, op.stage), {}).pop(op.unit,
                                                                 None)
            self.op_info.pop(id(op), None)
        if evict:
            for key in list(self.completed):
                if key[0] == rid:
                    self.completed[key] = set()
            for key in list(self.inflight):
                if key[0] == rid:
                    self.inflight[key] = {}

    def on_resume(self, now: float, rid: str):
        self.counters.admits += 1
        if rid not in self.suspended_set:
            self._violate("slot-conservation",
                          f"resume of {rid} which is not suspended")
        self.suspended_set.discard(rid)
        if rid in self.active:
            self._violate("slot-conservation",
                          f"resume of {rid} which is already active")
        self.active.add(rid)
        self.note_active(len(self.active))
        if self.core.max_active and len(self.active) > self.core.max_active:
            self._violate("slot-overflow",
                          f"active batch {len(self.active)} exceeds "
                          f"max_active {self.core.max_active} "
                          f"(resuming {rid})")

    def on_finish(self, now: float, rid: str):
        self.counters.finishes += 1
        if rid not in self.active:
            self._violate("slot-conservation",
                          f"finish of {rid} which is not active")
        self.active.discard(rid)
        if rid in self.finished:
            self._violate("slot-conservation", f"{rid} finished twice")
        self.finished.add(rid)

    def on_restore_done(self, now: float, rid: str):
        """All stage plans of ``rid`` restored: every unit must be
        accounted for exactly once, and the stores must balance."""
        for (r, stage), n in self.plan_units.items():
            if r != rid:
                continue
            done = self.completed.get((r, stage), set())
            missing = set(range(n)) - done
            if missing:
                self._violate("restore-incomplete",
                              f"{rid} stage {stage} reported restored with "
                              f"units {sorted(missing)} never completed")
            if self.inflight.get((r, stage)):
                self._violate("restore-incomplete",
                              f"{rid} stage {stage} reported restored with "
                              f"units still in flight: "
                              f"{self.inflight[(r, stage)]}")
        self._audit_stores()

    # -- run end --------------------------------------------------------
    def on_run_end(self, *, active, pending, suspended):
        """Conservation at the end of the run: every resource free, busy
        accounting bit-equal to the mirror (exact abort rollback), slot
        sets consistent with the engine's, stores balanced."""
        if self.resource_busy:
            self._violate("channel-occupancy",
                          f"run ended with resources still occupied: "
                          f"{ {r: d for r, (_o, d) in self.resource_busy.items()} }")
        busy_comp, busy_io = self._engine_busy
        for s, v in busy_comp.items():
            if v != self.busy_comp_mirror.get(s):
                self._violate(
                    "rollback-exact",
                    f"comp{s} busy accounting {v!r} != mirrored "
                    f"{self.busy_comp_mirror.get(s)!r} (inexact abort "
                    f"rollback)")
        for c, v in busy_io.items():
            if v != self.busy_io_mirror.get(c):
                self._violate(
                    "rollback-exact",
                    f"io{c} busy accounting {v!r} != mirrored "
                    f"{self.busy_io_mirror.get(c)!r} (inexact abort "
                    f"rollback)")
        if set(active) != self.active:
            self._violate("slot-conservation",
                          f"engine active set {sorted(active)} != sanitizer "
                          f"view {sorted(self.active)}")
        if set(suspended) != self.suspended_set:
            self._violate("slot-conservation",
                          f"engine suspended set {sorted(suspended)} != "
                          f"sanitizer view {sorted(self.suspended_set)}")
        self._audit_stores()
        if self._pool is not None and self._orig_copy is not None:
            self._pool.copy = self._orig_copy

    # -- trace schema ---------------------------------------------------
    def on_trace_event(self, ev):
        """Schema validity of an event recorded while sanitizing: its kind
        must be registered in the schema version table."""
        from repro.core.trace import EVENT_KINDS
        if ev.kind not in EVENT_KINDS:
            self._violate("trace-schema",
                          f"recorded event kind {ev.kind!r} is not "
                          f"registered in trace.EVENT_KINDS")

    def note_active(self, n: int):
        if n > self.counters.max_active:
            self.counters.max_active = n
