"""CLI for the offline trace linter.

    python -m repro.analysis.lint_trace trace.json [trace2.json ...]
        [--json] [--starvation-bound SECS] [--rules r1,r2,...]

Exit status 0 when every trace is clean, 1 when any finding fires, 2 on
load errors (unreadable file / unsupported schema version).  ``--json``
emits one machine-readable object per trace for CI artifact tooling.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.trace import ScheduleTrace, TraceVersionError
from repro.analysis.trace_lint import ALL_RULES, lint_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint_trace",
        description="Lint captured ScheduleTrace JSON files (schema v1-v5).")
    ap.add_argument("traces", nargs="+", help="trace JSON file(s)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON (one object per trace)")
    ap.add_argument("--starvation-bound", type=float, default=None,
                    metavar="SECS",
                    help="no-progress bound for the starvation rule "
                         "(default: half the trace span)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of: " + ", ".join(ALL_RULES))
    args = ap.parse_args(argv)

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        bad = set(rules) - set(ALL_RULES)
        if bad:
            ap.error(f"unknown rules: {sorted(bad)}")

    status = 0
    for path in args.traces:
        try:
            with open(path) as f:
                d = json.load(f)
            trace = ScheduleTrace.from_dict(d)
        except (OSError, ValueError, KeyError, TraceVersionError) as exc:
            print(f"{path}: failed to load: {exc}", file=sys.stderr)
            status = max(status, 2)
            continue
        findings = lint_trace(trace, raw_version=d.get("version"),
                              starvation_bound=args.starvation_bound,
                              rules=rules)
        if args.as_json:
            print(json.dumps({
                "trace": path,
                "version": d.get("version"),
                "events": len(trace.events),
                "findings": [{"rule": f.rule, "message": f.message,
                              "event_index": f.event_index, "t": f.t}
                             for f in findings],
            }))
        elif findings:
            print(f"{path}: {len(findings)} finding(s)")
            for f in findings:
                print(f"  {f}")
        else:
            print(f"{path}: clean ({len(trace.events)} events, "
                  f"schema v{d.get('version')})")
        if findings:
            status = max(status, 1)
    return status


if __name__ == "__main__":
    sys.exit(main())
