"""Offline linter for captured ``ScheduleTrace`` JSON (DESIGN.md §14).

``python -m repro.analysis.lint_trace trace.json`` statically re-derives
the schedule a trace claims the engine executed — plan pointer state,
per-resource occupancy, admission slots, gate answers — and reports every
place the recorded event stream is internally inconsistent.  It loads any
schema version the repo has ever written (v1–v5, via the existing
upgrader), so it runs unchanged on ``--trace-out`` artifacts from old
benchmarks and on traces uploaded from failing CI runs.

Rules (each independently toggleable via ``rules=``):

  * ``schema``          — every event kind registered in
    ``trace.EVENT_KINDS`` (and no newer than the trace's own version),
    required fields present, op dicts well-formed, request ids known.
  * ``causality``       — monotone timestamps; completions/aborts match an
    outstanding dispatch on that resource and land at exactly
    ``dispatch_t + duration``; pointer state is legal (units claimed in
    two-pointer order, restored at most once, no dispatches for requests
    not admitted / suspended / already restored); ``done``/``finish`` only
    after the state they summarize.
  * ``channel-overlap`` — at most one op in flight per resource (stage
    compute, I/O channel, the decode-batch resource).
  * ``gate-inversion``  — under the ``longest_remaining`` policy, a
    dispatched load whose plan sorts strictly worse than another runnable
    candidate must be justified by that candidate's recorded ``gate``
    answer being False in the same dispatch pass; a skipped candidate that
    passed its gate (or was never asked) is a benefit-gate inversion.
  * ``slot-leak``       — the admitted set never exceeds ``max_active``,
    no double admission / finish of a non-admitted request, and a COMPLETE
    trace (one with a result) retires every admitted request.
  * ``starvation``      — an admitted, still-restoring, unsuspended
    request that makes no progress for longer than ``starvation_bound``
    engine-seconds (default: half the trace span) while the engine keeps
    dispatching other work.
  * ``prefetch-race``   — prefetch/admission race misaccounting: a
    prefetch still in flight when its target is admitted must abort (its
    completion afterwards is the race the engine claims cannot happen),
    prefetches only for requests gated True and not yet admitted, one
    prefetch gate per request per ATTEMPT (a re-gate is legitimate only
    after the previous attempt's transfer aborted, e.g. channel failure).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.trace import (EVENT_KINDS, EVENT_REQUIRED_FIELDS,
                              ScheduleTrace, plan_from_dict)
from repro.core.plans import TwoPointerPlan

ALL_RULES = ("schema", "causality", "channel-overlap", "gate-inversion",
             "slot-leak", "starvation", "prefetch-race")

#: op kinds a dispatch/complete/abort event may carry (decode steps are
#: their own event kind and never appear as dispatches)
OP_KINDS = ("compute", "load", "prefill", "prefetch")


@dataclass
class LintFinding:
    rule: str
    message: str
    event_index: int        # index into trace.events; -1 = trace-level
    t: float = 0.0

    def __str__(self):
        return f"[{self.rule}] event {self.event_index} t={self.t:.6g}: " \
               f"{self.message}"


@dataclass
class _Inflight:
    """One op occupying a resource between its dispatch and completion."""
    key: Tuple[str, str, int, int]   # (kind, rid, stage, unit)
    ev_idx: int
    t: float
    dur: float
    abort_expected: bool = False     # preempted / cancelled mid-flight


def _op_key(op: dict) -> Tuple[str, str, int, int]:
    return (op["kind"], op["request_id"], op["stage"], op["unit"])


class _TraceLinter:
    def __init__(self, trace: ScheduleTrace, *,
                 raw_version: Optional[int] = None,
                 starvation_bound: Optional[float] = None,
                 rules=None):
        self.trace = trace
        self.raw_version = raw_version
        self.starvation_bound = starvation_bound
        self.rules = set(rules) if rules is not None else set(ALL_RULES)
        self.findings: List[LintFinding] = []
        meta = trace.meta
        self.max_active = meta.get("max_active", 0) or 0
        self.evict = meta.get("evict", False)
        self.io_policy = meta.get("io_policy", "longest_remaining")
        self.stage_parallel = meta.get("stage_parallel", True)
        # -- per-request static specs ----------------------------------
        self.known: set = set()
        self.priority: Dict[str, int] = {}
        self.deadline: Dict[str, float] = {}
        self.plans: Dict[Tuple[str, int], object] = {}
        for r in trace.requests:
            rid = r["request_id"]
            self.known.add(rid)
            self.priority[rid] = r.get("priority", 0)
            self.deadline[rid] = r.get("deadline", math.inf)
            for p in r.get("plans", ()):
                self.plans[(rid, p["stage"])] = plan_from_dict(p)
        # -- dynamic state ---------------------------------------------
        self.admitted: set = set()
        self.ever_admitted: set = set()
        self.suspended: set = set()
        self.restored: set = set()
        self.finished: set = set()
        self.admit_order: Dict[str, int] = {}
        self._admit_seq = 0
        self.inflight: Dict[str, _Inflight] = {}     # resource -> op
        self.completed_units: Dict[Tuple[str, int], set] = {}
        self.decode_end = -math.inf
        self.pf_gate_count: Dict[str, int] = {}
        self.pf_gate_ok: Dict[str, bool] = {}
        self.last_progress: Dict[str, float] = {}
        self.starved: set = set()
        self.prev_t = -math.inf

    # ------------------------------------------------------------------
    def flag(self, rule: str, i: int, t: float, msg: str):
        if rule in self.rules:
            self.findings.append(LintFinding(rule, msg, i, t))

    def run(self) -> List[LintFinding]:
        events = self.trace.events
        span = (events[-1].t - events[0].t) if len(events) > 1 else 0.0
        self._starve_after = self.starvation_bound \
            if self.starvation_bound is not None \
            else (0.5 * span if span > 0 else math.inf)
        for i, e in enumerate(events):
            if not self._check_schema(i, e):
                continue
            if e.t < self.prev_t:
                self.flag("causality", i, e.t,
                          f"{e.kind} at t={e.t!r} precedes the previous "
                          f"event's t={self.prev_t!r}")
            self.prev_t = max(self.prev_t, e.t)
            handler = getattr(self, f"_on_{e.kind}", None)
            if handler is not None:
                handler(i, e)
        self._finish()
        return self.findings

    # -- schema ---------------------------------------------------------
    def _check_schema(self, i: int, e) -> bool:
        if e.kind not in EVENT_KINDS:
            self.flag("schema", i, e.t,
                      f"unknown event kind {e.kind!r} (not in EVENT_KINDS)")
            return False
        if self.raw_version is not None \
                and EVENT_KINDS[e.kind] > self.raw_version:
            self.flag("schema", i, e.t,
                      f"event kind {e.kind!r} requires schema v"
                      f"{EVENT_KINDS[e.kind]} but the trace is v"
                      f"{self.raw_version}")
        ok = True
        for f in EVENT_REQUIRED_FIELDS.get(e.kind, ()):
            if getattr(e, f, None) is None:
                self.flag("schema", i, e.t,
                          f"{e.kind} event missing required field {f!r}")
                ok = False
        if ok and e.op is not None:
            missing = {"kind", "request_id", "stage", "unit"} - set(e.op)
            if missing:
                self.flag("schema", i, e.t,
                          f"op dict missing keys {sorted(missing)}")
                ok = False
            elif e.op["kind"] not in OP_KINDS:
                self.flag("schema", i, e.t,
                          f"unknown op kind {e.op['kind']!r}")
                ok = False
            elif e.op["request_id"] not in self.known:
                self.flag("schema", i, e.t,
                          f"op references unknown request "
                          f"{e.op['request_id']!r}")
                ok = False
        if ok and e.request_id is not None \
                and e.request_id not in self.known:
            self.flag("schema", i, e.t,
                      f"{e.kind} references unknown request "
                      f"{e.request_id!r}")
            ok = False
        return ok

    # -- helpers --------------------------------------------------------
    def _progress(self, rid: str, t: float):
        self.last_progress[rid] = t

    def _starvation_scan(self, i: int, t: float):
        if "starvation" not in self.rules or self._starve_after is math.inf:
            return
        for rid in self.admitted:
            if rid in self.restored or rid in self.starved:
                continue
            last = self.last_progress.get(rid)
            if last is not None and t - last > self._starve_after:
                self.starved.add(rid)
                self.flag("starvation", i, t,
                          f"{rid} admitted and restoring but made no "
                          f"progress for {t - last:.6g}s (bound "
                          f"{self._starve_after:.6g}s) while other work "
                          f"dispatched")

    def _release_rid(self, rid: str, i: int, t: float):
        """Preemption: every in-flight op of ``rid`` will abort; claims
        release now, plans reset in eviction mode."""
        for res, fl in self.inflight.items():
            if fl.key[1] == rid and fl.key[0] in ("compute", "load"):
                fl.abort_expected = True
        for (r, stage), p in self.plans.items():
            if r != rid:
                continue
            if self.evict:
                p.plan = TwoPointerPlan(p.plan.n_units,
                                        comp_enabled=p.plan.comp_enabled,
                                        io_enabled=p.plan.io_enabled)
                self.completed_units.pop((r, stage), None)
            else:
                p.plan.release_claims()

    # -- event handlers -------------------------------------------------
    def _on_admit(self, i: int, e):
        rid = e.request_id
        if rid in self.admitted:
            self.flag("slot-leak", i, e.t,
                      f"{rid} admitted while already active")
        for res, fl in self.inflight.items():
            if fl.key[0] == "prefetch" and fl.key[1] == rid \
                    and not fl.abort_expected:
                self.flag("prefetch-race", i, e.t,
                          f"{rid} admitted while its prefetch on {res} is "
                          f"still in flight with no abort recorded")
                fl.abort_expected = True
        self.admitted.add(rid)
        self.ever_admitted.add(rid)
        self.suspended.discard(rid)
        if rid not in self.admit_order:
            self.admit_order[rid] = self._admit_seq
            self._admit_seq += 1
        self._progress(rid, e.t)
        if self.max_active and len(self.admitted) > self.max_active:
            self.flag("slot-leak", i, e.t,
                      f"active set size {len(self.admitted)} exceeds "
                      f"max_active {self.max_active}")

    def _on_resume(self, i: int, e):
        rid = e.request_id
        if rid not in self.suspended:
            self.flag("slot-leak", i, e.t,
                      f"resume of {rid} which is not suspended")
        self.suspended.discard(rid)
        self.admitted.add(rid)
        self._progress(rid, e.t)
        if self.max_active and len(self.admitted) > self.max_active:
            self.flag("slot-leak", i, e.t,
                      f"active set size {len(self.admitted)} exceeds "
                      f"max_active {self.max_active} (resume)")

    def _on_preempt(self, i: int, e):
        rid = e.request_id
        if rid not in self.admitted:
            self.flag("slot-leak", i, e.t,
                      f"preempt of {rid} which is not active")
        self.admitted.discard(rid)
        self.suspended.add(rid)
        self._release_rid(rid, i, e.t)

    def _on_finish(self, i: int, e):
        rid = e.request_id
        if rid not in self.admitted:
            self.flag("slot-leak", i, e.t,
                      f"finish of {rid} which is not active")
        if rid in self.finished:
            self.flag("slot-leak", i, e.t, f"{rid} finished twice")
        self.admitted.discard(rid)
        self.finished.add(rid)

    def _on_done(self, i: int, e):
        rid = e.request_id
        for (r, stage), p in self.plans.items():
            if r == rid and not p.plan.done:
                self.flag("causality", i, e.t,
                          f"done for {rid} but stage {stage} has "
                          f"{p.plan.remaining_units} unrestored units")
        self.restored.add(rid)
        self._progress(rid, e.t)

    def _on_fail(self, i: int, e):
        pass   # channel failures manifest as aborts, matched per-op

    def _on_prefetch_gate(self, i: int, e):
        rid = e.request_id
        n = self.pf_gate_count.get(rid, 0) + 1
        self.pf_gate_count[rid] = n
        self.pf_gate_ok[rid] = bool(e.allowed)
        if n > 1:
            self.flag("prefetch-race", i, e.t,
                      f"{rid} prefetch-gated {n} times without an "
                      f"intervening aborted attempt (each queued request "
                      f"is gated at most once per attempt)")

    def _on_gate(self, i: int, e):
        self._gates_block = getattr(self, "_gates_block", [])
        self._gates_block.append((i, e))

    def _on_decode_step(self, i: int, e):
        if e.t < self.decode_end:
            self.flag("channel-overlap", i, e.t,
                      f"decode step at t={e.t!r} overlaps the previous "
                      f"step ending at t={self.decode_end!r}")
        self.decode_end = e.t + e.duration
        for rid in e.requests:
            if rid in self.finished:
                self.flag("causality", i, e.t,
                          f"decode step includes finished request {rid}")
            elif rid not in self.admitted:
                self.flag("slot-leak", i, e.t,
                          f"decode step includes non-admitted request "
                          f"{rid}")
            self._progress(rid, e.t)
        self._starvation_scan(i, e.t)

    def _on_dispatch(self, i: int, e):
        op = e.op
        key = _op_key(op)
        kind, rid, stage, unit = key
        held = self.inflight.get(e.resource)
        if held is not None:
            self.flag("channel-overlap", i, e.t,
                      f"dispatch of {key} on {e.resource} while {held.key} "
                      f"(dispatched at t={held.t!r}) is still in flight")
        if e.duration is not None and e.duration < 0:
            self.flag("causality", i, e.t,
                      f"dispatch of {key} with negative duration "
                      f"{e.duration!r}")
        if kind == "prefetch":
            if rid in self.admitted or rid in self.finished:
                self.flag("prefetch-race", i, e.t,
                          f"prefetch dispatched for {rid} which is already "
                          f"admitted")
            if not self.pf_gate_ok.get(rid, False):
                self.flag("prefetch-race", i, e.t,
                          f"prefetch dispatched for {rid} without a "
                          f"passing prefetch_gate")
        else:
            if rid not in self.admitted:
                self.flag("causality", i, e.t,
                          f"{kind} op for {rid} dispatched while not "
                          f"admitted")
            if rid in self.suspended:
                self.flag("causality", i, e.t,
                          f"{kind} op for {rid} dispatched while suspended")
            self._progress(rid, e.t)
        if kind in ("compute", "load"):
            if rid in self.restored:
                self.flag("causality", i, e.t,
                          f"{kind} op for {rid} dispatched after its "
                          f"restoration completed")
            p = self.plans.get((rid, stage))
            if p is None:
                self.flag("schema", i, e.t,
                          f"dispatch references unknown plan "
                          f"({rid}, stage {stage})")
            elif kind == "compute":
                if p.plan.comp_inflight is not None:
                    self.flag("causality", i, e.t,
                              f"compute pointer of ({rid}, {stage}) "
                              f"already in flight on unit "
                              f"{p.plan.comp_inflight}")
                elif unit != p.plan.comp_next:
                    self.flag("causality", i, e.t,
                              f"compute claimed unit {unit} of "
                              f"({rid}, {stage}); pointer is at "
                              f"{p.plan.comp_next}")
                if unit in self.completed_units.get((rid, stage), ()):
                    self.flag("causality", i, e.t,
                              f"unit {unit} of ({rid}, {stage}) "
                              f"re-dispatched after restoration")
                p.plan.comp_inflight = unit
            else:
                if p.plan.io_inflight is not None:
                    self.flag("causality", i, e.t,
                              f"I/O pointer of ({rid}, {stage}) already "
                              f"in flight on unit {p.plan.io_inflight}")
                elif unit != p.plan.io_next:
                    self.flag("causality", i, e.t,
                              f"load claimed unit {unit} of "
                              f"({rid}, {stage}); pointer is at "
                              f"{p.plan.io_next}")
                if unit in self.completed_units.get((rid, stage), ()):
                    self.flag("causality", i, e.t,
                              f"unit {unit} of ({rid}, {stage}) "
                              f"re-dispatched after restoration")
                if kind == "load":
                    self._check_inversion(i, e, p)
                p.plan.io_inflight = unit
        self.inflight[e.resource] = _Inflight(key, i, e.t,
                                              e.duration or 0.0)
        self._gates_block = []
        self._starvation_scan(i, e.t)

    def _on_complete(self, i: int, e):
        op = e.op
        key = _op_key(op)
        kind, rid, stage, unit = key
        fl = self.inflight.get(e.resource)
        if fl is None or fl.key != key:
            self.flag("causality", i, e.t,
                      f"complete of {key} on {e.resource}, which holds "
                      f"{fl.key if fl else 'nothing'}")
            return
        del self.inflight[e.resource]
        if fl.abort_expected:
            rule = "prefetch-race" if kind == "prefetch" else "causality"
            self.flag(rule, i, e.t,
                      f"{key} completed on {e.resource} but should have "
                      f"aborted (its request was "
                      f"{'admitted mid-prefetch' if kind == 'prefetch' else 'preempted mid-op'})")
            return
        if e.t != fl.t + fl.dur:
            self.flag("causality", i, e.t,
                      f"{key} completed at t={e.t!r}; dispatched at "
                      f"t={fl.t!r} with duration {fl.dur!r} (expected "
                      f"{fl.t + fl.dur!r})")
        if kind in ("compute", "load"):
            p = self.plans.get((rid, stage))
            done = self.completed_units.setdefault((rid, stage), set())
            if unit in done:
                self.flag("causality", i, e.t,
                          f"unit {unit} of ({rid}, {stage}) restored twice")
            done.add(unit)
            if p is not None:
                if kind == "compute":
                    if p.plan.comp_inflight == unit:
                        p.plan.comp_inflight = None
                        p.plan.comp_next = unit + 1
                        p.plan.comp_done += 1
                else:
                    if p.plan.io_inflight == unit:
                        p.plan.io_inflight = None
                        p.plan.io_next = unit - 1
                        p.plan.io_done += 1
            self._progress(rid, e.t)

    def _on_abort(self, i: int, e):
        op = e.op
        key = _op_key(op)
        kind, rid, stage, unit = key
        fl = self.inflight.get(e.resource)
        if fl is None or fl.key != key:
            self.flag("causality", i, e.t,
                      f"abort of {key} on {e.resource}, which holds "
                      f"{fl.key if fl else 'nothing'}")
            return
        del self.inflight[e.resource]
        if kind == "prefetch":
            # the attempt aborted mid-flight (channel failure, or cancel
            # on losing the race with admission): a still-queued request
            # may be re-gated on a later pass, so the gate budget resets
            self.pf_gate_count[rid] = 0
            self.pf_gate_ok[rid] = False
        p = self.plans.get((rid, stage))
        if p is not None:
            # claim release (no pointer movement) — a preempted request's
            # claims were already released at preempt time, so only clear
            # when this exact unit is still marked in flight
            if kind == "compute" and p.plan.comp_inflight == unit:
                p.plan.comp_inflight = None
            elif kind == "load" and p.plan.io_inflight == unit:
                p.plan.io_inflight = None

    # -- gate-inversion reconstruction ---------------------------------
    def _check_inversion(self, i: int, e, p):
        if "gate-inversion" not in self.rules:
            return
        if self.io_policy != "longest_remaining" or not self.stage_parallel:
            return
        # runnable candidates exactly as BatchScheduler.next_io filters
        cands = []
        for (rid, stage), q in self.plans.items():
            if rid not in self.admitted or rid in self.suspended:
                continue
            pl = q.plan
            if not (pl.io_enabled and not pl.done
                    and pl.io_inflight is None
                    and pl.io_next >= pl.comp_next
                    and not (pl.comp_inflight is not None
                             and pl.io_next <= pl.comp_inflight)):
                continue
            cands.append(q)
        if not cands:
            return
        head = min((r for r in self.admitted
                    if r not in self.restored and r not in self.suspended),
                   key=lambda r: self.admit_order.get(r, 1 << 30),
                   default=None)

        def sort_key(q):
            return (-self.priority.get(q.request_id, 0),
                    self.deadline.get(q.request_id, math.inf),
                    q.request_id != head,
                    -q.remaining_io_tokens(),
                    self.admit_order.get(q.request_id, 1 << 30))

        my_key = sort_key(p)
        block = [(gi, g) for gi, g in getattr(self, "_gates_block", [])
                 if g.t == e.t]
        for q in cands:
            if q is p or sort_key(q) >= my_key:
                continue
            want = (q.request_id, q.stage, q.plan.io_next)
            answer = None
            for _gi, g in block:
                if (g.request_id, g.stage, g.unit) == want:
                    answer = g.allowed
            if answer is None:
                self.flag("gate-inversion", i, e.t,
                          f"load {p.request_id}:{p.stage} dispatched while "
                          f"{want} sorts strictly better and was never "
                          f"gated this pass")
            elif answer:
                self.flag("gate-inversion", i, e.t,
                          f"load {p.request_id}:{p.stage} dispatched while "
                          f"{want} sorts strictly better AND passed its "
                          f"benefit gate — dispatched op has lower "
                          f"marginal benefit than a runnable skipped one")

    # -- end of trace ---------------------------------------------------
    def _finish(self):
        t = self.prev_t if self.prev_t > -math.inf else 0.0
        if self.trace.result is not None:
            leaked = self.ever_admitted - self.finished
            if leaked:
                self.flag("slot-leak", -1, t,
                          f"trace has a result but requests never retired "
                          f"(slot leak): {sorted(leaked)}")
            if self.suspended:
                self.flag("slot-leak", -1, t,
                          f"trace has a result but requests left "
                          f"suspended: {sorted(self.suspended)}")
            for res, fl in sorted(self.inflight.items()):
                if not fl.abort_expected:
                    self.flag("causality", -1, t,
                              f"{fl.key} still in flight on {res} at end "
                              f"of a completed trace")


def lint_trace(trace: ScheduleTrace, *, raw_version: Optional[int] = None,
               starvation_bound: Optional[float] = None,
               rules=None) -> List[LintFinding]:
    """Lint a loaded trace; returns findings (empty = clean).

    ``raw_version`` is the schema version of the file BEFORE the loader
    upgraded it (``ScheduleTrace.from_dict`` normalizes ``version`` to the
    current schema) — pass it to enable the kind-vs-version schema check.
    ``starvation_bound`` overrides the no-progress bound in engine seconds
    (default: half the trace's time span).  ``rules`` restricts checking
    to a subset of :data:`ALL_RULES`."""
    return _TraceLinter(trace, raw_version=raw_version,
                        starvation_bound=starvation_bound,
                        rules=rules).run()


def lint_trace_file(path: str, *, starvation_bound: Optional[float] = None,
                    rules=None) -> List[LintFinding]:
    """Load ``path`` (any supported schema version) and lint it."""
    import json
    with open(path) as f:
        d = json.load(f)
    trace = ScheduleTrace.from_dict(d)
    return lint_trace(trace, raw_version=d.get("version"),
                      starvation_bound=starvation_bound, rules=rules)
