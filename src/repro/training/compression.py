"""Int8 error-feedback gradient compression for cross-pod (DCN) all-reduce.

Multi-pod training reduces gradients over the slow "pod" axis.  We compress
to int8 with per-block scales before the collective and keep the
quantisation residual locally (error feedback), which preserves convergence
(Karimireddy et al., 2019).  On the wire this turns the pod-axis fp32
all-reduce into an int8 all-gather + local sum — 4× fewer DCN bytes, visible
in the dry-run HLO.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8. x: flat fp32 (padded to BLOCK)."""
    blocks = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)


def compress_decompress(x: jax.Array, residual: Optional[jax.Array] = None):
    """Local quantise→dequantise round trip with error feedback.
    Returns (x_hat, new_residual)."""
    flat = x.astype(jnp.float32).reshape(-1)
    if residual is not None:
        flat = flat + residual.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    padded = jnp.pad(flat, (0, pad))
    q, s = _quantize(padded)
    xh = _dequantize(q, s)[:n]
    return xh.reshape(x.shape).astype(x.dtype), (flat - xh).reshape(x.shape)


def error_feedback_psum(x: jax.Array, axis_name: str,
                        residual: Optional[jax.Array] = None):
    """Compressed mean over ``axis_name`` (use inside shard_map):
    int8 all-gather + local dequantised sum. Returns (mean, new_residual)."""
    flat = x.astype(jnp.float32).reshape(-1)
    if residual is not None:
        flat = flat + residual.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    padded = jnp.pad(flat, (0, pad))
    q, s = _quantize(padded)
    # int8 payload over the slow axis; scales are fp32 but 1/BLOCK the size
    q_all = jax.lax.all_gather(q, axis_name)           # (P, nblk, BLOCK) int8
    s_all = jax.lax.all_gather(s, axis_name)
    summed = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
    world = q_all.shape[0]
    mean = summed.reshape(-1)[:n] / world
    local_hat = _dequantize(q, s)[:n]
    new_residual = (flat[:n] - local_hat).reshape(x.shape)
    return mean.reshape(x.shape).astype(x.dtype), new_residual
