"""Checkpointing: atomic, async, restart- and reshard-friendly.

Layout:  <dir>/step_<N>/  with one ``.npy`` per pytree leaf (keyed by its
tree path) + ``manifest.json`` (step, leaf index, completion marker).  Writes
go to ``tmp_step_<N>`` and are published with an atomic ``os.replace`` —
a crash mid-save never corrupts the latest checkpoint.  ``save_async``
snapshots to host memory immediately (device buffers are free to be reused)
and writes on a background thread.

Restore is *mesh-agnostic*: leaves come back as host numpy and are re-placed
by the launcher's sharding rules, so restarting on a different mesh shape
(elastic scaling: 256 → 512 chips) is just a restore (see
``repro.distributed.elastic``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = True):
        leaves, treedef = _flatten(tree)
        host = [np.asarray(l) for l in leaves]           # device -> host now
        if blocking:
            self._write(step, host, treedef)
        else:
            self.wait()
            self._thread = threading.Thread(target=self._write,
                                            args=(step, host, treedef), daemon=True)
            self._thread.start()

    def save_async(self, step: int, tree):
        self.save(step, tree, blocking=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, treedef):
        tmp = os.path.join(self.dir, f"tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        for i, arr in enumerate(host_leaves):
            with open(os.path.join(tmp, _leaf_name(i)), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
        manifest = {"step": step, "n_leaves": len(host_leaves), "complete": True}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)                           # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> list:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                mf = os.path.join(self.dir, d, "manifest.json")
                if os.path.exists(mf):
                    with open(mf) as f:
                        if json.load(f).get("complete"):
                            out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: Optional[int] = None):
        """Returns (step, tree) with leaves as host numpy shaped like
        ``like_tree`` (the launcher re-places them onto the mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        leaves, treedef = _flatten(like_tree)
        host = [np.load(os.path.join(d, _leaf_name(i))) for i in range(len(leaves))]
        for i, (a, b) in enumerate(zip(host, leaves)):
            if tuple(a.shape) != tuple(np.shape(b)):
                raise ValueError(f"leaf {i} shape {a.shape} != expected {np.shape(b)}")
        return step, treedef.unflatten(host)
