"""Deterministic sharded synthetic data pipeline.

Stateless addressing: batch(step, host) is a pure function of (seed, step,
host), so a restarted host resumes at the exact global batch index with zero
coordination — the data-side half of the fault-tolerance story.  Production
would swap in grain/ArrayRecord readers behind the same interface.

The token stream is Zipf-ish random text plus a learnable periodic pattern so
training loss demonstrably decreases within a few hundred steps.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Returns {"tokens": (local_batch, seq+1) int32} — model input is
    tokens[:, :-1], labels tokens[:, 1:]."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    key = jax.random.fold_in(key, cfg.host_id)
    b, s = cfg.local_batch, cfg.seq_len + 1
    base = jax.random.randint(key, (b, s), 0, cfg.vocab_size, dtype=jnp.int32)
    # learnable structure: arithmetic token sequences with random phase
    phase = jax.random.randint(jax.random.fold_in(key, 1), (b, 1), 0, cfg.vocab_size,
                               dtype=jnp.int32)
    pattern = (phase + jnp.arange(s, dtype=jnp.int32)[None]) % cfg.vocab_size
    use_pattern = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.7, (b, 1))
    tokens = jnp.where(use_pattern, pattern, base)
    return {"tokens": tokens}


def embedding_batch_at(cfg: DataConfig, step: int, d_model: int) -> dict:
    """For embeddings-input archs (vlm/audio stubs): precomputed frame/patch
    embeddings + token labels."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 7), step)
    key = jax.random.fold_in(key, cfg.host_id)
    b, s = cfg.local_batch, cfg.seq_len
    emb = jax.random.normal(key, (b, s, d_model), jnp.float32) * 0.1
    labels = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    return {"embeddings": emb, "labels": labels}
