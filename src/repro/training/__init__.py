from repro.training.checkpoint import CheckpointManager  # noqa: F401
from repro.training.data import DataConfig, batch_at, embedding_batch_at  # noqa: F401
from repro.training.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state  # noqa: F401
from repro.training.train_step import lm_loss, make_eval_step, make_train_step  # noqa: F401
