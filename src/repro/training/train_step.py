"""Train-step factory: LM cross-entropy + AdamW, remat-aware, MoE-aux-aware."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, OptState, adamw_update


def lm_loss(model: Model, params, batch: dict):
    """Next-token cross entropy (fp32 log-softmax; vocab stays sharded)."""
    if "tokens" in batch:
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
    else:
        inputs, labels = batch["embeddings"], batch["labels"]
    logits, aux = model.forward(params, inputs)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll + aux, {"nll": nll, "aux": aux}


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    loss_fn: Optional[Callable] = None, grad_accum: int = 1):
    """grad_accum > 1 scans microbatches (leading batch dim split), keeping
    per-microbatch activation liveness bounded — the memory knob that lets
    100B+ archs train at global_batch=256×4k on 16 GB chips."""
    loss_fn = loss_fn or lm_loss

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(model, p, batch), has_aux=True)(params)

    def train_step(params, opt_state: OptState, batch: dict):
        if grad_accum <= 1:
            (loss, extras), grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum,
                                    *a.shape[1:]), batch)

            def body(carry, mb):
                acc, loss_acc, aux_acc = carry
                (loss, extras), g = grads_of(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + loss, aux_acc + extras["aux"]), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum, aux_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = loss_sum / grad_accum
            extras = {"nll": loss - aux_sum / grad_accum, "aux": aux_sum / grad_accum}
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **extras, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model, loss_fn: Optional[Callable] = None):
    loss_fn = loss_fn or lm_loss

    def eval_step(params, batch: dict):
        loss, extras = loss_fn(model, params, batch)
        return {"loss": loss, **extras}

    return eval_step
