"""AdamW + cosine schedule with warmup (no external deps).

Optimizer state is a pytree mirroring params (m, v in fp32) — sharded like
the params by the launcher (ZeRO-style: FSDP sharding of params implies
sharded optimizer state for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
