"""Qwen1.5-110B [hf:Qwen/Qwen1.5-110B; hf].

80L d_model=8192 64H (GQA kv=8) head_dim=128 d_ff=49152 vocab=152064, QKV bias.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49_152,
    vocab_size=152_064,
    activation="swiglu",
    position="rope",
    use_qkv_bias=True,
)
