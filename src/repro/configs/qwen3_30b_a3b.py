"""Qwen3-30B-A3B (MoE, 3B active) — one of the paper's evaluation models.

48L d_model=2048 32H (GQA kv=4) head_dim=128 vocab=151936.
MoE: 128 routed experts, top-8, expert_d_ff=768, no shared experts.
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=6144,
    vocab_size=151_936,
    activation="swiglu",
    position="rope",
    rope_theta=1_000_000.0,
    use_qk_norm=True,
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=768),
)
