"""Phi-4-mini 3.8B [arXiv:2412.08905; hf].

32L d_model=3072 24H (GQA kv=8) head_dim=128 d_ff=8192 vocab=200064.
RoPE + SwiGLU + GQA, no biases.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    activation="swiglu",
    position="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
