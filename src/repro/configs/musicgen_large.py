"""MusicGen-large [arXiv:2306.05284; hf].

Decoder-only transformer over EnCodec tokens:
48L d_model=2048 32H (MHA kv=32) head_dim=64 d_ff=8192 vocab=2048.
LayerNorm + GELU + sinusoidal positions.  EnCodec frontend is a STUB —
``input_specs`` feeds precomputed frame embeddings (B, S, d_model).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    norm="layernorm",
    activation="gelu",
    position="sinusoidal",
    input_mode="embeddings",
)
