"""RWKV-6 "Finch" 7B [arXiv:2404.05892; hf].

32L d_model=4096 (attention-free, head_size=64 => 64 wkv heads)
d_ff=14336 vocab=65536.  Data-dependent decay via LoRA.
"""
from repro.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,        # wkv heads = d_model / head_size
    num_kv_heads=0,      # attention-free: no KV cache
    head_dim=64,
    d_ff=14_336,
    vocab_size=65_536,
    norm="layernorm",
    activation="gelu",   # channel-mix uses squared-relu-ish; gelu stand-in for the MLP shape
    position="none",
    rwkv=RWKVConfig(head_size=64, decay_lora_rank=64, tokenshift_lora_rank=32),
)
