"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1) head_dim=256 d_ff=7680 vocab=256000.
Block pattern 1 local-attention : 2 RG-LRU  —  (rec, rec, attn) repeating.
Local attention window 2048 => sub-quadratic long-context decode.
"""
from repro.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    norm="rmsnorm",
    activation="geglu",
    position="rope",
    attn_window=2048,
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=2560, conv1d_width=4,
                      block_pattern=("recurrent", "recurrent", "attention"),
                      num_rglru_heads=2560 // 128),
)
