"""Mistral-Large-Instruct-2407 123B [hf:mistralai/Mistral-Large-Instruct-2407; unverified].

88L d_model=12288 96H (GQA kv=8) head_dim=128 d_ff=28672 vocab=32768.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12_288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=32_768,
    activation="swiglu",
    position="rope",
    rope_theta=1_000_000.0,
)
