"""DeepSeek-V2 236B [arXiv:2405.04434; hf].

60L d_model=5120 128H MLA(kv_lora=512, q_lora=1536, qk_nope=128, qk_rope=64,
v=128) vocab=102400.  MoE: 2 shared + 160 routed experts, top-6,
expert_d_ff=1536; first layer dense d_ff=12288.
"""
from repro.config import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,   # MLA: all heads share the compressed kv cache
    head_dim=128,
    d_ff=12_288,        # dense layers
    vocab_size=102_400,
    activation="swiglu",
    position="rope",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, expert_d_ff=1536,
                  num_shared_experts=2, shared_d_ff=2 * 1536,
                  first_k_dense=1, dense_d_ff=12_288),
)
