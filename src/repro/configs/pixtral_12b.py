"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409; unverified].

Backbone only (mistral-nemo 12B): 40L d_model=5120 32H (GQA kv=8)
head_dim=128 d_ff=14336 vocab=131072.  The pixtral-ViT frontend is a STUB —
``input_specs`` feeds precomputed patch embeddings (B, S, d_model).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    activation="swiglu",
    position="rope",
    rope_theta=1_000_000.0,
    input_mode="embeddings",
)
