"""Qwen3-8B — one of the paper's evaluation models [hf:Qwen/Qwen3-8B].

36L d_model=4096 32H (GQA kv=8) head_dim=128 d_ff=12288 vocab=151936, QK-norm.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12_288,
    vocab_size=151_936,
    activation="swiglu",
    position="rope",
    rope_theta=1_000_000.0,
    use_qk_norm=True,
)
