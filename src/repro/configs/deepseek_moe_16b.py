"""DeepSeekMoE 16B [arXiv:2401.06066; hf].

28L d_model=2048 16H (MHA kv=16) head_dim=128 vocab=102400.
Fine-grained MoE: 2 shared + 64 routed, top-6, expert_d_ff=1408;
first layer dense d_ff=10944.
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10_944,        # dense first layer
    vocab_size=102_400,
    activation="swiglu",
    position="rope",
    moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408,
                  num_shared_experts=2, shared_d_ff=2 * 1408,
                  first_k_dense=1, dense_d_ff=10_944),
)
