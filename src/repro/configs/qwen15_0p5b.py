"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B; hf].

24L d_model=1024 16H (MHA kv=16) head_dim=64 d_ff=2816 vocab=151936, QKV bias.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151_936,
    activation="swiglu",
    position="rope",
    use_qkv_bias=True,
    tie_embeddings=True,
)
