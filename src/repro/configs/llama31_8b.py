"""Llama-3.1-8B — one of the paper's evaluation models [hf:meta-llama/Llama-3.1-8B].

32L d_model=4096 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=128256.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    activation="swiglu",
    position="rope",
    rope_theta=500_000.0,
)
