"""Architecture registry.

Every assigned architecture (plus the paper's own evaluation models) is a
module exporting ``CONFIG``.  Select with ``get_config("<arch-id>")`` or the
``--arch`` flag of the launchers.
"""
from __future__ import annotations

import importlib

from repro.config import ModelConfig

# arch-id -> module name
_REGISTRY = {
    # ---- assigned pool ----
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen1.5-0.5b": "qwen15_0p5b",
    "qwen1.5-110b": "qwen15_110b",
    "pixtral-12b": "pixtral_12b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "rwkv6-7b": "rwkv6_7b",
    "musicgen-large": "musicgen_large",
    # ---- paper's own evaluation models ----
    "qwen3-8b": "qwen3_8b",
    "llama3.1-8b": "llama31_8b",
    "qwen3-30b-a3b": "qwen3_30b_a3b",
}

ASSIGNED_ARCHS = tuple(list(_REGISTRY)[:10])
PAPER_ARCHS = tuple(list(_REGISTRY)[10:])
ALL_ARCHS = tuple(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(_REGISTRY)
