"""RG-LRU gated linear recurrence  h_t = a_t ⊙ h_{t-1} + b_t  — Pallas TPU.

Grid: ``(B, W/bw, S/bs)`` — batch and channel blocks are parallel; the time
axis iterates sequentially ("arbitrary") with the running hidden state ``h``
in VMEM scratch.  Within a time block the recurrence is a VPU loop over
``bs`` steps of width-``bw`` vectors (the recurrence is inherently
sequential; parallelism comes from the (B × W) grid, which for d=2560 gives
20 independent lanes per batch element at bw=128).

VMEM per program: 2·bs·bw·4B (a, b blocks) + bs·bw·4B (out) + bw·4B (h)
= ~1.5 MB at bs=256, bw=512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(loga_ref, b_ref, h0_ref, o_ref, hlast_ref, h_sc, *, bs: int, ns: int):
    t_blk = pl.program_id(2)

    @pl.when(t_blk == 0)
    def _init():
        h_sc[...] = h0_ref[0]

    a = jnp.exp(loga_ref[0].astype(jnp.float32))      # (bs, bw)
    bb = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + bb[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, h_sc[...])
    h_sc[...] = h

    @pl.when(t_blk == ns - 1)
    def _fin():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "bw", "interpret"))
def rglru_scan(log_a, b, h0, *, bs: int = 256, bw: int = 512, interpret: bool = False):
    """log_a/b: (B, S, W) f32; h0: (B, W) f32 -> (h (B,S,W), h_last (B,W))."""
    bsz, s, w = log_a.shape
    bs = min(bs, s)
    bw = min(bw, w)
    ns = pl.cdiv(s, bs)
    nw = pl.cdiv(w, bw)
    kern = functools.partial(_kernel, bs=bs, ns=ns)
    h, h_last = pl.pallas_call(
        kern,
        grid=(bsz, nw, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda b_, wi, t: (b_, t, wi)),
            pl.BlockSpec((1, bs, bw), lambda b_, wi, t: (b_, t, wi)),
            pl.BlockSpec((1, bw), lambda b_, wi, t: (b_, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bw), lambda b_, wi, t: (b_, t, wi)),
            pl.BlockSpec((1, bw), lambda b_, wi, t: (b_, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, w), jnp.float32),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, b, h0)
    return h, h_last
