"""Oracle: associative-scan linear recurrence (same math as models/rglru.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(log_a, b, h0):
    a = jnp.exp(log_a.astype(jnp.float32))
    b = b.astype(jnp.float32).at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]
