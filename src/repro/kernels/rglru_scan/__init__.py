from repro.kernels.rglru_scan.ops import rglru_scan  # noqa: F401
