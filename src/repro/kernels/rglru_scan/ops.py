"""Public wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import jax

from repro.kernels.rglru_scan import kernel, ref


def rglru_scan(log_a, b, h0, *, backend: str = "auto", bs: int = 256, bw: int = 512):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ref.rglru_scan_ref(log_a, b, h0)
    return kernel.rglru_scan(log_a, b, h0, bs=bs, bw=bw,
                             interpret=(backend == "interpret"))
