"""JAX-version compatibility shims for the Pallas TPU kernels.

``pltpu.TPUCompilerParams`` (JAX <= 0.4.x) was renamed to
``pltpu.CompilerParams`` in newer releases; resolve whichever this
environment ships so the same ``pallas_call`` works on both.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
