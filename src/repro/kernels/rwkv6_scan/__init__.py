from repro.kernels.rwkv6_scan.ops import wkv6  # noqa: F401
