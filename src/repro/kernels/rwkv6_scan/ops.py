"""Public wrapper for the RWKV-6 wkv kernel."""
from __future__ import annotations

import jax

from repro.kernels.rwkv6_scan import kernel, ref


def wkv6(r, k, v, w, u, s0, *, backend: str = "auto", bs: int = 256):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ref.wkv6_ref(r, k, v, w, u, s0)
    return kernel.wkv6(r, k, v, w, u, s0, bs=bs, interpret=(backend == "interpret"))
