"""RWKV-6 wkv recurrence — Pallas TPU kernel.

    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    y_t = S_{t-1}ᵀ r_t + (r_t · (u ⊙ k_t)) v_t

Grid: ``(B, H, S/bs)`` — the (Dh × Dh) state matrix of each (batch, head)
lives in VMEM scratch across the sequential time axis.  Per time step the
update is an outer product + elementwise decay (VPU); r/k/v/w arrive as
(bs, Dh) VMEM blocks.

VMEM per program: 4·bs·Dh·4B + Dh²·4B + bs·Dh·4B ≈ 0.35 MB at bs=256, Dh=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, slast_ref, s_sc,
            *, bs: int, ns: int):
    t_blk = pl.program_id(2)

    @pl.when(t_blk == 0)
    def _init():
        s_sc[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)      # (bs, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)               # (Dh,)

    def step(t, s):
        r_t, k_t, v_t, w_t = r[t], k[t], v[t], w[t]
        # y = Sᵀ r  +  (r · (u ⊙ k)) v
        y = jnp.dot(r_t, s) + (r_t * u * k_t).sum() * v_t
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        s = s * w_t[:, None] + k_t[:, None] * v_t[None, :]
        return s

    s = jax.lax.fori_loop(0, bs, step, s_sc[...])
    s_sc[...] = s

    @pl.when(t_blk == ns - 1)
    def _fin():
        slast_ref[0, 0] = s.astype(slast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def wkv6(r, k, v, w, u, s0, *, bs: int = 256, interpret: bool = False):
    """r/k/v/w: (B,S,H,Dh) f32; u: (H,Dh); s0: (B,H,Dh,Dh).
    Returns (y (B,S,H,Dh), s_last (B,H,Dh,Dh))."""
    b, s, h, dh = r.shape
    bs = min(bs, s)
    ns = pl.cdiv(s, bs)
    kern = functools.partial(_kernel, bs=bs, ns=ns)
    y, s_last = pl.pallas_call(
        kern,
        grid=(b, h, ns),
        in_specs=[
            pl.BlockSpec((1, bs, 1, dh), lambda b_, h_, t: (b_, t, h_, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda b_, h_, t: (b_, t, h_, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda b_, h_, t: (b_, t, h_, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda b_, h_, t: (b_, t, h_, 0)),
            pl.BlockSpec((1, dh), lambda b_, h_, t: (h_, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b_, h_, t: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, 1, dh), lambda b_, h_, t: (b_, t, h_, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b_, h_, t: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_last
