"""Oracle: sequential wkv recurrence (delegates to the model's lax.scan impl)."""
from __future__ import annotations

from repro.models.rwkv6 import wkv_scan_ref


def wkv6_ref(r, k, v, w, u, s0):
    return wkv_scan_ref(r, k, v, w, u, s0)
