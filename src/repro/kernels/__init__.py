"""Pallas TPU kernels for the restoration hot-spots.

Each kernel package ships three modules:
  kernel.py — ``pl.pallas_call`` body + BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (dispatches pallas / interpret / ref)
  ref.py    — pure-jnp oracle used by the property tests

Kernels:
  flash_prefill — causal flash attention over [cached prefix || chunk]; the
                  recompute-pointer step of CacheFlow token-wise restoration.
  flash_decode  — GQA decode attention blocked over cache length with
                  ring-buffer (kpos) masking.
  rglru_scan    — RG-LRU gated linear recurrence (RecurrentGemma).
  rwkv6_scan    — RWKV-6 wkv state recurrence, chunked, state in VMEM.

On this CPU container kernels are validated with ``interpret=True``; on a
real TPU fleet the same ``pallas_call`` lowers to Mosaic.
"""
