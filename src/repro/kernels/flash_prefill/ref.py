"""Pure-jnp oracle for flash_prefill: naive masked softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_prefill_ref(q, k, v, q_offset, kv_len, *, scale: float, window: int = 0):
    """q: (B,Sq,Hq,Dh); k/v: (B,Skv,Hkv,Dh). Token i (abs pos q_offset+i)
    attends to j iff j <= q_offset+i, j < kv_len (and window)."""
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, kf) * scale
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < kv_len)
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return o.reshape(b, sq, hq, dh).astype(q.dtype)
