"""Flash attention (causal, GQA, optional cached prefix) — Pallas TPU kernel.

Semantics: queries are a chunk of ``S_q`` tokens whose absolute positions are
``q_offset + i``; keys/values cover positions ``[0, kv_len)`` (a restored
prefix followed by the chunk itself).  Token ``i`` attends to ``j`` iff
``j <= q_offset + i`` (and ``j > q_offset + i - window`` when windowed).

Grid: ``(B, Hq, nq, nk)`` — the last axis iterates key blocks sequentially
("arbitrary" semantics) with the online-softmax carry (m, l, acc) resident in
VMEM scratch.  Block shapes are MXU-aligned: q/out ``(bq, Dh)``, k/v
``(bk, Dh)`` with ``bq = bk = 128`` by default and Dh ∈ {64, 128, 256}.

VMEM budget per program ≈ (bq + 2·bk)·Dh·2B + bq·bk·4B + carry ≈ 0.3 MB at
128/128/128 — far under the ~16 MB/core VMEM, leaving room for the compiler
to double-buffer the HBM→VMEM streams of k/v blocks.

Scalars (q_offset, kv_len) arrive via scalar prefetch (SMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _kernel(scalars, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc,
            *, bq: int, bk: int, nk: int, scale: float, window: int):
    j = pl.program_id(3)
    q_offset = scalars[0]
    kv_len = scalars[1]

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    i = pl.program_id(2)
    q_pos = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # whole block out of causal range? skip the matmul
    block_alive = (j * bk <= q_offset + i * bq + bq - 1)

    @pl.when(block_alive)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (k_pos <= q_pos) & (k_pos < kv_len)
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        l_prev = l_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * corr[:, None] + pv
        m_sc[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "bq", "bk", "interpret"))
def flash_prefill(q, k, v, q_offset, kv_len, *, scale: float, window: int = 0,
                  bq: int = 128, bk: int = 128, interpret: bool = False):
    """q: (B, Sq, Hq, Dh); k/v: (B, Skv, Hkv, Dh); q_offset/kv_len: i32 scalars.
    Returns (B, Sq, Hq, Dh)."""
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    nq = pl.cdiv(sq, bq)
    nk = pl.cdiv(skv, bk)
    scalars = jnp.array([q_offset, kv_len], jnp.int32)

    grid = (b, hq, nq, nk)
    kern = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, scale=scale, window=window)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bq, 1, dh), lambda b_, h, i, j, s: (b_, i, h, 0)),
                pl.BlockSpec((1, bk, 1, dh), lambda b_, h, i, j, s: (b_, j, h // g, 0)),
                pl.BlockSpec((1, bk, 1, dh), lambda b_, h, i, j, s: (b_, j, h // g, 0)),
            ],
            out_specs=pl.BlockSpec((1, bq, 1, dh), lambda b_, h, i, j, s: (b_, i, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq,), jnp.float32),
                pltpu.VMEM((bq,), jnp.float32),
                pltpu.VMEM((bq, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(scalars, q, k, v)
