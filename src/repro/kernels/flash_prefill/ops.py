"""Public wrapper: dispatches pallas (TPU) / interpret (CPU validation) / ref."""
from __future__ import annotations

import jax

from repro.kernels.flash_prefill import kernel, ref


def flash_prefill_attention(q, k, v, q_offset, kv_len, *, scale: float,
                            window: int = 0, backend: str = "auto",
                            bq: int = 128, bk: int = 128):
    """See kernel.py for semantics. backend: auto|pallas|interpret|ref.

    Non-block-aligned shapes are padded here (padded keys are masked via
    kv_len; padded query rows are sliced off) so the kernel grid stays
    MXU-aligned."""
    import jax.numpy as jnp
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ref.flash_prefill_ref(q, k, v, q_offset, kv_len, scale=scale,
                                     window=window)
    sq, skv = q.shape[1], k.shape[1]
    pq = (-sq) % min(bq, max(sq, 1))
    pk = (-skv) % min(bk, max(skv, 1))
    if pq or pk:
        qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        out = kernel.flash_prefill(qp, kp, vp, q_offset, kv_len, scale=scale,
                                   window=window, bq=bq, bk=bk,
                                   interpret=(backend == "interpret"))
        return out[:, :sq]
    return kernel.flash_prefill(q, k, v, q_offset, kv_len, scale=scale,
                                window=window, bq=bq, bk=bk,
                                interpret=(backend == "interpret"))
