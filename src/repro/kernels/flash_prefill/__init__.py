from repro.kernels.flash_prefill.ops import flash_prefill_attention  # noqa: F401
