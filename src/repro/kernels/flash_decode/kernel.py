"""GQA decode attention (one query token, blocked cache scan) — Pallas TPU.

The cache may be a ring buffer: validity/order come from a ``kpos`` array
(absolute position per slot, -1 = empty) instead of assuming contiguity —
slot ``j`` is visible iff ``0 <= kpos[j] <= q_pos`` (and within the window).

Grid: ``(B, Hkv, nk)`` — key blocks iterate sequentially with the
online-softmax carry in VMEM scratch; all ``G = Hq/Hkv`` query heads of a KV
group are processed together so the cache block is loaded once per group
(the GQA arithmetic-intensity trick: G ≥ 8 keeps the (G × bk) score matmul
on the MXU).

VMEM per program ≈ 2·bk·Dh·2B + G·Dh·4B ≈ 0.13 MB at bk=256, Dh=128, G=8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _kernel(scalars, q_ref, k_ref, v_ref, kpos_ref, o_ref, m_sc, l_sc, acc_sc,
            *, bk: int, nk: int, scale: float, window: int):
    j = pl.program_id(2)
    q_pos = scalars[0]

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                  # (G, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, Dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    kp = kpos_ref[...]                                # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G, bk)
    valid = (kp >= 0) & (kp <= q_pos)
    if window > 0:
        valid &= kp > q_pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_sc[...] = acc_sc[...] * corr[:, None] + pv
    m_sc[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "bk", "interpret"))
def flash_decode(q, k, v, kpos, q_pos, *, scale: float, window: int = 0,
                 bk: int = 256, interpret: bool = False):
    """q: (B, Hq, Dh); k/v: (B, S, Hkv, Dh); kpos: (S,) i32; q_pos: i32 scalar.
    Returns (B, Hq, Dh)."""
    b, hq, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bk = min(bk, s)
    nk = pl.cdiv(s, bk)
    scalars = jnp.array([q_pos], jnp.int32)

    kern = functools.partial(_kernel, bk=bk, nk=nk, scale=scale, window=window)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, hkv, nk),
            in_specs=[
                pl.BlockSpec((1, g, dh), lambda b_, h, j, sc: (b_, h, 0)),
                pl.BlockSpec((1, bk, 1, dh), lambda b_, h, j, sc: (b_, j, h, 0)),
                pl.BlockSpec((1, bk, 1, dh), lambda b_, h, j, sc: (b_, j, h, 0)),
                pl.BlockSpec((bk,), lambda b_, h, j, sc: (j,)),
            ],
            out_specs=pl.BlockSpec((1, g, dh), lambda b_, h, j, sc: (b_, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, dh), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(scalars, q, k, v, kpos)
    return out
