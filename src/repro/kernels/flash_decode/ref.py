"""Pure-jnp oracle for flash_decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_ref(q, k, v, kpos, q_pos, *, scale: float, window: int = 0):
    b, hq, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    sc = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
    valid = (kpos >= 0) & (kpos <= q_pos)
    if window > 0:
        valid &= kpos > q_pos - window
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, dh).astype(q.dtype)
