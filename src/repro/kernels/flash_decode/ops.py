"""Public wrapper for the decode attention kernel."""
from __future__ import annotations

import jax

from repro.kernels.flash_decode import kernel, ref


def flash_decode_attention(q, k, v, kpos, q_pos, *, scale: float, window: int = 0,
                           backend: str = "auto", bk: int = 256):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ref.flash_decode_ref(q, k, v, kpos, q_pos, scale=scale, window=window)
    return kernel.flash_decode(q, k, v, kpos, q_pos, scale=scale, window=window,
                               bk=bk, interpret=(backend == "interpret"))
