from repro.kernels.flash_decode.ops import flash_decode_attention  # noqa: F401
