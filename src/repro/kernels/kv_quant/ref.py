"""Pure-jnp oracle for the per-channel int8 KV quantizer.

Channel = the LAST axis (head_dim for k/v, the compressed latent dim for
MLA ckv); the scale for each channel is the absmax over every other axis
of the chunk, so the worst-case round-trip error per element is bounded by
``0.5 * scale[channel]`` (plus one target-dtype rounding when dequantizing
back to bf16 — see ``ChunkStore.quant_tolerance``).
"""
from __future__ import annotations

import jax.numpy as jnp


def kv_quantize_ref(x):
    """x: float array, any rank >= 1.  Returns (q int8 same shape,
    scales float32 of shape (x.shape[-1],))."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=tuple(range(x.ndim - 1)))
    scales = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scales), -127, 127).astype(jnp.int8)
    return q, scales


def kv_dequantize_ref(q, scales, dtype=jnp.bfloat16):
    """Inverse of :func:`kv_quantize_ref` (lossy: per-channel int8)."""
    return (q.astype(jnp.float32) * scales).astype(dtype)
