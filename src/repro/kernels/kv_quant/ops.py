"""Public wrappers for the per-channel int8 KV quantizer.

Any-rank arrays are viewed as (rows, channels) with channels = the last
axis; rows are padded to the kernel block (zero rows are absmax-neutral)
and, on the Pallas path, channels are padded to the TPU lane width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kv_quant import kernel, ref

_LANE = 128


def _pad2d(x2, br):
    r, c = x2.shape
    pr = (-r) % br
    pc = (-c) % _LANE
    if pr or pc:
        x2 = jnp.pad(x2, ((0, pr), (0, pc)))
    return x2, r, c


def kv_quantize(x, *, backend: str = "auto", br: int = 256):
    """Per-channel int8 quantization of a KV chunk.  Returns
    (q int8, shape of ``x``; scales f32, shape ``(x.shape[-1],)``)."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ref.kv_quantize_ref(x)
    x2 = x.reshape(-1, x.shape[-1])
    xp, r, c = _pad2d(x2, br)
    q, scales = kernel.kv_quantize_2d(xp, br=br,
                                      interpret=(backend == "interpret"))
    return q[:r, :c].reshape(x.shape), scales[0, :c]


def kv_dequantize(q, scales, dtype=jnp.bfloat16, *, backend: str = "auto",
                  br: int = 256):
    """Inverse of :func:`kv_quantize` (lossy)."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ref.kv_dequantize_ref(q, scales, dtype)
    q2 = q.reshape(-1, q.shape[-1])
    qp, r, c = _pad2d(q2, br)
    sp = jnp.pad(scales[None].astype(jnp.float32),
                 ((0, 0), (0, qp.shape[1] - c)), constant_values=1.0)
    out = kernel.kv_dequantize_2d(qp, sp, dtype=dtype, br=br,
                                  interpret=(backend == "interpret"))
    return out[:r, :c].reshape(q.shape)
