"""Per-channel int8 KV quantize / dequantize — Pallas TPU.

Operates on a 2D view ``(R, C)`` where C is the channel (last) axis of the
KV chunk; the ops wrapper reshapes/pads.  Quantization needs the global
per-channel absmax before any element can be scaled, so it is two
``pallas_call``s over the same row-block grid:

  1. ``_absmax_kernel`` — sequential row-block reduction into a (1, C)
     accumulator (init on the first block, max-accumulate after);
  2. ``_quant_kernel``  — elementwise scale+round+clip to int8 with the
     (1, C) scales broadcast to every block.

Dequantize is a single elementwise pass.  VMEM per program ≈ br·C·4B —
0.13 MB at br=256, C=128.  Rows are padded to the block size by the
wrapper (zero rows are absmax-neutral); on real TPUs C should be a
multiple of 128 (lane width) — the wrapper pads channels too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _absmax_kernel(x_ref, amax_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        amax_ref[...] = jnp.zeros_like(amax_ref)

    blk = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)), axis=0,
                  keepdims=True)
    amax_ref[...] = jnp.maximum(amax_ref[...], blk)


def _quant_kernel(x_ref, scales_ref, q_ref):
    s = scales_ref[...]                              # (1, C)
    y = jnp.round(x_ref[...].astype(jnp.float32) / s)
    q_ref[...] = jnp.clip(y, -127, 127).astype(jnp.int8)


def _dequant_kernel(q_ref, scales_ref, o_ref):
    s = scales_ref[...]                              # (1, C)
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def kv_quantize_2d(x, *, br: int = 256, interpret: bool = False):
    """x: (R, C) float, R a multiple of br.  Returns (q int8 (R, C),
    scales f32 (1, C))."""
    r, c = x.shape
    br = min(br, r)
    nr = pl.cdiv(r, br)
    amax = pl.pallas_call(
        _absmax_kernel,
        grid=(nr,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, c), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x)
    scales = jnp.maximum(amax, 1e-12) / 127.0
    q = pl.pallas_call(
        _quant_kernel,
        grid=(nr,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0)),
                  pl.BlockSpec((1, c), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int8),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, scales)
    return q, scales


@functools.partial(jax.jit, static_argnames=("dtype", "br", "interpret"))
def kv_dequantize_2d(q, scales, *, dtype=jnp.bfloat16, br: int = 256,
                     interpret: bool = False):
    """q: (R, C) int8; scales: (1, C) f32.  Returns (R, C) ``dtype``."""
    r, c = q.shape
    br = min(br, r)
    nr = pl.cdiv(r, br)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nr,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0)),
                  pl.BlockSpec((1, c), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, scales)
