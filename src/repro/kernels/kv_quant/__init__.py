from repro.kernels.kv_quant.ops import kv_dequantize, kv_quantize  # noqa: F401
