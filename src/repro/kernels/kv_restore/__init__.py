"""Fused restoration dequant-scatter kernel (one launch per load op)."""
from repro.kernels.kv_restore.ops import kv_restore_scatter

__all__ = ["kv_restore_scatter"]
