"""Fused restoration dequant-scatter — Pallas TPU.

One launch restores one load op: a grid over ``(slot, chunk)`` where every
program dequantizes (or plain-copies) one store chunk's rows for one cache
slot out of the packed staging buffer and writes them in place into the
live cache via ``input_output_aliases``.  All attention fields of the op
ride the same launch as parallel (input, output) pairs, so the legacy
O(chunks x layers x fields) ``.at[].set()`` storm collapses to a single
dispatch.

Layout per field f (channels = flattened trailing axes, token axis 1):

  cache_f   (A, S, C_f)  aliased in/out — only blocks touched by the grid
                         are written; boundary blocks past S are clipped
                         by Pallas' partial-block masking, which is what
                         lets the zero-padded tail of the last prefix
                         chunk ride along safely (tails only occur when
                         the op ends exactly at S).
  staged_f  (A, T, C_f)  packed staging buffer, T = n_chunks * cs
  scales_f  (n_chunks, 1, C_f) f32 — per-chunk per-channel scales
                         (quantized path only)

Grid ``(n_slots, n_chunks)``; block shapes ``(1, cs, C_f)`` with the out
index map offset by ``(slot_lo, t0 // cs)`` so a sub-span of slots and a
mid-prefix token range address the right cache region.  The dequant body
is bit-identical to ``kv_quant._dequant_kernel`` (f32 multiply, one cast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams


def _restore_kernel(nf, quant, *refs):
    out_refs = refs[-nf:]
    staged_refs = refs[nf:2 * nf]
    scales_refs = refs[2 * nf:3 * nf] if quant else ()
    for f in range(nf):
        x = staged_refs[f][...]
        if quant:
            s = scales_refs[f][...]                  # (1, 1, C_f)
            y = (x.astype(jnp.float32) * s).astype(out_refs[f].dtype)
        else:
            y = x.astype(out_refs[f].dtype)
        out_refs[f][...] = y


@functools.partial(jax.jit,
                   static_argnames=("t0", "slot_lo", "cs", "interpret"))
def kv_restore_call(caches, staged, scales, *, t0: int, slot_lo: int,
                    cs: int, interpret: bool = False):
    """caches/staged: tuples of (A, S, C_f) / (A, T, C_f); scales: tuple of
    (n_chunks, 1, C_f) f32 or None.  T % cs == 0 and t0 % cs == 0 required
    (the ops wrapper guarantees both).  Returns the updated caches."""
    nf = len(caches)
    quant = scales is not None
    t = staged[0].shape[1]
    n_chunks = t // cs
    n_slots = staged[0].shape[0] - slot_lo
    b0 = t0 // cs

    def _cache_map(a, i):
        return (slot_lo + a, b0 + i, 0)

    def _staged_map(a, i):
        return (slot_lo + a, i, 0)

    def _scales_map(a, i):
        return (i, 0, 0)

    cache_specs = [pl.BlockSpec((1, cs, c.shape[-1]), _cache_map)
                   for c in caches]
    staged_specs = [pl.BlockSpec((1, cs, x.shape[-1]), _staged_map)
                    for x in staged]
    in_specs = cache_specs + staged_specs
    operands = list(caches) + list(staged)
    if quant:
        in_specs += [pl.BlockSpec((1, 1, s.shape[-1]), _scales_map)
                     for s in scales]
        operands += list(scales)
    return pl.pallas_call(
        functools.partial(_restore_kernel, nf, quant),
        grid=(n_slots, n_chunks),
        in_specs=in_specs,
        out_specs=cache_specs,
        out_shape=[jax.ShapeDtypeStruct(c.shape, c.dtype) for c in caches],
        input_output_aliases={f: f for f in range(nf)},
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
