"""Public wrapper for the fused restoration dequant-scatter.

``kv_restore_scatter`` takes per-field 3D cache views ``(A, S, C_f)``
(token axis 1, channels flattened last), the op's packed staging buffers
``(A, T, C_f)`` and optional per-chunk scales ``(n_chunks, C_f)``, and
returns the caches with tokens ``[t0, t0 + T)`` of slots
``[slot_lo, slot_lo + n_slots)`` replaced by the dequantized payload.
Rows past S (padding in the last chunk of a prefix) are dropped.

Backend convention follows ``kv_quant``: ``auto`` uses the Pallas kernel
only on real TPUs (interpret mode is far slower than XLA on CPU) and
otherwise the jitted oracle, which XLA still fuses into one
dequant+dynamic-update-slice per field — already a single dispatch per
field instead of one per chunk x field.  The Pallas path additionally
requires lane-aligned channels and chunk-aligned t0; anything else falls
back to the oracle (the destination is aliased in place, so channels
cannot be pad-and-cropped the way kv_quant's out-of-place ops can).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.kv_restore import kernel, ref

_LANE = 128
_SUBLANE = 8


def _pallas_ok(caches, *, t0, chunk_size, t):
    if t % chunk_size or t0 % chunk_size or chunk_size % _SUBLANE:
        return False
    return all(c.shape[-1] % _LANE == 0 for c in caches)


@functools.partial(jax.jit, static_argnames=("t0", "slot_lo", "n_slots",
                                             "chunk_size"))
def _ref_all(caches, staged, scales, *, t0, slot_lo, n_slots, chunk_size):
    sc = scales if scales is not None else (None,) * len(caches)
    return [ref.kv_restore_ref(c, x, s, t0=t0, slot_lo=slot_lo,
                               n_slots=n_slots, chunk_size=chunk_size)
            for c, x, s in zip(caches, staged, sc)]


def kv_restore_scatter(caches, staged, scales=None, *, t0: int,
                       slot_lo: int = 0, n_slots: int | None = None,
                       chunk_size: int, backend: str = "auto"):
    """Fused dequant-scatter of one load op into the live cache views."""
    caches = tuple(caches)
    staged = tuple(staged)
    t = staged[0].shape[1]
    a = caches[0].shape[0]
    if n_slots is None:
        n_slots = a - slot_lo
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas" and not (
            _pallas_ok(caches, t0=t0, chunk_size=chunk_size, t=t)
            # the grid covers slots [slot_lo, A); a sub-span that stops
            # short of A (inner stage of a multi-stage split) takes the
            # oracle instead of risking writes past slot_hi
            and slot_lo + n_slots == a):
        backend = "ref"
    if backend == "ref":
        return _ref_all(caches, staged,
                        None if scales is None else tuple(scales),
                        t0=t0, slot_lo=slot_lo, n_slots=n_slots,
                        chunk_size=chunk_size)
    assert t % chunk_size == 0 and t0 % chunk_size == 0, (t, t0, chunk_size)
    sc = None
    if scales is not None:
        sc = tuple(s.astype(jax.numpy.float32)[:, None, :] for s in scales)
    return kernel.kv_restore_call(caches, staged, sc, t0=t0,
                                  slot_lo=slot_lo, cs=chunk_size,
                                  interpret=(backend == "interpret"))
