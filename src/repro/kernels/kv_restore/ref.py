"""Pure-jnp oracle for the fused restoration dequant-scatter.

One restoration load op owns a packed multi-chunk staging buffer: the
(possibly int8-quantized) KV of ``n_chunks`` consecutive store chunks,
concatenated along the token axis and padded to a whole number of chunks.
The scatter writes slots ``[slot_lo, slot_lo + n_slots)`` and tokens
``[t0, t0 + T)`` of a per-field cache view ``(A, S, C)`` — rows past ``S``
(the zero-padded tail of the last chunk of a prefix) are dropped, matching
the Pallas kernel's boundary-block clipping.

Dequantization is per store chunk: ``scales`` carries one f32 row per
chunk (the per-channel scales of :mod:`repro.kernels.kv_quant`, tiled to
the flattened channel axis), broadcast over the chunk's ``chunk_size``
token rows.  The math — f32 multiply, then a single cast to the cache
dtype — is exactly ``kv_dequantize_ref``, so a fused restore lands the
same bits as the legacy promote-then-copy path.  With ``scales=None`` the
scatter is a pure copy: ``quant="none"`` round-trips bit-exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kv_restore_ref(cache, staged, scales=None, *, t0: int, slot_lo: int = 0,
                   n_slots: int | None = None, chunk_size: int = 0):
    """cache: (A, S, C); staged: (A, T, C) int8 or cache-dtype; scales:
    (n_chunks, C) f32 or None (raw copy).  T must be a multiple of
    ``chunk_size`` when ``scales`` is given.  Returns the updated cache."""
    a, s, c = cache.shape
    t = staged.shape[1]
    ns = a - slot_lo if n_slots is None else n_slots
    if scales is not None:
        srep = jnp.repeat(scales.astype(jnp.float32), chunk_size, axis=0)
        dec = (staged.astype(jnp.float32) * srep[None]).astype(cache.dtype)
    else:
        dec = staged.astype(cache.dtype)
    t_eff = min(t, s - t0)
    upd = jax.lax.dynamic_slice(
        dec, (slot_lo, 0, 0), (ns, t_eff, c))
    return jax.lax.dynamic_update_slice(cache, upd, (slot_lo, t0, 0))
