"""Model builder: config -> functional model with train / prefill / decode /
restoration-chunk entry points.

Layer organisation:
  * uniform archs (most of the pool): parameters of identical layers are
    stacked on a leading axis and executed with ``jax.lax.scan`` — compact
    HLO, fast compiles, and the idiom FSDP weight-gathering optimises well.
  * a non-uniform *prefix* (DeepSeek's first dense layer) is unrolled before
    the scan segment.
  * heterogeneous stacks (RecurrentGemma's (rec, rec, attn) pattern) are
    fully unrolled python loops.

Cache layout (see ``kvcache.py``): stacked per layer-kind slot, so scan over
layers zips (stacked params, stacked cache) and emits updated cache — and the
CacheFlow executor can slice per-(layer, token-range) without reshapes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer as tfm
from repro.models.kvcache import cache_seq_len, init_cache, layer_slots
from repro.models.layers import (apply_norm, embed_init, init_norm,
                                 sinusoidal_positions)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


class Model:
    def __init__(self, cfg: ModelConfig, *, param_dtype=jnp.float32,
                 compute_dtype=jnp.float32, backend: str = "auto",
                 remat_policy: str = "none", moe_groups: int = 0,
                 moe_dropless: bool = True):
        if moe_dropless and cfg.moe is not None and cfg.moe.capacity_factor > 0:
            import dataclasses
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.0))
        self.cfg = cfg
        self.param_dtype = param_dtype
        self.compute_dtype = compute_dtype
        self.backend = backend
        self.remat_policy = remat_policy
        self.moe_groups = moe_groups
        self.slots = layer_slots(cfg)
        # layout: unrolled prefix + scan segment (or fully unrolled)
        if cfg.rglru is not None:
            self.prefix_len = cfg.num_layers          # fully unrolled
        elif cfg.moe is not None and cfg.moe.first_k_dense:
            self.prefix_len = cfg.moe.first_k_dense
        else:
            self.prefix_len = 0
        self.scan_len = cfg.num_layers - self.prefix_len

    # ------------------------------------------------------------------
    # Params
    # ------------------------------------------------------------------
    def init(self, rng) -> dict:
        cfg = self.cfg
        keys = jax.random.split(rng, cfg.num_layers + 3)
        p: dict = {}
        if cfg.input_mode == "tokens":
            p["embed"] = embed_init(keys[-1], (cfg.vocab_size, cfg.d_model), self.param_dtype)
        if not cfg.tie_embeddings:
            p["unembed"] = embed_init(keys[-2], (cfg.d_model, cfg.vocab_size), self.param_dtype)
        p["final_norm"] = init_norm(cfg.norm, cfg.d_model, self.param_dtype)
        layers = [tfm.init_layer(keys[i], cfg, i, self.param_dtype)
                  for i in range(cfg.num_layers)]
        p["prefix_layers"] = layers[: self.prefix_len]
        if self.scan_len:
            p["scan_layers"] = _stack(layers[self.prefix_len:])
        return p

    def param_specs(self) -> dict:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def num_params(self, params) -> int:
        return sum(int(a.size) for a in jax.tree.leaves(params))

    def layer_params(self, params, i: int):
        if i < self.prefix_len:
            return params["prefix_layers"][i]
        return _index(params["scan_layers"], i - self.prefix_len)

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------
    def embed(self, params, inputs, positions):
        cfg = self.cfg
        if cfg.input_mode == "tokens":
            x = params["embed"].astype(self.compute_dtype)[inputs]
        else:
            x = inputs.astype(self.compute_dtype)
        if cfg.position == "sinusoidal":
            x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
        return x

    def unembed(self, params, x):
        cfg = self.cfg
        x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        table = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
        return x @ table.astype(x.dtype)

    # ------------------------------------------------------------------
    # Full forward (train) / prefill
    # ------------------------------------------------------------------
    def _layer_full(self, p, cfg_kind, x, positions, states):
        """One layer, full sequence. states: per-kind state views or None.

        Layer boundaries carry explicit sharding constraints: batch over
        ("pod","data") and — sequence-parallel, Megatron-SP style — sequence
        over "model".  This pins GSPMD to gathering *weights* per layer (the
        FSDP/2D-TP intent) instead of replicating activations, and shrinks
        remat-saved activations by the TP degree.  No-ops off-mesh.
        """
        from repro.distributed.constraints import constrain
        cfg = self.cfg
        if cfg_kind == "attention":
            x, entry, aux = tfm.attention_layer_full(
                cfg, p, x, positions, backend=self.backend, moe_groups=self.moe_groups)
            entry = {f: constrain(a, ("pod", "data"), "model")
                     for f, a in entry.items()}
            return constrain(x, ("pod", "data"), "model", None), entry, aux
        if cfg_kind == "recurrent":
            conv, h0 = states
            x, conv, h = tfm.recurrent_layer_full(cfg, p, x, conv, h0, backend=self.backend)
            return (constrain(x, ("pod", "data"), "model", None), (conv, h),
                    jnp.zeros((), jnp.float32))
        if cfg_kind == "rwkv":
            stm, scm, wkv = states
            x, stm, scm, wkv = tfm.rwkv_layer_full(cfg, p, x, stm, scm, wkv,
                                                   backend=self.backend)
            return (constrain(x, ("pod", "data"), "model", None),
                    (stm, scm, wkv), jnp.zeros((), jnp.float32))
        raise ValueError(cfg_kind)

    def fresh_state(self, kind: str, b: int, dtype):
        cfg = self.cfg
        if kind == "recurrent":
            w = cfg.rglru.lru_width or cfg.d_model
            return (jnp.zeros((b, cfg.rglru.conv1d_width - 1, w), dtype),
                    jnp.zeros((b, w), jnp.float32))
        if kind == "rwkv":
            h = cfg.d_model // cfg.rwkv.head_size
            return (jnp.zeros((b, cfg.d_model), dtype),
                    jnp.zeros((b, cfg.d_model), dtype),
                    jnp.zeros((b, h, cfg.rwkv.head_size, cfg.rwkv.head_size), jnp.float32))
        return None

    def run_layer_full(self, params, i: int, x, positions, states=None):
        """One layer, full-sequence mode. Returns (x', cache_entry_or_state).
        Used by the layer-wise restoration executor (bottom-up forward)."""
        kind = self.cfg.layer_kinds()[i]
        if states is None:
            states = self.fresh_state(kind, x.shape[0], x.dtype)
        return self._layer_full(self.layer_params(params, i), kind, x, positions,
                                states)[:2]

    def layer_chunk(self, params, i: int, x, positions, cache):
        """One layer over a chunk, attending to + updating the cache."""
        kind, slot = self.slots[i]
        return self._layer_cached(self.layer_params(params, i), kind, slot, x,
                                  positions, dict(cache))

    def forward(self, params, inputs, positions=None, collect_cache: bool = False):
        """Whole-sequence forward.

        Returns (logits, aux) or (logits, aux, raw_entries) when
        ``collect_cache`` — raw_entries are full-sequence per-layer cache
        entries (list in layer order) for cache construction.
        """
        cfg = self.cfg
        b, s = inputs.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = self.embed(params, inputs, positions)
        aux_total = jnp.zeros((), jnp.float32)
        entries = []
        kinds = cfg.layer_kinds()

        # fresh zero states for recurrent/rwkv kinds
        def fresh_state(kind):
            return self.fresh_state(kind, b, x.dtype)

        for i in range(self.prefix_len):
            x, entry, aux = self._layer_full(params["prefix_layers"][i], kinds[i], x,
                                             positions, fresh_state(kinds[i]))
            aux_total += aux
            entries.append(entry)

        if self.scan_len:
            kind = kinds[self.prefix_len]          # scan segment is uniform

            def body(carry, layer_p):
                xc, auxc = carry
                xc, entry, aux = self._layer_full(layer_p, kind, xc, positions,
                                                  fresh_state(kind))
                out = entry if (collect_cache or kind != "attention") else 0.0
                return (xc, auxc + aux), out

            if self.remat_policy != "none":
                body = _remat(body, self.remat_policy)
            (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), params["scan_layers"])
            if collect_cache or kind != "attention":
                entries.append(("scan", ys))

        logits = self.unembed(params, x)
        if collect_cache:
            return logits, aux_total, entries
        return logits, aux_total

    # ------------------------------------------------------------------
    # Prefill: full forward + cache construction
    # ------------------------------------------------------------------
    def prefill(self, params, inputs, positions=None):
        """Returns (last-token logits (B,V), cache filled with the sequence)."""
        cfg = self.cfg
        b, s = inputs.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        logits, _, entries = self.forward(params, inputs, positions, collect_cache=True)
        cache = self._entries_to_cache(entries, positions, s)
        return logits[:, -1], cache

    def _entries_to_cache(self, entries, positions, s, max_len: Optional[int] = None):
        """Assemble the stacked cache dict from per-layer full-sequence entries."""
        cfg = self.cfg
        s_cache = cache_seq_len(cfg, max_len or s)
        cache: dict = {}
        kinds = cfg.layer_kinds()

        # unpack scan ys back into per-layer entries
        flat: list = []
        for e in entries:
            if isinstance(e, tuple) and len(e) == 2 and e[0] == "scan":
                ys = e[1]
                for j in range(self.scan_len):
                    flat.append(_index(ys, j))
            else:
                flat.append(e)

        attn_entries = [e for e, k in zip(flat, kinds) if k == "attention"]
        rec_entries = [e for e, k in zip(flat, kinds) if k == "recurrent"]
        rwkv_entries = [e for e, k in zip(flat, kinds) if k == "rwkv"]

        pos_row = positions[0]
        if attn_entries:
            if cfg.attn_window and s > s_cache:
                sel = jnp.arange(s - s_cache, s)
                slot = pos_row[sel] % s_cache
            else:
                sel = jnp.arange(s)
                slot = pos_row % s_cache

            def to_cache(seq_arr):
                tail = seq_arr[:, sel]
                buf_shape = (seq_arr.shape[0], s_cache) + seq_arr.shape[2:]
                buf = jnp.zeros(buf_shape, seq_arr.dtype)
                return buf.at[:, slot].set(tail)

            if cfg.mla is not None:
                cache["ckv"] = jnp.stack([to_cache(e["ckv"]) for e in attn_entries])
            else:
                cache["k"] = jnp.stack([to_cache(e["k"]) for e in attn_entries])
                cache["v"] = jnp.stack([to_cache(e["v"]) for e in attn_entries])
            kpos_row = jnp.full((s_cache,), -1, jnp.int32).at[slot].set(pos_row[sel])
            cache["kpos"] = jnp.broadcast_to(kpos_row[None], (len(attn_entries), s_cache))
        if rec_entries:
            cache["conv"] = jnp.stack([e[0] for e in rec_entries])
            cache["lru"] = jnp.stack([e[1] for e in rec_entries])
        if rwkv_entries:
            cache["shift_tm"] = jnp.stack([e[0] for e in rwkv_entries])
            cache["shift_cm"] = jnp.stack([e[1] for e in rwkv_entries])
            cache["wkv"] = jnp.stack([e[2] for e in rwkv_entries])
        return cache

    # ------------------------------------------------------------------
    # Cached-chunk forward (decode C=1; restoration chunks C>1)
    # ------------------------------------------------------------------
    def _layer_cached(self, p, kind, slot, x, positions, cache):
        cfg = self.cfg
        if kind == "attention":
            if cfg.mla is not None:
                view = {"ckv": cache["ckv"][slot], "kpos": cache["kpos"][slot]}
            else:
                view = {"k": cache["k"][slot], "v": cache["v"][slot],
                        "kpos": cache["kpos"][slot]}
            x, new = tfm.attention_layer_cached(cfg, p, x, positions, view,
                                                backend=self.backend,
                                                moe_groups=self.moe_groups)
            for f, a in new.items():
                cache[f] = cache[f].at[slot].set(a)
            return x, cache
        if kind == "recurrent":
            x, conv, h = tfm.recurrent_layer_full(cfg, p, x, cache["conv"][slot],
                                                  cache["lru"][slot], backend=self.backend)
            cache["conv"] = cache["conv"].at[slot].set(conv)
            cache["lru"] = cache["lru"].at[slot].set(h)
            return x, cache
        if kind == "rwkv":
            x, stm, scm, wkv = tfm.rwkv_layer_full(cfg, p, x, cache["shift_tm"][slot],
                                                   cache["shift_cm"][slot],
                                                   cache["wkv"][slot], backend=self.backend)
            cache["shift_tm"] = cache["shift_tm"].at[slot].set(stm)
            cache["shift_cm"] = cache["shift_cm"].at[slot].set(scm)
            cache["wkv"] = cache["wkv"].at[slot].set(wkv)
            return x, cache
        raise ValueError(kind)

    def stack_chunk(self, params, x, positions, cache, lo: int = 0, hi: Optional[int] = None):
        """Run layers [lo, hi) over a chunk (B,C,D), attending to + updating
        the cache. The workhorse of token-wise and stage-local restoration."""
        cfg = self.cfg
        hi = cfg.num_layers if hi is None else hi
        # scan fast-path: whole stack of a uniform arch
        if cfg.is_uniform and lo == 0 and hi == cfg.num_layers and self.scan_len:
            kind = cfg.layer_kinds()[0]

            def body(xc, xs):
                layer_p, layer_cache = xs
                if kind == "attention":
                    xc, new = tfm.attention_layer_cached(
                        cfg, layer_p, xc, positions, layer_cache,
                        backend=self.backend, moe_groups=self.moe_groups)
                    return xc, new
                elif kind == "rwkv":
                    xc, stm, scm, wkv = tfm.rwkv_layer_full(
                        cfg, layer_p, xc, layer_cache["shift_tm"],
                        layer_cache["shift_cm"], layer_cache["wkv"],
                        backend=self.backend)
                    return xc, {"shift_tm": stm, "shift_cm": scm, "wkv": wkv}
                raise ValueError(kind)

            x, new_cache = jax.lax.scan(body, x, (params["scan_layers"], cache))
            return x, new_cache

        cache = dict(cache)
        for i in range(lo, hi):
            kind, slot = self.slots[i]
            x, cache = self._layer_cached(self.layer_params(params, i), kind, slot,
                                          x, positions, cache)
        return x, cache

    def decode_step_append(self, params, tokens, cache, tail, tail_len, pos):
        """Append-buffer decode (beyond-paper optimisation, EXPERIMENTS.md
        §Perf): the big prefix cache is READ-ONLY; the new token's KV is
        written into a small ``tail`` buffer instead, and attention runs over
        [cache || tail].  This removes the masked full-cache writes GSPMD
        emits for dynamic updates into a sequence-sharded cache — the engine
        merges tails back every W steps, off the decode critical path.

        tail: cache-shaped dict with S = W slots; tail_len: scalar i32.
        Returns (logits, tail')."""
        cfg = self.cfg
        assert cfg.is_uniform and self.scan_len, \
            "append-buffer decode requires a uniform scan stack"
        b = tokens.shape[0]
        positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b, 1))
        inp = tokens[:, None] if cfg.input_mode == "tokens" else tokens[:, None, :]
        x = self.embed(params, inp, positions)
        x, new_tail = self._decode_append_scan(params, x, positions, cache,
                                               tail, tail_len)
        logits = self.unembed(params, x)
        return logits[:, 0], new_tail

    def _decode_append_scan(self, params, x, positions, cache, tail, tail_len):
        cfg = self.cfg

        def body(xc, xs):
            layer_p, layer_cache, layer_tail = xs
            from repro.models import attention as attn_mod
            from repro.models import mla as mla_mod
            from repro.models.layers import apply_norm
            from repro.models import transformer as tfm_mod
            h = apply_norm(cfg.norm, layer_p["norm1"], xc, cfg.norm_eps)
            if cfg.mla is not None:
                q_nope, q_rope = mla_mod._project_q(cfg, layer_p["attn"], h,
                                                    positions)
                ckv_new = mla_mod.compress_kv(cfg, layer_p["attn"], h, positions)
                lt = dict(layer_tail)
                lt["ckv"] = jax.lax.dynamic_update_slice_in_dim(
                    layer_tail["ckv"], ckv_new.astype(layer_tail["ckv"].dtype),
                    tail_len, axis=1)
                lt["kpos"] = jax.lax.dynamic_update_slice_in_dim(
                    layer_tail["kpos"], positions[0], tail_len, axis=0)
                full_ckv = jnp.concatenate([layer_cache["ckv"], lt["ckv"]], axis=1)
                kp = jnp.concatenate([layer_cache["kpos"], lt["kpos"]])
                a = mla_mod.mla_attend_absorbed(
                    cfg, layer_p["attn"], q_nope, q_rope, positions,
                    full_ckv.astype(h.dtype), kp)
            else:
                q, k_new, v_new = attn_mod._project_qkv(cfg, layer_p["attn"], h,
                                                        positions)
                lt = dict(layer_tail)
                lt["k"] = jax.lax.dynamic_update_slice_in_dim(
                    layer_tail["k"], k_new.astype(layer_tail["k"].dtype),
                    tail_len, axis=1)
                lt["v"] = jax.lax.dynamic_update_slice_in_dim(
                    layer_tail["v"], v_new.astype(layer_tail["v"].dtype),
                    tail_len, axis=1)
                lt["kpos"] = jax.lax.dynamic_update_slice_in_dim(
                    layer_tail["kpos"], positions[0], tail_len, axis=0)
                scale = 1.0 / (cfg.qk_head_dim ** 0.5)
                from repro.distributed.collectives import lse_decode_attention
                from repro.distributed.constraints import _ambient_mesh
                mesh = _ambient_mesh()
                seq_sharded = (mesh is not None
                               and mesh.shape.get("model", 1) > 1
                               and cfg.num_kv_heads % mesh.shape["model"] != 0)
                if seq_sharded:
                    # sequence-sharded cache: LSE-combine partial attention;
                    # comm = (B,Hq,Dh) psum, NOT a full-cache all-gather, and
                    # the tail merges inside the shard (no cache reshard)
                    a = lse_decode_attention(
                        q, layer_cache["k"].astype(q.dtype),
                        layer_cache["v"].astype(q.dtype), layer_cache["kpos"],
                        positions, scale=scale, window=cfg.attn_window,
                        tail=(lt["k"], lt["v"], lt["kpos"]))
                else:
                    k_full = jnp.concatenate([layer_cache["k"], lt["k"]], axis=1)
                    v_full = jnp.concatenate([layer_cache["v"], lt["v"]], axis=1)
                    kp = jnp.concatenate([layer_cache["kpos"], lt["kpos"]])
                    kpb = jnp.broadcast_to(kp[None], (q.shape[0], kp.shape[0]))
                    a = attn_mod._gqa_flash(q, k_full.astype(q.dtype),
                                            v_full.astype(q.dtype),
                                            positions, kpb, scale, cfg.attn_window)
                a = a.reshape(*h.shape[:2], cfg.num_heads * cfg.head_dim)
                a = a @ layer_p["attn"]["wo"].astype(h.dtype)
            xc = xc + a
            h = apply_norm(cfg.norm, layer_p["norm2"], xc, cfg.norm_eps)
            f, _ = tfm_mod._ffn(cfg, layer_p, h, self.moe_groups)
            return xc + f, lt

        sub_cache = {f: cache[f] for f in ("k", "v", "ckv", "kpos") if f in cache}
        x, new_tail = jax.lax.scan(body, x, (params["scan_layers"], sub_cache, tail))
        return x, new_tail

    def init_tail(self, batch: int, window: int, dtype=None):
        """Small append buffer for decode_step_append."""
        cfg = self.cfg
        t = init_cache(cfg, batch, window, dtype or self.compute_dtype)
        return t

    def decode_step(self, params, tokens, cache, pos):
        """tokens: (B,) int32 (or (B,D) embeddings); pos: scalar int32.
        Returns (logits (B,V), cache')."""
        cfg = self.cfg
        b = tokens.shape[0]
        positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b, 1))
        inp = tokens[:, None] if cfg.input_mode == "tokens" else tokens[:, None, :]
        x = self.embed(params, inp, positions)
        x, cache = self.stack_chunk(params, x, positions, cache)
        logits = self.unembed(params, x)
        return logits[:, 0], cache

    def prefill_chunk(self, params, inputs, cache, start_pos):
        """Chunk prefill against an existing cache (token-wise restoration
        recompute step): inputs (B,C); start_pos scalar. Returns
        (last logits, cache')."""
        cfg = self.cfg
        b, c = inputs.shape[:2]
        positions = start_pos + jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[None],
                                                 (b, c))
        x = self.embed(params, inputs, positions)
        x, cache = self.stack_chunk(params, x, positions, cache)
        logits = self.unembed(params, x[:, -1:])
        return logits[:, 0], cache

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None):
        return init_cache(self.cfg, batch, max_len,
                          dtype or self.compute_dtype)


def _remat(fn, policy: str):
    policies = {
        "full": None,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "nothing": jax.checkpoint_policies.nothing_saveable,
    }
    pol = policies.get(policy)
    if policy == "full" or pol is None:
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=pol)


@functools.lru_cache(maxsize=None)
def _cached_model(cfg: ModelConfig, param_dtype_name: str, compute_dtype_name: str,
                  backend: str, remat_policy: str, moe_groups: int,
                  moe_dropless: bool) -> Model:
    import numpy as np
    return Model(cfg, param_dtype=np.dtype(param_dtype_name),
                 compute_dtype=np.dtype(compute_dtype_name), backend=backend,
                 remat_policy=remat_policy, moe_groups=moe_groups,
                 moe_dropless=moe_dropless)


def build_model(cfg: ModelConfig, *, param_dtype=jnp.float32, compute_dtype=jnp.float32,
                backend: str = "auto", remat_policy: str = "none",
                moe_groups: int = 0, moe_dropless: bool = True) -> Model:
    import numpy as np
    return _cached_model(cfg, np.dtype(param_dtype).name, np.dtype(compute_dtype).name,
                         backend, remat_policy, moe_groups, moe_dropless)
