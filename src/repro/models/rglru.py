"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU.

    y = W_out( gelu(W_y x) ⊙ RG-LRU(conv1d(W_x x)) )

RG-LRU (per channel, block-diagonal gates per head):
    r_t = σ(W_a z_t + b_a)                recurrence gate
    i_t = σ(W_i z_t + b_i)                input gate
    log a_t = -c · softplus(Λ) · r_t      (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ z_t)

The recurrence is h_t = a_t h_{t-1} + b_t — a first-order linear recurrence
executed with ``jax.lax.associative_scan`` (parallel in time; the Pallas
``rglru_scan`` kernel implements the same contraction blocked for VMEM).

The carried state (conv tail + h) is O(1) in sequence length: this is what
makes recurrentgemma a ``long_500k``-capable arch, and it is the unit the
CacheFlow executor snapshots at chunk boundaries for hybrid-arch restoration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init

_C = 8.0
_MAX_SQRT_GRADIENT = 1000.0


def init_rglru_block(key, cfg: ModelConfig, dtype) -> dict:
    g = cfg.rglru
    d = cfg.d_model
    w = g.lru_width or d
    nh = g.num_rglru_heads or max(1, w // 128)
    hd = w // nh
    ks = jax.random.split(key, 8)
    # Λ init so that a^c ∈ [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^{-1}(-log u / c)
    return {
        "w_y": dense_init(ks[1], (d, w), dtype),
        "w_x": dense_init(ks[2], (d, w), dtype),
        "conv_w": dense_init(ks[3], (g.conv1d_width, w), dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": dense_init(ks[4], (nh, hd, hd), dtype, in_axis=1),
        "gate_a_b": jnp.zeros((w,), dtype),
        "gate_i": dense_init(ks[5], (nh, hd, hd), dtype, in_axis=1),
        "gate_i_b": jnp.zeros((w,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], (w, d), dtype),
    }


def _gates(params: dict, z: jax.Array, nh: int):
    """z: (B, S, W) -> log_a (B,S,W) fp32, gated input b (B,S,W) fp32."""
    b, s, w = z.shape
    zh = z.reshape(b, s, nh, w // nh)
    ra = jnp.einsum("bsnh,nhk->bsnk", zh, params["gate_a"].astype(z.dtype)).reshape(b, s, w)
    ri = jnp.einsum("bsnh,nhk->bsnk", zh, params["gate_i"].astype(z.dtype)).reshape(b, s, w)
    r = jax.nn.sigmoid(ra.astype(jnp.float32) + params["gate_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(ri.astype(jnp.float32) + params["gate_i_b"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a2 = jnp.exp(2 * log_a)
    gated = i * z.astype(jnp.float32)
    b_t = jnp.sqrt(jnp.clip(1.0 - a2, 1.0 / _MAX_SQRT_GRADIENT**2, 1.0)) * gated
    return log_a, b_t


def lru_scan(log_a: jax.Array, b_t: jax.Array, h0: jax.Array):
    """h_t = exp(log_a_t) h_{t-1} + b_t along axis 1 via associative scan.
    log_a/b_t: (B, S, W) fp32; h0: (B, W) fp32. Returns (h (B,S,W), h_last)."""
    a = jnp.exp(log_a)
    # fold h0 into the first step
    b_t = b_t.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, b_t), axis=1)
    return h, h[:, -1]


def causal_conv1d(z: jax.Array, conv_w: jax.Array, conv_b: jax.Array, tail: jax.Array):
    """Depthwise causal conv. z: (B,S,W); conv_w: (K,W); tail: (B,K-1,W) —
    the last K-1 inputs from the previous chunk. Returns (out, new_tail)."""
    k = conv_w.shape[0]
    zc = jnp.concatenate([tail.astype(z.dtype), z], axis=1)       # (B, S+K-1, W)
    out = sum(zc[:, i : i + z.shape[1]] * conv_w[i].astype(z.dtype) for i in range(k))
    out = out + conv_b.astype(z.dtype)
    new_tail = zc[:, -(k - 1):] if k > 1 else tail
    return out, new_tail


def rglru_full(cfg: ModelConfig, params: dict, x: jax.Array,
               conv_tail: jax.Array, h0: jax.Array, backend: str = "auto"):
    """Full/chunk forward. x: (B,S,D). Returns (out (B,S,D), conv_tail', h')."""
    g = cfg.rglru
    w = g.lru_width or cfg.d_model
    nh = g.num_rglru_heads or max(1, w // 128)
    y = jax.nn.gelu(x @ params["w_y"].astype(x.dtype))
    z = x @ params["w_x"].astype(x.dtype)
    z, conv_tail = causal_conv1d(z, params["conv_w"], params["conv_b"], conv_tail)
    log_a, b_t = _gates(params, z, nh)
    if backend == "pallas":
        from repro.kernels.rglru_scan import ops as _ops
        h, h_last = _ops.rglru_scan(log_a, b_t, h0)
    else:
        h, h_last = lru_scan(log_a, b_t, h0)
    out = (y * h.astype(x.dtype)) @ params["w_out"].astype(x.dtype)
    return out, conv_tail, h_last


def rglru_step(cfg: ModelConfig, params: dict, x: jax.Array,
               conv_tail: jax.Array, h0: jax.Array):
    """Single decode step. x: (B,1,D). Same returns as rglru_full."""
    return rglru_full(cfg, params, x, conv_tail, h0)
