"""KV-cache / recurrent-state pytrees.

The cache is a flat dict of stacked arrays (leading axis = layer slot) so the
CacheFlow restoration executor can slice per-layer, per-token-range views —
exactly the granularity of the paper's token/layer two-pointer plans.

Fields (present depending on architecture):
  k, v      : (n_attn, B, S_cache, H_kv, Dh)       attention KV
  ckv       : (n_attn, B, S_cache, kv_lora + rope) MLA compressed KV
  kpos      : (n_attn, S_cache) int32              position of each cache slot
                                                   (-1 = empty; ring buffer for
                                                   windowed attention)
  conv      : (n_rec, B, conv_w - 1, W)            RG-LRU conv1d tail
  lru       : (n_rec, B, W) float32                RG-LRU hidden state
  wkv       : (n_rwkv, B, H, Dh, Dh) float32       RWKV6 state matrix
  shift_tm  : (n_rwkv, B, D)                       RWKV token-shift (time mix)
  shift_cm  : (n_rwkv, B, D)                       RWKV token-shift (channel mix)

Positions/lengths are carried *outside* the cache (launcher passes them), so
the cache stays a plain array pytree.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


def layer_slots(cfg: ModelConfig) -> dict:
    """Map layer index -> (kind, slot index within that kind's stacked array)."""
    slots, counters = {}, {"attention": 0, "recurrent": 0, "rwkv": 0}
    for i, kind in enumerate(cfg.layer_kinds()):
        slots[i] = (kind, counters[kind])
        counters[kind] += 1
    return slots


def cache_seq_len(cfg: ModelConfig, max_len: int) -> int:
    """Windowed archs only ever hold ``attn_window`` keys (ring buffer)."""
    if cfg.attn_window:
        return min(max_len, cfg.attn_window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    kinds = cfg.layer_kinds()
    n_attn = kinds.count("attention")
    n_rec = kinds.count("recurrent")
    n_rwkv = kinds.count("rwkv")
    s = cache_seq_len(cfg, max_len)
    cache: dict = {}
    if n_attn:
        if cfg.mla is not None:
            d_c = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            cache["ckv"] = jnp.zeros((n_attn, batch, s, d_c), dtype)
        else:
            cache["k"] = jnp.zeros((n_attn, batch, s, cfg.num_kv_heads, cfg.head_dim), dtype)
            cache["v"] = jnp.zeros((n_attn, batch, s, cfg.num_kv_heads, cfg.head_dim), dtype)
        cache["kpos"] = jnp.full((n_attn, s), -1, jnp.int32)
    if n_rec:
        w = cfg.rglru.lru_width or cfg.d_model
        cache["conv"] = jnp.zeros((n_rec, batch, cfg.rglru.conv1d_width - 1, w), dtype)
        cache["lru"] = jnp.zeros((n_rec, batch, w), jnp.float32)
    if n_rwkv:
        h = cfg.d_model // cfg.rwkv.head_size
        cache["wkv"] = jnp.zeros((n_rwkv, batch, h, cfg.rwkv.head_size, cfg.rwkv.head_size),
                                 jnp.float32)
        cache["shift_tm"] = jnp.zeros((n_rwkv, batch, cfg.d_model), dtype)
        cache["shift_cm"] = jnp.zeros((n_rwkv, batch, cfg.d_model), dtype)
    return cache


def cache_bytes(cache: dict) -> int:
    return sum(int(a.size) * a.dtype.itemsize for a in cache.values())


def park_cache(cache: dict) -> dict:
    """Move a (partially restored) cache off-device to host numpy buffers —
    how a preempted restoration parks WITHOUT being finalized, so suspended
    requests stop pinning device HBM while they wait for an admission slot.
    Inverse: :func:`unpark_cache`."""
    return {f: np.asarray(a) for f, a in cache.items()}


def unpark_cache(cache: dict) -> dict:
    """Return a parked cache to device arrays (dtypes preserved); resumed
    restoration ops continue writing into it exactly where they left off."""
    return {f: jnp.asarray(a) for f, a in cache.items()}


def grow_cache(cfg: ModelConfig, cache: dict, new_len: int) -> dict:
    """Pad the attention KV buffers (k/v/ckv/kpos) so the cache holds
    ``new_len`` tokens — how suffix prefill and decode append onto a
    restored prefix cache.  Recurrent/RWKV state fields are length-free and
    pass through; windowed archs stay capped at the ring-buffer size."""
    target = cache_seq_len(cfg, new_len)
    out = {}
    for f, a in cache.items():
        if f in ("k", "v", "ckv") and a.shape[2] < target:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, target - a.shape[2])
            out[f] = jnp.pad(a, pad)
        elif f == "kpos" and a.shape[1] < target:
            out[f] = jnp.pad(a, ((0, 0), (0, target - a.shape[1])),
                             constant_values=-1)
        else:
            out[f] = a
    return out
