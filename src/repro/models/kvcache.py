"""KV-cache / recurrent-state pytrees.

The cache is a flat dict of stacked arrays (leading axis = layer slot) so the
CacheFlow restoration executor can slice per-layer, per-token-range views —
exactly the granularity of the paper's token/layer two-pointer plans.

Fields (present depending on architecture):
  k, v      : (n_attn, B, S_cache, H_kv, Dh)       attention KV
  ckv       : (n_attn, B, S_cache, kv_lora + rope) MLA compressed KV
  kpos      : (n_attn, S_cache) int32              position of each cache slot
                                                   (-1 = empty; ring buffer for
                                                   windowed attention)
  conv      : (n_rec, B, conv_w - 1, W)            RG-LRU conv1d tail
  lru       : (n_rec, B, W) float32                RG-LRU hidden state
  wkv       : (n_rwkv, B, H, Dh, Dh) float32       RWKV6 state matrix
  shift_tm  : (n_rwkv, B, D)                       RWKV token-shift (time mix)
  shift_cm  : (n_rwkv, B, D)                       RWKV token-shift (channel mix)

Positions/lengths are carried *outside* the cache (launcher passes them), so
the cache stays a plain array pytree.

Paged layout (DESIGN.md §12): on top of the contiguous per-request caches,
:class:`BlockPool` + :class:`PagedKVCache` provide a vLLM-style paged view
of the attention KV — fixed-size token blocks in a shared device-side pool,
per-request block tables mapping logical block index -> physical block id,
physical blocks refcounted so requests sharing a prefix alias the SAME
device memory, and copy-on-write on append so ``clone()`` forks a live
session's cache in O(1) copied bytes.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig

# token axis of each attention field inside a per-block payload
# (k/v/ckv: (n_attn, B, bs, ...); kpos: (n_attn, bs)); +1 in the pool slab
_TOKEN_AXIS = {"k": 2, "v": 2, "ckv": 2, "kpos": 1}


def layer_slots(cfg: ModelConfig) -> dict:
    """Map layer index -> (kind, slot index within that kind's stacked array)."""
    slots, counters = {}, {"attention": 0, "recurrent": 0, "rwkv": 0}
    for i, kind in enumerate(cfg.layer_kinds()):
        slots[i] = (kind, counters[kind])
        counters[kind] += 1
    return slots


def cache_seq_len(cfg: ModelConfig, max_len: int) -> int:
    """Windowed archs only ever hold ``attn_window`` keys (ring buffer)."""
    if cfg.attn_window:
        return min(max_len, cfg.attn_window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    kinds = cfg.layer_kinds()
    n_attn = kinds.count("attention")
    n_rec = kinds.count("recurrent")
    n_rwkv = kinds.count("rwkv")
    s = cache_seq_len(cfg, max_len)
    cache: dict = {}
    if n_attn:
        if cfg.mla is not None:
            d_c = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            cache["ckv"] = jnp.zeros((n_attn, batch, s, d_c), dtype)
        else:
            cache["k"] = jnp.zeros((n_attn, batch, s, cfg.num_kv_heads, cfg.head_dim), dtype)
            cache["v"] = jnp.zeros((n_attn, batch, s, cfg.num_kv_heads, cfg.head_dim), dtype)
        cache["kpos"] = jnp.full((n_attn, s), -1, jnp.int32)
    if n_rec:
        w = cfg.rglru.lru_width or cfg.d_model
        cache["conv"] = jnp.zeros((n_rec, batch, cfg.rglru.conv1d_width - 1, w), dtype)
        cache["lru"] = jnp.zeros((n_rec, batch, w), jnp.float32)
    if n_rwkv:
        h = cfg.d_model // cfg.rwkv.head_size
        cache["wkv"] = jnp.zeros((n_rwkv, batch, h, cfg.rwkv.head_size, cfg.rwkv.head_size),
                                 jnp.float32)
        cache["shift_tm"] = jnp.zeros((n_rwkv, batch, cfg.d_model), dtype)
        cache["shift_cm"] = jnp.zeros((n_rwkv, batch, cfg.d_model), dtype)
    return cache


def cache_bytes(cache: dict) -> int:
    return sum(int(a.size) * a.dtype.itemsize for a in cache.values())


def park_cache(cache: dict) -> dict:
    """Move a (partially restored) cache off-device to host numpy buffers —
    how a preempted restoration parks WITHOUT being finalized, so suspended
    requests stop pinning device HBM while they wait for an admission slot.
    Inverse: :func:`unpark_cache`."""
    return {f: np.asarray(a) for f, a in cache.items()}


def unpark_cache(cache: dict) -> dict:
    """Return a parked cache to device arrays (dtypes preserved); resumed
    restoration ops continue writing into it exactly where they left off."""
    return {f: jnp.asarray(a) for f, a in cache.items()}


class BlockPool:
    """Shared device-side pool of fixed-size KV token blocks.

    One block holds ``block_size`` tokens' attention KV across ALL
    attention layer slots (k/v or MLA ckv, plus kpos) — the same span a
    content-addressed store chunk covers, so a store chunk promoted to HBM
    *is* a pool block and every request table that maps it aliases one
    physical copy.  Storage is one slab per field with a leading block
    axis; the slab doubles when the free list runs dry.  Blocks are
    refcounted: ``incref``/``decref`` with a free list at zero, and
    ``copy`` is the CoW primitive (counted in ``cow_copies`` /
    ``bytes_copied`` — the bytes a fork pays, which O(1)-fork tests pin).

    Field shapes are fixed by the first block written; payloads shorter
    than ``block_size`` tokens (a prefix's tail block) are zero-padded
    (kpos pads with -1 = empty slot, matching :func:`init_cache`).
    """

    def __init__(self, block_size: int, *, capacity: int = 8):
        if block_size <= 0:
            raise ValueError("block_size must be positive")
        self.block_size = block_size
        self._slabs: Optional[Dict[str, jnp.ndarray]] = None
        self._specs: Optional[Dict[str, tuple]] = None   # f -> (shape, dtype)
        self.capacity = 0
        self._init_capacity = max(1, capacity)
        self.refcounts: List[int] = []
        self._free: List[int] = []
        self.block_nbytes = 0
        self.allocs = 0
        self.frees = 0
        self.cow_copies = 0
        self.bytes_copied = 0

    # -- layout ---------------------------------------------------------
    def _pad(self, f: str, arr) -> jnp.ndarray:
        """Pad (or trim) the payload's token axis to exactly one block."""
        arr = jnp.asarray(arr)
        ax = _TOKEN_AXIS[f]
        short = self.block_size - arr.shape[ax]
        if short > 0:
            pad = [(0, 0)] * arr.ndim
            pad[ax] = (0, short)
            fill = -1 if f == "kpos" else 0
            arr = jnp.pad(arr, pad, constant_values=fill)
        elif short < 0:
            take = [slice(None)] * arr.ndim
            take[ax] = slice(0, self.block_size)
            arr = arr[tuple(take)]
        return arr

    def _ensure_slabs(self, payload: dict):
        if self._slabs is not None:
            return
        self._specs = {}
        for f, arr in payload.items():
            a = self._pad(f, arr)
            self._specs[f] = (tuple(a.shape), a.dtype)
            self.block_nbytes += int(np.prod(a.shape)) * a.dtype.itemsize
        self._grow(self._init_capacity)

    def _grow(self, extra: int):
        new = {}
        for f, (shape, dtype) in self._specs.items():
            blank = jnp.full((extra,) + shape, -1, dtype) if f == "kpos" \
                else jnp.zeros((extra,) + shape, dtype)
            new[f] = blank if self._slabs is None \
                else jnp.concatenate([self._slabs[f], blank])
        self._slabs = new
        self._free.extend(range(self.capacity, self.capacity + extra))
        self.refcounts.extend([0] * extra)
        self.capacity += extra

    def _take_slot(self) -> int:
        if not self._free:
            self._grow(max(1, self.capacity))
        bid = self._free.pop()
        assert self.refcounts[bid] == 0, bid
        self.refcounts[bid] = 1
        self.allocs += 1
        return bid

    def ensure_layout(self, payload: dict):
        """Fix the pool's field shapes/dtypes from a sample payload (padded
        to one block) without allocating; no-op once the layout is set."""
        self._ensure_slabs(payload)

    # -- lifecycle ------------------------------------------------------
    def alloc(self, payload: dict) -> int:
        """Write ``payload`` (a per-block field dict) into a fresh block;
        returns its id with refcount 1."""
        self._ensure_slabs(payload)
        bid = self._take_slot()
        for f, arr in payload.items():
            self._slabs[f] = self._slabs[f].at[bid].set(self._pad(f, arr))
        return bid

    def alloc_blank(self) -> int:
        """A fresh zeroed block (kpos = -1); the CoW append target when a
        table extends past its mapped blocks."""
        if self._slabs is None:
            raise RuntimeError("pool layout unset: alloc() a block first")
        bid = self._take_slot()
        for f, (shape, dtype) in self._specs.items():
            blank = jnp.full(shape, -1, dtype) if f == "kpos" \
                else jnp.zeros(shape, dtype)
            self._slabs[f] = self._slabs[f].at[bid].set(blank)
        return bid

    def copy(self, bid: int) -> int:
        """CoW: a new sole-owner block holding ``bid``'s bytes."""
        new = self._take_slot()
        for f in self._specs:
            self._slabs[f] = self._slabs[f].at[new].set(self._slabs[f][bid])
        self.cow_copies += 1
        self.bytes_copied += self.block_nbytes
        return new

    def incref(self, bid: int):
        assert self.refcounts[bid] > 0, f"incref of free block {bid}"
        self.refcounts[bid] += 1

    def decref(self, bid: int):
        rc = self.refcounts[bid]
        if rc <= 0:
            raise AssertionError(f"double free of block {bid}")
        self.refcounts[bid] = rc - 1
        if rc == 1:
            self.frees += 1
            self._free.append(bid)

    # -- access ---------------------------------------------------------
    def read(self, bid: int) -> dict:
        """The block's fields as device array views (one block's span)."""
        return {f: self._slabs[f][bid] for f in self._specs}

    def write_slice(self, bid: int, lo: int, hi: int, fields: dict):
        """Overwrite tokens [lo, hi) of a SOLELY-OWNED block (callers CoW
        first when the refcount is > 1)."""
        assert self.refcounts[bid] == 1, \
            f"write to shared block {bid} (refcount {self.refcounts[bid]})"
        assert 0 <= lo <= hi <= self.block_size, (lo, hi)
        for f, arr in fields.items():
            idx = [bid] + [slice(None)] * len(self._specs[f][0])
            idx[_TOKEN_AXIS[f] + 1] = slice(lo, hi)
            self._slabs[f] = self._slabs[f].at[tuple(idx)].set(jnp.asarray(arr))

    # -- accounting -----------------------------------------------------
    def live_blocks(self) -> int:
        return sum(1 for rc in self.refcounts if rc > 0)

    def audit(self):
        """No block is both free and referenced; free-list ids are unique;
        every slot is either live or on the free list."""
        assert len(self._free) == len(set(self._free)), "dup free-list ids"
        for bid in self._free:
            assert self.refcounts[bid] == 0, f"free block {bid} referenced"
        assert all(rc >= 0 for rc in self.refcounts)
        assert self.live_blocks() + len(self._free) == self.capacity, \
            (self.live_blocks(), len(self._free), self.capacity)


class PagedKVCache:
    """A request's paged view of its attention KV: a block table mapping
    logical block index (token span [i·bs, (i+1)·bs)) to a physical
    :class:`BlockPool` block, or None while the span is not yet resident.

    ``clone()`` is an O(1)-copied-bytes fork: the child copies the table
    and increfs every mapped block — both sessions then alias the same
    device memory until one of them writes (``write_span`` copies a shared
    block before mutating it: copy-on-write on append)."""

    def __init__(self, pool: BlockPool, n_tokens: int = 0):
        self.pool = pool
        self.blocks: List[Optional[int]] = [None] * self._nblocks(n_tokens)
        self.n_tokens = n_tokens

    def _nblocks(self, n: int) -> int:
        return -(-n // self.pool.block_size)

    # -- fork / free ----------------------------------------------------
    def clone(self) -> "PagedKVCache":
        child = PagedKVCache(self.pool, self.n_tokens)
        child.blocks = list(self.blocks)
        for bid in child.blocks:
            if bid is not None:
                self.pool.incref(bid)
        return child

    def free(self):
        for bid in self.blocks:
            if bid is not None:
                self.pool.decref(bid)
        self.blocks = []
        self.n_tokens = 0

    def truncate(self, n_tokens: int):
        """Drop table entries past ``n_tokens`` (releasing their refs) —
        e.g. a fork that only inherits the parent's stored prefix, not its
        decoded tail."""
        keep = self._nblocks(n_tokens)
        for bid in self.blocks[keep:]:
            if bid is not None:
                self.pool.decref(bid)
        self.blocks = self.blocks[:keep]
        self.n_tokens = min(self.n_tokens, n_tokens)

    # -- residency ------------------------------------------------------
    def _extend(self, n_tokens: int):
        need = self._nblocks(n_tokens)
        if need > len(self.blocks):
            self.blocks.extend([None] * (need - len(self.blocks)))
        self.n_tokens = max(self.n_tokens, n_tokens)

    def has_block(self, idx: int) -> bool:
        return idx < len(self.blocks) and self.blocks[idx] is not None

    def map_block(self, idx: int, bid: int):
        """Alias an existing pool block (e.g. a store chunk promoted to
        HBM) at logical index ``idx``; takes a new reference."""
        self._extend((idx + 1) * self.pool.block_size)
        old = self.blocks[idx]
        if old == bid:
            return
        self.pool.incref(bid)
        if old is not None:
            self.pool.decref(old)
        self.blocks[idx] = bid

    def missing_blocks(self, t0: int, t1: int) -> List[int]:
        bs = self.pool.block_size
        return [i for i in range(t0 // bs, self._nblocks(t1))
                if not self.has_block(i)]

    def read_block(self, idx: int) -> dict:
        return self.pool.read(self.blocks[idx])

    # -- copy-on-write append -------------------------------------------
    def write_span(self, t0: int, t1: int, fields: dict):
        """Write tokens [t0, t1) of the given attention fields through the
        table.  Unmapped blocks allocate fresh; blocks shared with another
        table (refcount > 1) are copied first — the writer pays one block
        copy, every other referent keeps the original bytes."""
        self.pool.ensure_layout(fields)
        self._extend(t1)
        bs = self.pool.block_size
        for idx in range(t0 // bs, self._nblocks(t1)):
            lo = max(t0, idx * bs) - idx * bs
            hi = min(t1, (idx + 1) * bs) - idx * bs
            bid = self.blocks[idx]
            if bid is None:
                bid = self.pool.alloc_blank()
            elif self.pool.refcounts[bid] > 1:
                new = self.pool.copy(bid)
                self.pool.decref(bid)
                bid = new
            self.blocks[idx] = bid
            sliced = {}
            for f, arr in fields.items():
                ax = _TOKEN_AXIS[f]
                take = [slice(None)] * jnp.asarray(arr).ndim
                take[ax] = slice(idx * bs + lo - t0, idx * bs + hi - t0)
                sliced[f] = jnp.asarray(arr)[tuple(take)]
            self.pool.write_slice(bid, lo, hi, sliced)


def grow_cache(cfg: ModelConfig, cache: dict, new_len: int) -> dict:
    """Pad the attention KV buffers (k/v/ckv/kpos) so the cache holds
    ``new_len`` tokens — how suffix prefill and decode append onto a
    restored prefix cache.  Recurrent/RWKV state fields are length-free and
    pass through; windowed archs stay capped at the ring-buffer size."""
    target = cache_seq_len(cfg, new_len)
    out = {}
    for f, a in cache.items():
        if f in ("k", "v", "ckv") and a.shape[2] < target:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, target - a.shape[2])
            out[f] = jnp.pad(a, pad)
        elif f == "kpos" and a.shape[1] < target:
            out[f] = jnp.pad(a, ((0, 0), (0, target - a.shape[1])),
                             constant_values=-1)
        else:
            out[f] = a
    return out
