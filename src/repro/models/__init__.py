from repro.models.model import Model, build_model  # noqa: F401
from repro.models.kvcache import init_cache, layer_slots, cache_bytes  # noqa: F401
