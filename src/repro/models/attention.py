"""GQA / MQA / windowed attention with unified full & chunked-cache paths.

Three compute backends:
  * ``naive``  — materialises (S, T) scores; used for short sequences.
  * ``flash``  — pure-jnp online-softmax over key blocks via ``lax.scan``;
                 bounded memory for long sequences (this is also the oracle
                 structure the Pallas kernels implement on TPU).
  * ``pallas`` — ``repro.kernels`` flash kernels (TPU target; interpret mode
                 on CPU for tests).

The chunked path (``apply_attention_chunk``) is the restoration primitive:
queries of a chunk attend to [cached prefix || chunk] and the chunk's KV is
written into the cache — one recompute-pointer step of CacheFlow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_head_norm

NEG_INF = -1e30
_FLASH_THRESHOLD = 8192       # use blocked attention above this many keys
_FLASH_BLOCK = 1024


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, hq, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    # flattened head dims => always divisible by the "model" mesh axis
    p = {
        "wq": dense_init(ks[0], (d, hq * dh), dtype),
        "wk": dense_init(ks[1], (d, hk * dh), dtype),
        "wv": dense_init(ks[2], (d, hk * dh), dtype),
        "wo": dense_init(ks[3], (hq * dh, d), dtype),
    }
    if cfg.use_qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hk * dh,), dtype)
        p["bv"] = jnp.zeros((hk * dh,), dtype)
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, params: dict, x: jax.Array, positions: jax.Array):
    """x: (B, S, D) -> q (B,S,Hq,Dh), k/v (B,S,Hk,Dh), rope applied."""
    b, s, _ = x.shape
    hq, hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.use_qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hk, dh)
    v = v.reshape(b, s, hk, dh)
    if cfg.use_qk_norm:
        q = rms_head_norm(q, params["q_norm"])
        k = rms_head_norm(k, params["k_norm"])
    if cfg.position == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Core attention math (grouped heads)
# ---------------------------------------------------------------------------


def _gqa_scores_naive(q, k, v, mask, scale):
    """q:(B,S,Hq,Dh) k/v:(B,T,Hk,Dh) mask:(B,S,T) or (S,T) -> (B,S,Hq,Dh)."""
    b, s, hq, dh = q.shape
    hk = k.shape[2]
    g = hq // hk
    qg = q.reshape(b, s, hk, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, hq, dh)


def _gqa_flash(q, k, v, q_pos, k_pos, scale, window: int, block: int = _FLASH_BLOCK):
    """Online-softmax attention, scanning key blocks; O(S·block) memory.

    q:(B,S,Hq,Dh); k/v:(B,T,Hk,Dh); q_pos:(B,S) int32; k_pos:(B,T) int32
    (entries < 0 are invalid/empty cache slots).
    """
    from repro.distributed.constraints import _ambient_mesh, constrain
    # Distribution of the blocked attention: shard heads over "model" when
    # they divide the axis; otherwise fall back to SEQUENCE-parallel queries
    # (q rows are independent in flash attention) with replicated KV — this
    # is what keeps 24-head/8-kv archs (phi4) from replicating the whole
    # attention computation per shard.
    mesh = _ambient_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    if msize > 1 and q.shape[2] % msize == 0:
        q = constrain(q, ("pod", "data"), None, "model", None)
        k = constrain(k, ("pod", "data"), None, "model", None)
        v = constrain(v, ("pod", "data"), None, "model", None)
    elif msize > 1 and q.shape[1] > 1 and q.shape[1] % msize == 0:
        q = constrain(q, ("pod", "data"), "model", None, None)
        k = constrain(k, ("pod", "data"), None, None, None)
        v = constrain(v, ("pod", "data"), None, None, None)
    b, s, hq, dh = q.shape
    t = k.shape[1]
    hk = k.shape[2]
    g = hq // hk
    dv = v.shape[-1]          # may differ from dh (MLA: qk 192, v 128)
    if t % block:
        pad = block - t % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        t += pad
    nb = t // block
    qg = q.reshape(b, s, hk, g, dh)
    kb = k.reshape(b, nb, block, hk, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block, hk, dv).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(b, nb, block).transpose(1, 0, 2)

    def step(carry, blk):
        m, l, acc = carry                      # (B,Hk,G,S), (B,Hk,G,S), (B,S,Hk,G,Dh)
        kc, vc, pc = blk
        sc = jnp.einsum("bskgd,btkd->bkgst", qg, kc).astype(jnp.float32) * scale
        valid = (pc[:, None, :] <= q_pos[:, :, None]) & (pc[:, None, :] >= 0)
        if window > 0:
            valid &= pc[:, None, :] > q_pos[:, :, None] - window
        sc = jnp.where(valid[:, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), vc).astype(jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, s), jnp.float32)
    a0 = jnp.zeros((b, s, hk, g, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.reshape(b, s, hq, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full-sequence path (train / prefill)
# ---------------------------------------------------------------------------


def attention_full(cfg: ModelConfig, params: dict, x: jax.Array, positions: jax.Array,
                   backend: str = "auto"):
    """Causal self-attention over the whole sequence.

    Returns (out (B,S,D), (k, v)) — callers keep k/v when building a cache.
    """
    q, k, v = _project_qkv(cfg, params, x, positions)
    scale = 1.0 / (cfg.qk_head_dim ** 0.5)
    b, s = x.shape[:2]
    use_flash = backend == "flash" or (backend in ("auto", "pallas") and s > _FLASH_THRESHOLD)
    if backend == "pallas" and s <= 0:
        pass  # pallas dispatch happens in repro.kernels.dispatch (model-level flag)
    if use_flash:
        out = _gqa_flash(q, k, v, positions, positions, scale, cfg.attn_window)
    else:
        i = positions[:, :, None] if positions.ndim == 2 else positions[:, None]
        j = positions[:, None, :] if positions.ndim == 2 else positions[None, :]
        mask = j <= i
        if cfg.attn_window:
            mask &= j > i - cfg.attn_window
        if mask.ndim == 2:
            mask = mask[None]
        mask = jnp.broadcast_to(mask, (b, s, s))
        out = _gqa_scores_naive(q, k, v, mask, scale)
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"].astype(x.dtype), (k, v)


# ---------------------------------------------------------------------------
# Chunked path (restoration recompute step / decode)
# ---------------------------------------------------------------------------


def attention_chunk(cfg: ModelConfig, params: dict, x: jax.Array, positions: jax.Array,
                    k_cache: jax.Array, v_cache: jax.Array, kpos: jax.Array,
                    backend: str = "auto"):
    """Chunk queries attend to [cache || chunk]; chunk KV is written back.

    x: (B, C, D) chunk activations; positions: (B, C) absolute positions.
    k_cache/v_cache: (B, S_cache, Hk, Dh); kpos: (S_cache,) slot positions.
    Returns (out, k_cache', v_cache', kpos').
    """
    b, c, _ = x.shape
    s_cache = k_cache.shape[1]
    q, k, v = _project_qkv(cfg, params, x, positions)
    # --- write chunk KV into the cache (ring buffer if windowed) ---
    slot = positions[0] % s_cache if cfg.attn_window else positions[0]
    k_cache = k_cache.at[:, slot].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[:, slot].set(v.astype(v_cache.dtype))
    kpos = kpos.at[slot].set(positions[0])
    scale = 1.0 / (cfg.qk_head_dim ** 0.5)
    kp = jnp.broadcast_to(kpos[None], (b, s_cache))
    if c == 1 or s_cache > _FLASH_THRESHOLD or backend == "flash":
        out = _gqa_flash(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                         positions, kp, scale, cfg.attn_window,
                         block=min(_FLASH_BLOCK, max(128, s_cache)))
    else:
        mask = (kp[:, None, :] <= positions[:, :, None]) & (kp[:, None, :] >= 0)
        if cfg.attn_window:
            mask &= kp[:, None, :] > positions[:, :, None] - cfg.attn_window
        out = _gqa_scores_naive(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                                mask, scale)
    out = out.reshape(b, c, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"].astype(x.dtype), k_cache, v_cache, kpos
