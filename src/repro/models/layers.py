"""Common building blocks: norms, MLPs, rotary / sinusoidal positions.

All modules are functional: ``init_*`` returns a param pytree (nested dict),
the apply function takes ``(params, x, ...)``.  Parameter leaves are created
in ``param_dtype``; compute runs in the dtype of the incoming activations
(callers cast at the model boundary).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = 0):
    """Truncated-normal fan-in init (matches common LLM inits closely enough
    for loss-goes-down purposes)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(np.prod([shape[a] for a in in_axis]))
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, dim: int, dtype) -> dict:
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(kind: str, params: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    elif kind == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)
                + params["bias"].astype(jnp.float32)).astype(x.dtype)
    raise ValueError(kind)


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (Qwen3 style): normalise the trailing head_dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated and plain)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(k1, (d_model, d_ff), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype),
    }


def apply_mlp(params: dict, x: jax.Array, activation: str) -> jax.Array:
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
        g = act(x @ params["w_gate"].astype(x.dtype))
        u = x @ params["w_up"].astype(x.dtype)
        return (g * u) @ params["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ params["w_up"].astype(x.dtype))
    return h @ params["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh) or (..., S, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                              # (Dh/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, Dh/2)
    if x.ndim == angles.ndim + 1:                              # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """(..., S) int positions -> (..., S, d_model) sinusoidal embedding."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap
