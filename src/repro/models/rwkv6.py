"""RWKV-6 "Finch" block: data-dependent-decay time mix + channel mix.

Time mix (per head, head_size = Dh; state S ∈ R^{Dh×Dh}):
    S_t = diag(w_t) S_{t-1} + k_t vᵀ_t
    y_t = (S_{t-1} + diag(u ⊙ k_t) · (k̂_t v̂ᵀ_t? — bonus term)ᵀ) r_t
        = Sᵀ_{t-1} r_t + (r_t · k_t)(u ⊙ v_t)      [equivalent contraction]
with data-dependent decay  w_t = exp(−exp(w0 + tanh(x_w W1) W2)) ∈ (0,1)^D
and data-dependent token-shift mixing (the "ddlerp" five-way LoRA).

The recurrence is sequential over time (diag decay ⇒ associative, but the
(Dh×Dh) state makes a full associative scan memory-prohibitive); the ref path
uses ``lax.scan`` per token, the ops path a *chunked* scan (parallel within a
chunk, sequential across chunks — the structure the Pallas ``rwkv6_scan``
kernel implements with the state resident in VMEM).

Attention-free: no KV cache. "Restoration" for this arch is loading the O(1)
per-layer state — see DESIGN.md §5 (token/layer pointers inapplicable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init

_MIX_NAMES = ("w", "k", "v", "r", "g")


def init_rwkv_block(key, cfg: ModelConfig, dtype) -> dict:
    r = cfg.rwkv
    d = cfg.d_model
    h = d // r.head_size
    ks = jax.random.split(key, 16)
    p = {
        # time-mix projections
        "w_r": dense_init(ks[0], (d, d), dtype),
        "w_k": dense_init(ks[1], (d, d), dtype),
        "w_v": dense_init(ks[2], (d, d), dtype),
        "w_g": dense_init(ks[3], (d, d), dtype),
        "w_o": dense_init(ks[4], (d, d), dtype),
        # data-dependent decay LoRA
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "decay_w1": dense_init(ks[5], (d, r.decay_lora_rank), dtype),
        "decay_w2": dense_init(ks[6], (r.decay_lora_rank, d), dtype),
        "bonus_u": (jax.random.normal(ks[7], (h, r.head_size), jnp.float32) * 0.1),
        # ddlerp token-shift: base mus + shared lora
        "mix_base": (jax.random.normal(ks[8], (len(_MIX_NAMES), d), jnp.float32) * 0.02),
        "mix_w1": dense_init(ks[9], (d, len(_MIX_NAMES) * r.tokenshift_lora_rank), dtype),
        "mix_w2": dense_init(ks[10], (len(_MIX_NAMES), r.tokenshift_lora_rank, d), dtype,
                             in_axis=1),
        "ln_y_scale": jnp.ones((d,), dtype),   # per-head groupnorm on y
        "ln_y_bias": jnp.zeros((d,), dtype),
        # channel-mix
        "cm_mix_k": (jax.random.normal(ks[11], (d,), jnp.float32) * 0.02),
        "cm_mix_r": (jax.random.normal(ks[12], (d,), jnp.float32) * 0.02),
        "cm_k": dense_init(ks[13], (d, cfg.d_ff), dtype),
        "cm_v": dense_init(ks[14], (cfg.d_ff, d), dtype),
        "cm_r": dense_init(ks[15], (d, d), dtype),
    }
    return p


def _ddlerp(params: dict, x: jax.Array, x_prev: jax.Array, rank: int):
    """Data-dependent five-way token-shift mix -> dict name -> mixed input."""
    xx = x_prev - x
    base = x + xx * params["mix_base"][_MIX_NAMES.index("w")].astype(x.dtype)  # seed mix
    lora = jnp.tanh(base @ params["mix_w1"].astype(x.dtype))
    lora = lora.reshape(*x.shape[:-1], len(_MIX_NAMES), rank)
    deltas = jnp.einsum("...nr,nrd->...nd", lora, params["mix_w2"].astype(x.dtype))
    out = {}
    for i, name in enumerate(_MIX_NAMES):
        mu = params["mix_base"][i].astype(x.dtype) + deltas[..., i, :]
        out[name] = x + xx * mu
    return out


def wkv_scan_ref(r, k, v, w, u, s0):
    """Sequential wkv recurrence (oracle).

    r,k,v: (B,S,H,Dh); w: (B,S,H,Dh) decay in (0,1); u: (H,Dh);
    s0: (B,H,Dh,Dh).  Returns (y (B,S,H,Dh), s_last).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp           # (B,H,Dh) each
        # y_t = S^T r + (r·k)(u ⊙ v)?  Use explicit contraction:
        # y[d_v] = sum_dk r[dk] * (S[dk,dv] + u[dk]*k[dk]*v[dv])
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s) \
            + jnp.einsum("bhk,bhk,bhv->bhv", r_t, u[None] * k_t, v_t)
        s = s * w_t[..., None] + jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        return s, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s_last


def wkv_scan_chunked(r, k, v, w, u, s0, chunk: int = 64):
    """Chunked wkv: O(S·Dh) state traffic instead of per-token scan.

    Within a chunk the contribution of the entering state and the intra-chunk
    "linear attention" term are computed in parallel (this mirrors the Pallas
    kernel's VMEM blocking).
    """
    b, s, h, dh = r.shape
    if s % chunk:
        return wkv_scan_ref(r, k, v, w, u, s0)
    n = s // chunk
    rc, kc, vc, wc = (a.reshape(b, n, chunk, h, dh).transpose(1, 0, 2, 3, 4)
                      for a in (r, k, v, w))

    def body(s_in, inp):
        r_t, k_t, v_t, w_t = inp                       # (B,C,H,Dh)
        logw = jnp.log(jnp.maximum(w_t, 1e-30))
        cum = jnp.cumsum(logw, axis=1)                 # log ∏_{i<=t} w_i  (decreasing)
        cum_ex = cum - logw                            # log ∏_{i<t}  w_i
        # state contribution: y_state[t] = (r_t ⊙ e^{cum_ex[t]})^T S_in   (e^{cum_ex} ≤ 1)
        y = jnp.einsum("bchk,bhkv->bchv", r_t * jnp.exp(cum_ex), s_in)
        # intra-chunk: coeff(t,j<t) = Σ_k r_tk k_jk e^{cum_ex[t]−cum[j]}.
        # Factored form e^{cum_ex[t]} · e^{−cum[j]}; the second factor is
        # clipped — it only saturates where the true coefficient underflows.
        att = jnp.einsum("bchk,bjhk->bhcj",
                         r_t * jnp.exp(cum_ex),
                         k_t * jnp.exp(jnp.clip(-cum, None, 60.0)))
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
        att = jnp.where(tri[None, None], att, 0.0)
        y += jnp.einsum("bhcj,bjhv->bchv", att, v_t)
        # bonus diagonal term
        y += jnp.einsum("bchk,bchk,bchv->bchv", r_t, u[None, None] * k_t, v_t)
        # state update: S_out = e^{cum[-1]} S_in + Σ_j e^{cum[-1]−cum[j]} k_j v_j^T
        # (cum[-1]−cum[j] ≤ 0 ⇒ exact, no overflow)
        s_out = s_in * jnp.exp(cum[:, -1])[..., None] \
            + jnp.einsum("bjhk,bjhv->bhkv", k_t * jnp.exp(cum[:, -1:] - cum), v_t)
        return s_out, y

    s_last, ys = jax.lax.scan(body, s0.astype(jnp.float32),
                              (rc.astype(jnp.float32), kc.astype(jnp.float32),
                               vc.astype(jnp.float32), wc.astype(jnp.float32)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return y, s_last


def time_mix(cfg: ModelConfig, params: dict, x: jax.Array, shift_state: jax.Array,
             wkv_state: jax.Array, backend: str = "auto"):
    """x: (B,S,D); shift_state: (B,D) last token of previous chunk;
    wkv_state: (B,H,Dh,Dh) fp32. Returns (out, shift', wkv')."""
    rk = cfg.rwkv
    b, s, d = x.shape
    h, dh = d // rk.head_size, rk.head_size
    x_prev = jnp.concatenate([shift_state[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    mixed = _ddlerp(params, x, x_prev, rk.tokenshift_lora_rank)
    r = (mixed["r"] @ params["w_r"].astype(x.dtype)).reshape(b, s, h, dh)
    k = (mixed["k"] @ params["w_k"].astype(x.dtype)).reshape(b, s, h, dh)
    v = (mixed["v"] @ params["w_v"].astype(x.dtype)).reshape(b, s, h, dh)
    g = jax.nn.silu(mixed["g"] @ params["w_g"].astype(x.dtype))
    dec = params["decay_base"].astype(jnp.float32) + \
        (jnp.tanh(mixed["w"] @ params["decay_w1"].astype(x.dtype)).astype(jnp.float32)
         @ params["decay_w2"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(dec)).reshape(b, s, h, dh)               # (0,1)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    if backend == "pallas":
        from repro.kernels.rwkv6_scan import ops as _ops
        y, wkv_state = _ops.wkv6(rf, kf, vf, w, params["bonus_u"], wkv_state)
    elif s >= 128 and s % 64 == 0:
        y, wkv_state = wkv_scan_chunked(rf, kf, vf, w, params["bonus_u"], wkv_state)
    else:
        y, wkv_state = wkv_scan_ref(rf, kf, vf, w, params["bonus_u"], wkv_state)
    # per-head groupnorm
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = y * params["ln_y_scale"].astype(x.dtype) + params["ln_y_bias"].astype(x.dtype)
    out = (y * g) @ params["w_o"].astype(x.dtype)
    return out, x[:, -1], wkv_state


def channel_mix(cfg: ModelConfig, params: dict, x: jax.Array, shift_state: jax.Array):
    """Finch channel mix: relu²(k)·W_v gated by receptance."""
    x_prev = jnp.concatenate([shift_state[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    xx = x_prev - x
    x_k = x + xx * params["cm_mix_k"].astype(x.dtype)
    x_r = x + xx * params["cm_mix_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(x_k @ params["cm_k"].astype(x.dtype)))
    kv = k @ params["cm_v"].astype(x.dtype)
    out = jax.nn.sigmoid(x_r @ params["cm_r"].astype(x.dtype)) * kv
    return out, x[:, -1]
