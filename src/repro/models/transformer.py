"""Per-layer blocks (pre-norm residual) shared by the stack in ``model.py``.

Every block comes in two entry points:
  * ``*_full``  — whole-sequence forward (train / prefill); emits the state
                  the cache stores for that layer kind.
  * ``*_cached``— chunk forward against an existing cache (restoration
                  recompute steps and single-token decode are the same path
                  with C = chunk or C = 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, layer_idx: int, dtype) -> dict:
    kind = cfg.layer_kinds()[layer_idx]
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "norm1": init_norm(cfg.norm, cfg.d_model, dtype),
        "norm2": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if kind == "attention":
        p["attn"] = (mla_mod.init_mla(k1, cfg, dtype) if cfg.mla is not None
                     else attn.init_attention(k1, cfg, dtype))
    elif kind == "recurrent":
        p["rglru"] = rglru_mod.init_rglru_block(k1, cfg, dtype)
    elif kind == "rwkv":
        p["rwkv"] = rwkv_mod.init_rwkv_block(k1, cfg, dtype)
        return p  # rwkv blocks have no separate MLP (channel mix is inside)
    # FFN: dense or MoE
    if cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense:
        p["moe"] = moe_mod.init_moe(k2, cfg, dtype)
    else:
        d_ff = (cfg.moe.dense_d_ff if (cfg.moe is not None and cfg.moe.dense_d_ff)
                else cfg.d_ff)
        p["mlp"] = init_mlp(k2, cfg.d_model, d_ff, cfg.activation, dtype)
    return p


# ---------------------------------------------------------------------------
# Full-sequence blocks
# ---------------------------------------------------------------------------


def _ffn(cfg: ModelConfig, p: dict, h: jax.Array, moe_groups: int):
    if "moe" in p:
        y, aux = moe_mod.apply_moe(p["moe"], h, cfg, num_groups=moe_groups)
        return y, aux
    return apply_mlp(p["mlp"], h, cfg.activation), jnp.zeros((), jnp.float32)


def attention_layer_full(cfg: ModelConfig, p: dict, x, positions, *, backend="auto",
                         moe_groups: int = 0):
    """Returns (x', layer_cache_entry, aux). Cache entry:
    {"k","v"} or {"ckv"} for the *whole* sequence."""
    h = apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, ckv = mla_mod.mla_full(cfg, p["attn"], h, positions, backend)
        entry = {"ckv": ckv}
    else:
        a, (k, v) = attn.attention_full(cfg, p["attn"], h, positions, backend)
        entry = {"k": k, "v": v}
    x = x + a
    h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
    f, aux = _ffn(cfg, p, h, moe_groups)
    return x + f, entry, aux


def recurrent_layer_full(cfg: ModelConfig, p: dict, x, conv_tail, h0, *, backend="auto"):
    h = apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
    r, conv_tail, h_last = rglru_mod.rglru_full(cfg, p["rglru"], h, conv_tail, h0, backend)
    x = x + r
    h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
    f, _ = _ffn(cfg, p, h, 0)
    return x + f, conv_tail, h_last


def rwkv_layer_full(cfg: ModelConfig, p: dict, x, shift_tm, shift_cm, wkv, *, backend="auto"):
    h = apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
    t, shift_tm, wkv = rwkv_mod.time_mix(cfg, p["rwkv"], h, shift_tm, wkv, backend)
    x = x + t
    h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
    c, shift_cm = rwkv_mod.channel_mix(cfg, p["rwkv"], h, shift_cm)
    return x + c, shift_tm, shift_cm, wkv


# ---------------------------------------------------------------------------
# Cached-chunk blocks (restoration recompute / decode)
# ---------------------------------------------------------------------------


def attention_layer_cached(cfg: ModelConfig, p: dict, x, positions, layer_cache: dict,
                           *, backend="auto", moe_groups: int = 0):
    """layer_cache: {"k","v","kpos"} or {"ckv","kpos"} views for THIS layer.
    Returns (x', updated layer_cache)."""
    h = apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, ckv, kpos = mla_mod.mla_chunk(cfg, p["attn"], h, positions,
                                         layer_cache["ckv"], layer_cache["kpos"], backend)
        new_cache = {"ckv": ckv, "kpos": kpos}
    else:
        a, k, v, kpos = attn.attention_chunk(cfg, p["attn"], h, positions,
                                             layer_cache["k"], layer_cache["v"],
                                             layer_cache["kpos"], backend)
        new_cache = {"k": k, "v": v, "kpos": kpos}
    x = x + a
    h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
    f, _ = _ffn(cfg, p, h, moe_groups)
    return x + f, new_cache
