"""DeepSeek-V2 Multi-head Latent Attention (MLA).

The KV cache stores only the compressed latent ``c_kv`` (kv_lora_rank) plus a
shared rotary key (qk_rope_head_dim) per token — 592 dims/layer for V2 vs
~32k for an equivalent MHA cache row.  For CacheFlow this shrinks T_io per
token ~55×, pushing the token-wise crossover L_Δ strongly toward
recomputation (see DESIGN.md §5).

Two attention paths:
  * ``mla_full``  — prefill/train: decompress per-head K/V and run flash
    (blocked online-softmax) attention for long sequences.
  * ``mla_chunk`` — decode/restoration chunks: **absorbed** attention — scores
    and values are computed directly against the compressed latents
    (q̃ = q·W_uk, out = probs·c_kv·W_uv), never materialising per-head K/V of
    the whole cache.  This is the TPU-friendly analogue of DeepSeek's decode
    kernel and is what makes decode_32k/B=128 memory-feasible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.attention import _gqa_flash, _FLASH_THRESHOLD
from repro.models.layers import apply_rope, dense_init, apply_norm, init_norm

NEG_INF = -1e30


def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if m.q_lora_rank > 0:
        p["wq_a"] = dense_init(ks[0], (d, m.q_lora_rank), dtype)
        p["q_norm"] = init_norm("rmsnorm", m.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[1], (m.q_lora_rank, h * qk_dim), dtype)
    else:
        p["wq"] = dense_init(ks[0], (d, h * qk_dim), dtype)
    p["wkv_a"] = dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype)
    p["kv_norm"] = init_norm("rmsnorm", m.kv_lora_rank, dtype)
    p["wkv_b"] = dense_init(ks[3], (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)), dtype)
    p["wo"] = dense_init(ks[4], (h * m.v_head_dim, d), dtype)
    return p


def _project_q(cfg: ModelConfig, params: dict, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank > 0:
        ql = x @ params["wq_a"].astype(x.dtype)
        ql = apply_norm("rmsnorm", params["q_norm"], ql, cfg.norm_eps)
        q = ql @ params["wq_b"].astype(x.dtype)
    else:
        q = x @ params["wq"].astype(x.dtype)
    q = q.reshape(b, s, h, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def compress_kv(cfg: ModelConfig, params: dict, x: jax.Array, positions: jax.Array):
    """x -> [c_kv (normalised) || k_rope (rotated)]: (B,S,lora+rope).
    This is exactly what the cache stores and what restoration I/O moves."""
    m = cfg.mla
    kv = x @ params["wkv_a"].astype(x.dtype)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = apply_norm("rmsnorm", params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return jnp.concatenate([c_kv, k_rope], axis=-1)


def _uk_uv(cfg: ModelConfig, params: dict, dtype):
    m = cfg.mla
    h = cfg.num_heads
    wkv_b = params["wkv_b"].astype(dtype).reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., : m.qk_nope_head_dim]   # (lora, H, nope)
    w_uv = wkv_b[..., m.qk_nope_head_dim:]    # (lora, H, vd)
    return w_uk, w_uv


def mla_full(cfg: ModelConfig, params: dict, x: jax.Array, positions: jax.Array,
             backend: str = "auto"):
    """Full causal MLA (prefill/train). Returns (out, ckv latent for caching)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _project_q(cfg, params, x, positions)
    ckv = compress_kv(cfg, params, x, positions)
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    # decompress per-head K/V (sharded over heads on the mesh; fine for prefill)
    kv = c_kv @ params["wkv_b"].astype(x.dtype)
    kv = kv.reshape(b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, h, m.qk_rope_head_dim))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / (cfg.qk_head_dim ** 0.5)
    if s > _FLASH_THRESHOLD or backend == "flash":
        # pad v to qk dim? no — flash handles differing v dim via separate arg shapes
        out = _gqa_flash(q, k, v, positions, positions, scale, 0)
    else:
        sc = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
        mask = positions[:, :, None] >= positions[:, None, :]
        sc = jnp.where(mask[:, None], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthd->bshd", p, v)
    out = out.reshape(b, s, h * m.v_head_dim)
    return out @ params["wo"].astype(x.dtype), ckv


def mla_chunk(cfg: ModelConfig, params: dict, x: jax.Array, positions: jax.Array,
              ckv_cache: jax.Array, kpos: jax.Array, backend: str = "auto"):
    """Absorbed-matrix chunk/decode attention over the latent cache.

    x: (B,C,D); ckv_cache: (B,S_cache,lora+rope); kpos: (S_cache,).
    Returns (out, ckv_cache', kpos').
    """
    q_nope, q_rope = _project_q(cfg, params, x, positions)
    ckv = compress_kv(cfg, params, x, positions)
    slot = positions[0]
    ckv_cache = ckv_cache.at[:, slot].set(ckv.astype(ckv_cache.dtype))
    kpos = kpos.at[slot].set(positions[0])
    out = mla_attend_absorbed(cfg, params, q_nope, q_rope, positions,
                              ckv_cache.astype(x.dtype), kpos)
    return out, ckv_cache, kpos


def mla_attend_absorbed(cfg: ModelConfig, params: dict, q_nope, q_rope,
                        positions, lat, kpos):
    """Absorbed attention over a (read-only) latent cache view."""
    m = cfg.mla
    b, c = q_nope.shape[:2]
    h = cfg.num_heads
    x_dtype = q_nope.dtype
    c_kv, k_rope = jnp.split(lat, [m.kv_lora_rank], axis=-1)     # (B,T,lora),(B,T,rope)
    w_uk, w_uv = _uk_uv(cfg, params, x_dtype)
    # absorb W_uk into q: q̃ (B,C,H,lora)
    q_lat = jnp.einsum("bchd,lhd->bchl", q_nope, w_uk)
    scale = 1.0 / (cfg.qk_head_dim ** 0.5)
    sc = jnp.einsum("bchl,btl->bhct", q_lat, c_kv)
    sc += jnp.einsum("bchd,btd->bhct", q_rope, k_rope)
    sc = sc.astype(jnp.float32) * scale
    kp = kpos[None, None, None, :]
    mask = (kp <= positions[:, None, :, None]) & (kp >= 0)
    sc = jnp.where(mask, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(x_dtype)
    # out in latent space, then absorb W_uv
    o_lat = jnp.einsum("bhct,btl->bchl", p, c_kv)
    out = jnp.einsum("bchl,lhd->bchd", o_lat, w_uv)
    out = out.reshape(b, c, h * m.v_head_dim)
    return out @ params["wo"].astype(x_dtype)
