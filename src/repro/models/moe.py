"""Mixture-of-Experts FFN (DeepSeek-style shared + routed, top-k).

TPU-native dispatch (GShard lineage, scatter formulation): tokens are
scattered into a per-expert capacity buffer ``(E, C, D)``, expert FFNs run as
dense einsums over that buffer, results are gathered back and combined with
router weights.  The buffer's expert axis is sharded over the "model" mesh
axis (expert parallelism) and its capacity axis over "data" — GSPMD derives
the token all-to-all from the shardings.

Memory is bounded by ``num_groups``: tokens are processed in sequential
groups via ``lax.scan``, capping the dispatch buffers at
``tokens/num_groups × top_k`` slots (the classic GShard group trick).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.models.layers import apply_mlp, dense_init, init_mlp


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    k_router, k_exp, k_shared = jax.random.split(key, 3)
    gated = cfg.activation in ("swiglu", "geglu")
    n_mats = 3 if gated else 2
    ek = jax.random.split(k_exp, n_mats)
    p = {
        "router": dense_init(k_router, (d, m.num_experts), jnp.float32),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "w_gate": dense_init(ek[0], (m.num_experts, d, m.expert_d_ff), dtype, in_axis=1),
        "w_up": dense_init(ek[1 % n_mats], (m.num_experts, d, m.expert_d_ff), dtype, in_axis=1),
        "w_down": dense_init(ek[-1], (m.num_experts, m.expert_d_ff, d), dtype, in_axis=1),
    }
    if not gated:
        del p["w_gate"]
    shared_ff = m.shared_d_ff or m.num_shared_experts * m.expert_d_ff
    if shared_ff:
        p["shared"] = init_mlp(k_shared, d, shared_ff, cfg.activation, dtype)
    return p


def _expert_ffn(params: dict, xb: jax.Array, activation: str) -> jax.Array:
    """xb: (E, C, D) -> (E, C, D) through per-expert weights."""
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
        g = act(jnp.einsum("ecd,edf->ecf", xb, params["w_gate"].astype(xb.dtype)))
        u = jnp.einsum("ecd,edf->ecf", xb, params["w_up"].astype(xb.dtype))
        h = g * u
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xb, params["w_up"].astype(xb.dtype)))
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(xb.dtype))


# dispatch mechanism: "scatter" (token scatter/gather; GSPMD lowers the
# cross-shard scatter to full-buffer all-reduces — collective-heavy) or
# "einsum" (GShard one-hot dispatch matmuls; partitions into one all-to-all,
# at the cost of T·E·C·D dispatch FLOPs). See EXPERIMENTS.md §Perf.
DISPATCH = "scatter"


def moe_group(params: dict, x: jax.Array, moe: MoEConfig, activation: str):
    """One group of tokens through the routed experts.

    x: (T, D) -> (y (T, D), aux_loss scalar)
    """
    t, d = x.shape
    e, k = moe.num_experts, moe.top_k
    # capacity_factor <= 0 => dropless (cap = t covers the worst case: every
    # token hits the same expert once). Serving/restoration MUST be dropless
    # so chunked recomputation reproduces the full-prefill KV bit-for-bit.
    cap = t if moe.capacity_factor <= 0 else max(1, int(t * k / e * moe.capacity_factor))
    if DISPATCH == "einsum" and moe.capacity_factor > 0:
        return _moe_group_einsum(params, x, moe, activation, cap)

    from repro.distributed.constraints import constrain

    logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                                    # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)           # renormalise

    # --- slot assignment: position of each (token, k) among its expert's hits
    flat_e = top_i.reshape(t * k)                                             # (T·k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)                       # (T·k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]       # (T·k,)
    keep = slot < cap

    # --- scatter tokens into the (E, C, D) buffer; EP: experts over "model",
    # capacity over "data" — GSPMD derives the token all-to-all
    x_rep = jnp.repeat(x, k, axis=0)                                          # (T·k, D)
    x_rep = jnp.where(keep[:, None], x_rep, 0)
    x_rep = constrain(x_rep, ("pod", "data"), None)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_e, jnp.minimum(slot, cap - 1)].add(x_rep)
    buf = constrain(buf, "model", "data", None)

    y_buf = _expert_ffn(params, buf, activation)                              # (E, C, D)
    y_buf = constrain(y_buf, "model", "data", None)

    # --- gather back + combine
    y_rep = y_buf[flat_e, jnp.minimum(slot, cap - 1)]                         # (T·k, D)
    y_rep = jnp.where(keep[:, None], y_rep, 0)
    w = (top_p.reshape(t * k, 1)).astype(y_rep.dtype)
    y = (y_rep * w).reshape(t, k, d).sum(axis=1)

    # --- load-balancing aux loss (Switch style)
    me = probs.mean(axis=0)                                                   # (E,)
    ce = jnp.bincount(flat_e, length=e).astype(jnp.float32) / (t * k)
    aux = e * jnp.sum(me * ce) * moe.router_aux_loss
    return y, aux


def _moe_group_einsum(params: dict, x: jax.Array, moe: MoEConfig,
                      activation: str, cap: int):
    """GShard-style one-hot dispatch: one all-to-all instead of scatter
    all-reduces. Keep groups small (T ≈ 2-4k) so the (T,E,C) one-hot fits."""
    from repro.distributed.constraints import constrain
    t, d = x.shape
    e, k = moe.num_experts, moe.top_k
    logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    onehot_e = jax.nn.one_hot(top_i, e, dtype=jnp.float32)           # (T,k,E)
    # position of each (t,k) hit within its expert
    pos = jnp.cumsum(onehot_e.reshape(t * k, e), axis=0) - onehot_e.reshape(t * k, e)
    slot = (pos.reshape(t, k, e) * onehot_e).sum(-1).astype(jnp.int32)  # (T,k)
    keep = slot < cap
    onehot_c = jax.nn.one_hot(slot, cap, dtype=x.dtype) * keep[..., None]
    # dispatch (T,E,C) = Σ_k onehot_e ⊗ onehot_c
    disp = jnp.einsum("tke,tkc->tec", onehot_e.astype(x.dtype), onehot_c)
    disp = constrain(disp, ("pod", "data"), "model", None)
    comb = jnp.einsum("tke,tkc,tk->tec", onehot_e.astype(x.dtype), onehot_c,
                      top_p.astype(x.dtype))
    comb = constrain(comb, ("pod", "data"), "model", None)
    buf = jnp.einsum("tec,td->ecd", disp, x)
    buf = constrain(buf, "model", "data", None)
    y_buf = _expert_ffn(params, buf, activation)
    y_buf = constrain(y_buf, "model", "data", None)
    y = jnp.einsum("tec,ecd->td", comb, y_buf)

    me = probs.mean(axis=0)
    ce = onehot_e.sum(axis=(0, 1)) / (t * k)
    aux = e * jnp.sum(me * ce) * moe.router_aux_loss
    return y, aux


def apply_moe(params: dict, x: jax.Array, cfg: ModelConfig, num_groups: int = 0):
    """x: (B, S, D) -> (y, aux_loss). ``num_groups`` > 1 bounds dispatch
    memory by scanning groups of the SEQUENCE axis sequentially (grouping
    along S keeps the batch-axis sharding intact — grouping along B would
    force a gather whenever groups < batch shards)."""
    m = cfg.moe
    b, s, d = x.shape
    if num_groups <= 1 or s % num_groups:
        y, aux = moe_group(params, x.reshape(b * s, d), m, cfg.activation)
        y = y.reshape(b, s, d)
    else:
        sg = s // num_groups
        grouped = x.reshape(b, num_groups, sg, d).transpose(1, 0, 2, 3)

        def body(_, xg):
            yg, auxg = moe_group(params, xg.reshape(b * sg, d), m, cfg.activation)
            return None, (yg.reshape(b, sg, d), auxg)

        _, (y, aux) = jax.lax.scan(body, None, grouped)
        y = y.transpose(1, 0, 2, 3).reshape(b, s, d)
        aux = aux.mean()
    if "shared" in params:
        y = y + apply_mlp(params["shared"], x, cfg.activation)
    return y, aux
