"""Real-JAX restoration executor.

Executes CacheFlow restoration ops (from the BatchScheduler / plans) on an
actual model: compute ops run chunk/layer forwards on device, load ops copy
KV slices from the stored payload — then the restored cache is verified
against the full-prefill ground truth.  The simulator measures the schedule;
this executor proves its *correctness* (restored KV ≡ recomputed KV for any
legal op interleaving — a property test randomises the interleaving).

Requests are single-sequence (B = 1) as in the serving engine.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import BoundaryStore, StoredRequest, stage_bounds
from repro.core.plans import RequestPlan, make_request_plans
from repro.core.scheduler import ScheduledOp
from repro.models.model import Model

ATTN_FIELDS = ("k", "v", "ckv")


class RestorationExecutor:
    def __init__(self, model: Model, params, store: Optional[BoundaryStore] = None,
                 *, chunk_size: int = 16, stages: int = 1):
        self.model = model
        self.params = params
        self.store = store or BoundaryStore()
        self.chunk_size = chunk_size
        self.stages = stages
        self.bounds = stage_bounds(model.cfg.num_layers, stages)
        # live restoration state: rid -> dict(cache=..., act={stage: x}, ...)
        self._live: Dict[str, dict] = {}

    # ------------------------------------------------------------------
    # Previous turn: full (chunked) prefill; persist KV + boundaries + states
    # ------------------------------------------------------------------
    def remember(self, rid: str, inputs) -> StoredRequest:
        m, cfg = self.model, self.model.cfg
        n = inputs.shape[1]
        cache = m.init_cache(1, n, dtype=m.compute_dtype)
        boundaries = {s: [] for s in range(self.stages)}
        snapshots: Dict[Tuple[int, int], dict] = {}
        c = self.chunk_size
        x_last = None
        for ci, t0 in enumerate(range(0, n, c)):
            t1 = min(n, t0 + c)
            pos = jnp.arange(t0, t1, dtype=jnp.int32)[None]
            chunk = inputs[:, t0:t1]
            x = m.embed(self.params, chunk, pos)
            for s, (lo, hi) in enumerate(self.bounds):
                boundaries[s].append(x)
                for i in range(lo, hi):
                    x, cache = m.layer_chunk(self.params, i, x, pos, cache)
                # snapshot recurrent state at end of this chunk for this stage
                snap = _state_snapshot(cfg, cache)
                if snap:
                    snapshots[(s, ci)] = snap
            x_last = x
        logits = m.unembed(self.params, x_last[:, -1:])[:, 0]
        req = StoredRequest(
            request_id=rid, n_tokens=n, inputs=inputs,
            kv_reference=jax.tree.map(lambda a: a, cache),
            boundaries={s: jnp.concatenate(bs, axis=1) for s, bs in boundaries.items()},
            state_snapshots=snapshots, final_logits=logits)
        self.store.put(req)
        return req

    # ------------------------------------------------------------------
    # Restoration
    # ------------------------------------------------------------------
    def begin_restore(self, rid: str, plans: Optional[List[RequestPlan]] = None):
        req = self.store.get(rid)
        m = self.model
        cache = m.init_cache(1, req.n_tokens, dtype=m.compute_dtype)
        self._live[rid] = {"cache": cache, "act": {}, "req": req}
        if plans is not None:
            self._live[rid]["plans"] = {p.stage: p for p in plans}

    def live_cache(self, rid: str):
        """The in-flight (or final) restored cache of a live restoration."""
        return self._live[rid]["cache"]

    def make_plans(self, rid: str, *, l_delta: int, strategy: Optional[str] = None
                   ) -> List[RequestPlan]:
        req = self.store.get(rid)
        cfg = self.model.cfg
        if cfg.rwkv is not None:
            strategy = "layer"      # token pointers inapplicable (DESIGN §5)
        return make_request_plans(rid, req.n_tokens, chunk_size=self.chunk_size,
                                  l_delta=l_delta, num_layers=cfg.num_layers,
                                  stage_bounds=self.bounds if self.stages > 1 else None,
                                  strategy=strategy)

    def execute_op(self, op: ScheduledOp):
        if op.kind == "compute":
            self._exec_compute(op)
        else:
            self._exec_load(op)

    # -- compute ---------------------------------------------------------
    def _stage_input(self, rid: str, stage: int, t0: int, t1: int):
        """Activations entering the stage's first layer for tokens [t0,t1)."""
        m = self.model
        live = self._live[rid]
        req: StoredRequest = live["req"]
        if stage == 0:
            pos = jnp.arange(t0, t1, dtype=jnp.int32)[None]
            return m.embed(self.params, req.inputs[:, t0:t1], pos)
        return self.store.read_boundary(rid, stage)[:, t0:t1]

    def _exec_compute(self, op: ScheduledOp):
        m = self.model
        live = self._live[op.request_id]
        cache = live["cache"]
        t0, t1 = op.tokens
        lo, hi = op.layers
        pos = jnp.arange(t0, t1, dtype=jnp.int32)[None]
        plan = _plan_of(live, op)
        if plan.strategy == "token":
            x = self._stage_input(op.request_id, op.stage, t0, t1)
            for i in range(lo, hi):
                x, cache = m.layer_chunk(self.params, i, x, pos, cache)
        else:
            # layer-wise: maintain the running full-prefix activation
            key = ("act", op.stage)
            if key not in live["act"]:
                live["act"][key] = self._stage_input(op.request_id, op.stage,
                                                     0, plan.n_tokens)
            x = live["act"][key]
            for i in range(lo, hi):
                x, cache = m.layer_chunk(self.params, i, x, pos, cache)
            live["act"][key] = x
        live["cache"] = cache

    # -- load --------------------------------------------------------------
    def _exec_load(self, op: ScheduledOp):
        cfg = self.model.cfg
        live = self._live[op.request_id]
        req: StoredRequest = live["req"]
        cache, ref = live["cache"], req.kv_reference
        t0, t1 = op.tokens
        lo, hi = op.layers
        plan = _plan_of(live, op)
        slots = self.model.slots
        for i in range(lo, hi):
            kind, slot = slots[i]
            if kind == "attention":
                kp_ref = ref["kpos"][slot]
                # slots whose stored position falls inside [t0, t1)
                sel = np.nonzero((np.asarray(kp_ref) >= t0) & (np.asarray(kp_ref) < t1))[0]
                if sel.size:
                    sel = jnp.asarray(sel)
                    for f in ATTN_FIELDS:
                        if f in cache:
                            upd = cache[f][slot].at[:, sel].set(ref[f][slot][:, sel])
                            cache[f] = cache[f].at[slot].set(upd)
                    cache["kpos"] = cache["kpos"].at[slot, sel].set(kp_ref[sel])
            else:
                # recurrent/rwkv state. Layer strategy: this layer is restored
                # wholly by I/O -> apply its end-of-prefix snapshot now (compute
                # never touches this slot). Token strategy: state fix-up happens
                # in finalize_restore so op order cannot clobber the live state.
                if plan.strategy == "layer":
                    n_chunks = -(-plan.n_tokens // self.chunk_size)
                    snap = req.state_snapshots.get((op.stage, n_chunks - 1))
                    if snap:
                        for f, arr in snap.items():
                            cache[f] = cache[f].at[slot].set(arr[slot])
        live["cache"] = cache

    # ------------------------------------------------------------------
    def restore(self, rid: str, *, l_delta: int = 0, strategy: Optional[str] = None,
                plans: Optional[List[RequestPlan]] = None,
                io_policy: str = "longest_remaining",
                op_order: str = "alternate", rng: Optional[np.random.Generator] = None):
        """Run a full restoration for one request; returns the live cache.

        Convenience wrapper: drives the shared engine core with a RealBackend
        over a single-request batch.  op_order: "alternate" | "io_first" |
        "compute_first" | "random" | "measured" — mapped onto schedule
        durations (see ``interleaving_dur_fn``); correctness must hold for
        ANY legal interleaving (property-tested).
        """
        from repro.core.engine_core import (EngineCore, EngineRequest,
                                            RealBackend, interleaving_dur_fn)
        if plans is None:
            plans = self.make_plans(rid, l_delta=l_delta, strategy=strategy)
        backend = RealBackend(self, dur_fn=interleaving_dur_fn(op_order, rng))
        core = EngineCore(backend, stages=max(p.stage for p in plans) + 1,
                          io_channels=1, io_policy=io_policy, strict=True)
        req = self.store.get(rid)
        core.run([EngineRequest(rid, req.n_tokens, 0.0, plans)])
        return self._live[rid]["cache"]

    def finalize_restore(self, rid: str):
        """Recurrent-state fix-up for token-wise plans on hybrid archs: the
        end-of-prefix state must come from the tail chunk's snapshot whenever
        I/O restored the tail (compute ops legitimately run the state only up
        to the meeting point; op order must not matter)."""
        cfg = self.model.cfg
        if cfg.rglru is None and cfg.rwkv is None:
            return
        live = self._live[rid]
        req: StoredRequest = live["req"]
        cache = live["cache"]
        for stage, plan in live["plans"].items():
            if plan.strategy != "token" or plan.plan.io_done == 0:
                continue
            n_chunks = plan.plan.n_units
            snap = req.state_snapshots.get((stage, n_chunks - 1))
            if not snap:
                continue
            lo, hi = plan.layer_lo, plan.layer_hi
            for i in range(lo, hi):
                kind, slot = self.model.slots[i]
                if kind != "attention":
                    for f, arr in snap.items():
                        cache[f] = cache[f].at[slot].set(arr[slot])
        live["cache"] = cache

    # ------------------------------------------------------------------
    def verify(self, rid: str, atol: float = 2e-2) -> dict:
        """Compare the live restored cache against the ground-truth payload.
        Returns max-abs errors per field (raises on mismatch)."""
        live = self._live[rid]
        req: StoredRequest = live["req"]
        errs = {}
        for f in req.kv_reference:
            a = np.asarray(req.kv_reference[f], np.float32)
            b = np.asarray(live["cache"][f], np.float32)
            if f == "kpos":
                if not (a == b).all():
                    raise AssertionError(f"kpos mismatch for {rid}")
                errs[f] = 0.0
                continue
            err = float(np.max(np.abs(a - b))) if a.size else 0.0
            errs[f] = err
            if err >= atol:
                raise AssertionError(f"{f} mismatch for {rid}: {err}")
        return errs

    def first_token_logits(self, rid: str, new_inputs):
        """Prefill the new suffix on the restored cache -> first-token logits."""
        m = self.model
        live = self._live[rid]
        req: StoredRequest = live["req"]
        n = req.n_tokens
        # grow cache to fit the suffix
        c_new = new_inputs.shape[1]
        cache = _grow_cache(self.model, live["cache"], n + c_new)
        logits, cache = m.prefill_chunk(self.params, new_inputs, cache, n)
        live["cache"] = cache
        return logits


# ---------------------------------------------------------------------------


def _plan_of(live: dict, op: ScheduledOp) -> RequestPlan:
    return live["plans"][op.stage]


def _state_snapshot(cfg, cache: dict) -> dict:
    out = {}
    for f in ("conv", "lru", "wkv", "shift_tm", "shift_cm"):
        if f in cache:
            out[f] = cache[f]
    return out


def _grow_cache(model: Model, cache: dict, new_len: int) -> dict:
    from repro.models.kvcache import cache_seq_len
    target = cache_seq_len(model.cfg, new_len)
    out = {}
    for f, a in cache.items():
        if f in ("k", "v", "ckv") and a.shape[2] < target:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, target - a.shape[2])
            out[f] = jnp.pad(a, pad)
        elif f == "kpos" and a.shape[1] < target:
            out[f] = jnp.pad(a, ((0, 0), (0, target - a.shape[1])), constant_values=-1)
        else:
            out[f] = a
    return out
