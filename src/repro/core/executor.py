"""Real-JAX request-lifecycle executor.

Executes CacheFlow lifecycle ops (from the BatchScheduler / plans) on an
actual model: restoration compute ops run chunk/layer forwards on device,
load ops copy KV slices from the stored payload, suffix-prefill ops run the
new turn's tokens through each pipeline stage of the restored cache (the
last stage yields the first-token logits), and batched decode steps append
one generated token per request.  The restored cache is verified against
the full-prefill ground truth.  The simulator measures the schedule; this
executor proves its *correctness* (restored KV ≡ recomputed KV for any
legal op interleaving — a property test randomises the interleaving).

Requests are single-sequence (B = 1) as in the serving engine; decode
batches across requests by stepping each live cache in arrival order.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import BoundaryStore, StoredRequest, stage_bounds
from repro.core.plans import RequestPlan, make_request_plans
from repro.core.scheduler import ScheduledOp
from repro.models.kvcache import (PagedKVCache, grow_cache, park_cache,
                                  unpark_cache)
from repro.models.model import Model

ATTN_FIELDS = ("k", "v", "ckv")


class RestorationExecutor:
    def __init__(self, model: Model, params, store: Optional[BoundaryStore] = None,
                 *, chunk_size: int = 16, stages: int = 1, chunk_store=None,
                 datapath=None):
        self.model = model
        self.params = params
        self.store = store or BoundaryStore()
        self.chunk_size = chunk_size
        self.stages = stages
        self.bounds = stage_bounds(model.cfg.num_layers, stages)
        # materialized chunk-granular KV store (repro.storage.ChunkStore):
        # load ops read REAL chunk bytes out of its tiers instead of the
        # boundary store's ground-truth payload.  Requires linear (non-ring)
        # attention caches; store blocks must tile the executor's I/O unit
        # (block size divides chunk_size), so residency — and partial
        # re-restoration after eviction — is BLOCK-granular even when the
        # restoration plan moves coarser units.
        if chunk_store is not None:
            if chunk_size % chunk_store.chunk_size != 0:
                raise ValueError(
                    f"chunk_store block size {chunk_store.chunk_size} must "
                    f"divide executor chunk_size {chunk_size}")
            if model.cfg.attn_window:
                raise ValueError("chunk store does not support ring-buffer "
                                 "(windowed) caches; token->slot is modular")
        self.chunk_store = chunk_store
        # fused restoration datapath (core/datapath.py): load ops consume
        # the store's PACKED chunk bytes through per-channel transfer
        # streams and one dequant-scatter launch per op; None restores
        # through the legacy per-chunk/per-layer/per-field `.at[].set()`
        # path (kept as the measured baseline and the fallback for ops
        # whose layer span has no attention slots)
        self.datapath = datapath
        self.io_channel = 0          # engine channel of the op in flight
        # accounting (benchmarks/tests): cache-write + staging dispatches
        # issued by load ops, and which path each load op took
        self.load_dispatches = 0
        self.fused_loads = 0
        self.legacy_loads = 0
        # live restoration state: rid -> dict(cache=..., act={stage: x}, ...)
        self._live: Dict[str, dict] = {}
        # lifecycle inputs registered before the engine runs:
        # rid -> (suffix inputs | None, decode_len)
        self._suffix: Dict[str, Tuple[object, int]] = {}
        # child rid -> parent rid for O(1) session forks (fork())
        self._forks: Dict[str, str] = {}

    # ------------------------------------------------------------------
    # Previous turn: full (chunked) prefill; persist KV + boundaries + states
    # ------------------------------------------------------------------
    def remember(self, rid: str, inputs) -> StoredRequest:
        m, cfg = self.model, self.model.cfg
        n = inputs.shape[1]
        cache = m.init_cache(1, n, dtype=m.compute_dtype)
        boundaries = {s: [] for s in range(self.stages)}
        snapshots: Dict[Tuple[int, int], dict] = {}
        c = self.chunk_size
        x_last = None
        for ci, t0 in enumerate(range(0, n, c)):
            t1 = min(n, t0 + c)
            pos = jnp.arange(t0, t1, dtype=jnp.int32)[None]
            chunk = inputs[:, t0:t1]
            x = m.embed(self.params, chunk, pos)
            for s, (lo, hi) in enumerate(self.bounds):
                boundaries[s].append(x)
                for i in range(lo, hi):
                    x, cache = m.layer_chunk(self.params, i, x, pos, cache)
                # snapshot recurrent state at end of this chunk for this stage
                snap = _state_snapshot(cfg, cache)
                if snap:
                    snapshots[(s, ci)] = snap
            x_last = x
        logits = m.unembed(self.params, x_last[:, -1:])[:, 0]
        req = StoredRequest(
            request_id=rid, n_tokens=n, inputs=inputs,
            kv_reference=jax.tree.map(lambda a: a, cache),
            boundaries={s: jnp.concatenate(bs, axis=1) for s, bs in boundaries.items()},
            state_snapshots=snapshots, final_logits=logits)
        self.store.put(req)
        if self.chunk_store is not None and "kpos" in cache:
            # materialize the prefix KV as content-addressed chunks (shared
            # prefixes dedup); non-attention state stays in the boundary
            # store's snapshots — it has no per-token byte range
            self.chunk_store.put_request(rid, inputs, cache)
        return req

    def fork(self, parent_rid: str, child_rid: str) -> StoredRequest:
        """O(1) fork of a stored (possibly live) session: the child aliases
        the parent's stored prefix — inputs/KV reference/boundaries are
        SHARED arrays, the chunk chain forks by refcount bumps, and on
        device the child's block table will alias the parent's physical
        blocks (copy-on-write) when restoration begins.  No prefill runs
        and no KV bytes are copied; contrast with :meth:`remember`, which
        recomputes the whole prefix."""
        child = self.store.fork(parent_rid, child_rid)
        if self.chunk_store is not None:
            self.chunk_store.fork_request(parent_rid, child_rid)
        self._forks[child_rid] = parent_rid
        return child

    # ------------------------------------------------------------------
    # Restoration
    # ------------------------------------------------------------------
    def begin_restore(self, rid: str, plans: Optional[List[RequestPlan]] = None):
        req = self.store.get(rid)
        m = self.model
        cache = m.init_cache(1, req.n_tokens, dtype=m.compute_dtype)
        self._live[rid] = {"cache": cache, "act": {}, "req": req}
        if plans is not None:
            self._live[rid]["plans"] = {p.stage: p for p in plans}
        if self.chunk_store is not None and "kpos" in cache:
            parent = self._forks.get(rid)
            p_live = self._live.get(parent) if parent is not None else None
            if p_live is not None and "paged" in p_live:
                # fork of a LIVE session: the child's block table clones the
                # parent's — O(1) copied bytes, CoW from here on.  Only the
                # stored prefix is inherited (not the parent's decoded tail).
                paged = p_live["paged"].clone()
                paged.truncate(req.n_tokens)
            else:
                paged = PagedKVCache(self.chunk_store.pool, req.n_tokens)
            self._live[rid]["paged"] = paged
            self._sync_paged(rid)

    def _sync_paged(self, rid: str):
        """Alias every already-HBM-resident store block into the request's
        block table (no bytes move) — the table then answers residency at
        block granularity."""
        live = self._live[rid]
        paged: PagedKVCache = live["paged"]
        n_blocks = paged._nblocks(live["req"].n_tokens)
        for ci, key in enumerate(self.chunk_store.requests.get(rid, ())):
            if ci >= n_blocks:
                break
            bid = self.chunk_store.block_of(key)
            if bid is not None and not paged.has_block(ci):
                paged.map_block(ci, bid)

    def _paged_write(self, live: dict, t0: int, t1: int):
        """Write tokens [t0, t1) of the live contiguous cache through the
        request's block table (CoW: blocks shared with a forked session are
        copied before mutation)."""
        paged = live.get("paged")
        if paged is None:
            return
        cache = live["cache"]
        fields = {f: cache[f][:, :, t0:t1] for f in ATTN_FIELDS if f in cache}
        fields["kpos"] = cache["kpos"][:, t0:t1]
        paged.write_span(t0, t1, fields)

    def live_cache(self, rid: str):
        """The in-flight (or final) restored cache of a live restoration."""
        return self._live[rid]["cache"]

    def paged_cache(self, rid: str) -> Optional[PagedKVCache]:
        """The request's block-table view (None without a chunk store)."""
        live = self._live.get(rid)
        return live.get("paged") if live else None

    def make_plans(self, rid: str, *, l_delta: int, strategy: Optional[str] = None
                   ) -> List[RequestPlan]:
        req = self.store.get(rid)
        cfg = self.model.cfg
        if cfg.rwkv is not None:
            strategy = "layer"      # token pointers inapplicable (DESIGN §5)
        return make_request_plans(rid, req.n_tokens, chunk_size=self.chunk_size,
                                  l_delta=l_delta, num_layers=cfg.num_layers,
                                  stage_bounds=self.bounds if self.stages > 1 else None,
                                  strategy=strategy)

    # ------------------------------------------------------------------
    # Lifecycle inputs (registered before the engine core runs)
    # ------------------------------------------------------------------
    def set_suffix(self, rid: str, new_inputs, decode_len: int = 0):
        """Register the request's new-turn suffix (may be None for
        decode-only lifecycles) and decode extent; the engine core's
        prefill/decode ops pull from here."""
        self._suffix[rid] = (new_inputs, decode_len)

    def suffix_inputs(self, rid: str):
        return self._suffix[rid][0]

    def outputs(self, rid: str) -> dict:
        """Per-request lifecycle outputs: first-token logits, greedy token
        ids, and the logits of every decode step."""
        live = self._live[rid]
        return {"first_logits": live.get("first_logits"),
                "last_logits": live.get("last_logits"),
                "tokens": list(live.get("tokens_out", [])),
                "step_logits": list(live.get("step_logits", []))}

    def execute_op(self, op: ScheduledOp):
        if op.kind == "compute":
            self._exec_compute(op)
        elif op.kind == "prefill":
            self._exec_prefill(op)
        else:
            self._exec_load(op)

    # -- compute ---------------------------------------------------------
    def _stage_input(self, rid: str, stage: int, t0: int, t1: int):
        """Activations entering the stage's first layer for tokens [t0,t1)."""
        m = self.model
        live = self._live[rid]
        req: StoredRequest = live["req"]
        if stage == 0:
            pos = jnp.arange(t0, t1, dtype=jnp.int32)[None]
            return m.embed(self.params, req.inputs[:, t0:t1], pos)
        return self.store.read_boundary(rid, stage)[:, t0:t1]

    def _exec_compute(self, op: ScheduledOp):
        m = self.model
        live = self._live[op.request_id]
        cache = live["cache"]
        t0, t1 = op.tokens
        lo, hi = op.layers
        pos = jnp.arange(t0, t1, dtype=jnp.int32)[None]
        plan = _plan_of(live, op)
        if plan.strategy == "token":
            x = self._stage_input(op.request_id, op.stage, t0, t1)
            for i in range(lo, hi):
                x, cache = m.layer_chunk(self.params, i, x, pos, cache)
        else:
            # layer-wise: the full-prefix activation ENTERING each unit is
            # snapshotted per unit (not a single running value) so an op
            # aborted by preemption after it already ran re-executes from
            # the same input — idempotent for any abort/resume interleaving.
            # Only the last two snapshots are live: unit u-1 can never run
            # again once unit u dispatches (its completion is permanent).
            acts = live["act"]
            key = (op.stage, op.unit)
            if key not in acts:
                assert op.unit == 0, key
                acts[key] = self._stage_input(op.request_id, op.stage,
                                              0, plan.n_tokens)
            x = acts[key]
            for i in range(lo, hi):
                x, cache = m.layer_chunk(self.params, i, x, pos, cache)
            acts[(op.stage, op.unit + 1)] = x
            acts.pop((op.stage, op.unit - 1), None)
        live["cache"] = cache

    def _attn_slot_span(self, lo: int, hi: int) -> Optional[Tuple[int, int]]:
        """Contiguous attention-slot range owned by layers [lo, hi) — slot
        counters grow monotonically with layer index, so any layer span
        maps to one contiguous slot range (asserted).  None when the span
        has no attention layers (pure-recurrent stage of a hybrid)."""
        slots = [s for k, s in (self.model.slots[i] for i in range(lo, hi))
                 if k == "attention"]
        if not slots:
            return None
        assert slots == list(range(slots[0], slots[0] + len(slots))), slots
        return slots[0], slots[-1] + 1

    # -- load --------------------------------------------------------------
    def _exec_load(self, op: ScheduledOp):
        live = self._live[op.request_id]
        req: StoredRequest = live["req"]
        cache, ref = live["cache"], req.kv_reference
        t0, t1 = op.tokens
        lo, hi = op.layers
        plan = _plan_of(live, op)
        slots = self.model.slots
        # materialized path: the transfer's bytes come out of the chunk
        # store's tiers; a store miss (chunk dropped off the bottom tier)
        # falls back to the ground truth.  With a datapath, the op's
        # chunks stay in their stored (possibly int8) encoding across the
        # wire and ONE fused dequant-scatter writes the whole layer span;
        # without one, the legacy loop decodes per chunk and issues one
        # `.at[].set()` per chunk x layer x field.
        chunks = packed = None
        if self.chunk_store is not None and "kpos" in cache:
            span = self._attn_slot_span(lo, hi)
            if self.datapath is not None and span is not None:
                packed = self.chunk_store.fetch_range_packed(
                    op.request_id, t0, t1)
            if packed is not None:
                self.datapath.restore_op(cache, packed,
                                         store=self.chunk_store,
                                         slot_span=span,
                                         channel=self.io_channel)
                self.fused_loads += 1
                self.load_dispatches += self.datapath.last_op_dispatches
                self._map_loaded_blocks(op.request_id, t0, t1)
            else:
                chunks = self.chunk_store.fetch_range(op.request_id, t0, t1)
                if chunks is not None:
                    self.legacy_loads += 1
                    self._map_loaded_blocks(op.request_id, t0, t1)
        kp_all = None
        # legacy per-chunk baseline + recurrent-state snapshot apply: kept
        # deliberately as the comparison point for the fused datapath
        for i in range(lo, hi):  # codelint: allow(at-set-loop)
            kind, slot = slots[i]
            if kind == "attention":
                if packed is not None:
                    continue          # fused scatter covered the whole span
                if chunks is not None:
                    for c0, c1, pay in chunks:
                        for f in ATTN_FIELDS:
                            if f in cache:
                                cache[f] = cache[f].at[slot, :, c0:c1].set(
                                    pay[f][slot])
                                self.load_dispatches += 1
                        cache["kpos"] = cache["kpos"].at[slot, c0:c1].set(
                            pay["kpos"][slot])
                        self.load_dispatches += 1
                    continue
                if kp_all is None:
                    kp_all = np.asarray(ref["kpos"])
                # slots whose stored position falls inside [t0, t1)
                sel = np.nonzero((kp_all[slot] >= t0)
                                 & (kp_all[slot] < t1))[0]
                if sel.size:
                    sel = jnp.asarray(sel)
                    for f in ATTN_FIELDS:
                        if f in cache:
                            cache[f] = cache[f].at[slot, :, sel].set(
                                jnp.moveaxis(ref[f][slot][:, sel], 1, 0))
                    cache["kpos"] = cache["kpos"].at[slot, sel].set(
                        ref["kpos"][slot][sel])
            else:
                # recurrent/rwkv state. Layer strategy: this layer is restored
                # wholly by I/O -> apply its end-of-prefix snapshot now (compute
                # never touches this slot). Token strategy: state fix-up happens
                # in finalize_restore so op order cannot clobber the live state.
                if plan.strategy == "layer":
                    n_chunks = -(-plan.n_tokens // self.chunk_size)
                    snap = req.state_snapshots.get((op.stage, n_chunks - 1))
                    if snap:
                        for f, arr in snap.items():
                            cache[f] = cache[f].at[slot].set(arr[slot])
        live["cache"] = cache

    def _map_loaded_blocks(self, rid: str, t0: int, t1: int):
        """After a load fetched tokens [t0, t1), alias the now-HBM-resident
        store blocks into the request's block table."""
        live = self._live[rid]
        paged = live.get("paged")
        if paged is None:
            return
        keys = self.chunk_store.requests.get(rid, ())
        cs = self.chunk_store.chunk_size
        for ci in range(t0 // cs, min(len(keys), -(-t1 // cs))):
            bid = self.chunk_store.block_of(keys[ci])
            if bid is not None and not paged.has_block(ci):
                paged.map_block(ci, bid)

    # -- suffix prefill (one op per pipeline stage, in stage order) --------
    def _exec_prefill(self, op: ScheduledOp):
        m = self.model
        live = self._live[op.request_id]
        req: StoredRequest = live["req"]
        new_inputs, decode_len = self._suffix[op.request_id]
        t0, t1 = op.tokens
        lo, hi = op.layers
        positions = jnp.arange(t0, t1, dtype=jnp.int32)[None]
        if "prefill_x" not in live:
            # first stage: make room for suffix + decode tail, embed suffix
            live["cache"] = grow_cache(m.cfg, live["cache"],
                                       req.n_tokens + (t1 - t0) + decode_len)
            live["prefill_x"] = m.embed(self.params, new_inputs, positions)
        x, cache = m.stack_chunk(self.params, live["prefill_x"], positions,
                                 live["cache"], lo, hi)
        live["prefill_x"], live["cache"] = x, cache
        if hi == m.cfg.num_layers:
            # last pipeline stage: the suffix's final activation gives the
            # request's FIRST output token
            logits = m.unembed(self.params, x[:, -1:])[:, 0]
            live["first_logits"] = logits
            live["last_logits"] = logits
            live["tokens_out"] = [int(jnp.argmax(logits[0]))]
            live["step_logits"] = []
            live["pos"] = t1
            # every layer's suffix KV is now in the contiguous cache:
            # append it through the block table (CoW against forks)
            if "kpos" in live["cache"]:
                self._paged_write(live, t0, t1)

    # -- batched decode (one token per request per step) -------------------
    def decode_step_batch(self, rids: List[str]):
        """One engine decode step: append one generated token to every
        listed request's live cache (greedy feed of its previous output)."""
        m, cfg = self.model, self.model.cfg
        for rid in rids:
            live = self._live[rid]
            req: StoredRequest = live["req"]
            if "pos" not in live:
                # decode-only lifecycle (no suffix): seed from the stored
                # prefix's final logits and grow room for the decode tail
                _, decode_len = self._suffix.get(rid, (None, 0))
                live["cache"] = grow_cache(cfg, live["cache"],
                                           req.n_tokens + max(1, decode_len))
                live["last_logits"] = req.final_logits
                live["tokens_out"] = []
                live["step_logits"] = []
                live["pos"] = req.n_tokens
            if cfg.input_mode == "tokens":
                inp = jnp.argmax(live["last_logits"], axis=-1).astype(jnp.int32)
            else:
                # embedding frontends have no token feedback path; feed a
                # deterministic pseudo-embedding keyed on the position
                key = jax.random.fold_in(jax.random.PRNGKey(0), live["pos"])
                inp = jax.random.normal(key, (1, cfg.d_model), jnp.float32)
            logits, cache = m.decode_step(self.params, inp, live["cache"],
                                          live["pos"])
            live["cache"] = cache
            live["last_logits"] = logits
            if "kpos" in cache:
                # append the new token's KV through the block table: a tail
                # block shared with a forked sibling copies here (CoW)
                self._paged_write(live, live["pos"], live["pos"] + 1)
            live["pos"] += 1
            live["tokens_out"].append(int(jnp.argmax(logits[0])))
            live["step_logits"].append(logits)

    # ------------------------------------------------------------------
    def restore(self, rid: str, *, l_delta: int = 0, strategy: Optional[str] = None,
                plans: Optional[List[RequestPlan]] = None,
                io_policy: str = "longest_remaining",
                op_order: str = "alternate", rng: Optional[np.random.Generator] = None):
        """Run a full restoration for one request; returns the live cache.

        Convenience wrapper: drives the shared engine core with a RealBackend
        over a single-request batch.  op_order: "alternate" | "io_first" |
        "compute_first" | "random" | "measured" — mapped onto schedule
        durations (see ``interleaving_dur_fn``); correctness must hold for
        ANY legal interleaving (property-tested).
        """
        from repro.core.engine_core import (EngineCore, EngineRequest,
                                            RealBackend, interleaving_dur_fn)
        if plans is None:
            plans = self.make_plans(rid, l_delta=l_delta, strategy=strategy)
        backend = RealBackend(self, dur_fn=interleaving_dur_fn(op_order, rng))
        core = EngineCore(backend, stages=max(p.stage for p in plans) + 1,
                          io_channels=1, io_policy=io_policy, strict=True)
        req = self.store.get(rid)
        core.run([EngineRequest(rid, req.n_tokens, 0.0, plans)])
        return self._live[rid]["cache"]

    # ------------------------------------------------------------------
    # Preemption: park / unpark an in-flight restoration
    # ------------------------------------------------------------------
    def suspend_restore(self, rid: str):
        """Park a preempted request's restoration state: the partially
        restored cache and layer-strategy boundary activations move to host
        buffers so a suspended request stops pinning device memory while it
        waits for a slot.  ``finalize_restore`` (recurrent-state fix-up) is
        deliberately NOT run — restoration is incomplete and will continue,
        not restart, on resume."""
        live = self._live[rid]
        live["cache"] = park_cache(live["cache"])
        live["act"] = {k: np.asarray(v) for k, v in live["act"].items()}
        live["parked"] = True

    def resume_restore(self, rid: str):
        """Inverse of :meth:`suspend_restore`: the parked state returns to
        device exactly as suspended; released plan units re-execute
        idempotently on top of it."""
        live = self._live[rid]
        live["cache"] = unpark_cache(live["cache"])
        live["act"] = {k: jnp.asarray(v) for k, v in live["act"].items()}
        live.pop("parked", None)

    def drop_restore(self, rid: str):
        """Eviction-mode preemption: the partially-restored cache (and its
        boundary activations) are DROPPED — nothing is parked, host memory
        is freed immediately.  Restoration restarts from the KV store via a
        fresh :meth:`begin_restore` when the request is re-admitted.  The
        block table releases its refs, but blocks the STORE still holds
        stay HBM-resident — re-restoration re-fetches only the blocks the
        store actually demoted in the meantime, not the whole prefix."""
        live = self._live.pop(rid, None)
        if live is not None and "paged" in live:
            live["paged"].free()

    def release(self, rid: str):
        """Retire a finished request: free its live state (block-table refs
        included) and drop its store references.  Store-held blocks remain
        for prefix reuse; chunks at refcount 0 become eviction candidates."""
        self.drop_restore(rid)
        self._forks.pop(rid, None)
        if self.chunk_store is not None:
            self.chunk_store.free_request(rid)

    def is_live(self, rid: str) -> bool:
        return rid in self._live

    def finalize_restore(self, rid: str):
        """Recurrent-state fix-up for token-wise plans on hybrid archs: the
        end-of-prefix state must come from the tail chunk's snapshot whenever
        I/O restored the tail (compute ops legitimately run the state only up
        to the meeting point; op order must not matter)."""
        cfg = self.model.cfg
        if cfg.rglru is None and cfg.rwkv is None:
            return
        live = self._live[rid]
        req: StoredRequest = live["req"]
        cache = live["cache"]
        for stage, plan in live["plans"].items():
            if plan.strategy != "token" or plan.plan.io_done == 0:
                continue
            n_chunks = plan.plan.n_units
            snap = req.state_snapshots.get((stage, n_chunks - 1))
            if not snap:
                continue
            lo, hi = plan.layer_lo, plan.layer_hi
            for i in range(lo, hi):
                kind, slot = self.model.slots[i]
                if kind != "attention":
                    # tiny once-per-layer state fix-up at restore finalize,
                    # not the bulk KV path
                    for f, arr in snap.items():  # codelint: allow(at-set-loop)
                        cache[f] = cache[f].at[slot].set(arr[slot])
        live["cache"] = cache

    # ------------------------------------------------------------------
    def verify(self, rid: str, atol: float = 2e-2) -> dict:
        """Compare the live restored cache against the ground-truth payload.
        Returns max-abs errors per field (raises on mismatch)."""
        live = self._live[rid]
        req: StoredRequest = live["req"]
        errs = {}
        for f in req.kv_reference:
            a = np.asarray(req.kv_reference[f], np.float32)
            b = np.asarray(live["cache"][f], np.float32)
            if f == "kpos":
                if not (a == b).all():
                    raise AssertionError(f"kpos mismatch for {rid}")
                errs[f] = 0.0
                continue
            err = float(np.max(np.abs(a - b))) if a.size else 0.0
            errs[f] = err
            if err >= atol:
                raise AssertionError(f"{f} mismatch for {rid}: {err}")
        return errs

    def first_token_logits(self, rid: str, new_inputs):
        """Prefill the new suffix on the restored cache -> first-token logits.

        One-shot convenience path (quickstart / direct use); the serving
        engines instead schedule per-stage ``prefill`` ops through the
        engine core so the suffix contends for stage compute."""
        m = self.model
        live = self._live[rid]
        req: StoredRequest = live["req"]
        n = req.n_tokens
        # grow cache to fit the suffix
        c_new = new_inputs.shape[1]
        cache = grow_cache(m.cfg, live["cache"], n + c_new)
        logits, cache = m.prefill_chunk(self.params, new_inputs, cache, n)
        live["cache"] = cache
        return logits


# ---------------------------------------------------------------------------


def _plan_of(live: dict, op: ScheduledOp) -> RequestPlan:
    return live["plans"][op.stage]


def _state_snapshot(cfg, cache: dict) -> dict:
    out = {}
    for f in ("conv", "lru", "wkv", "shift_tm", "shift_cm"):
        if f in cache:
            out[f] = cache[f]
    return out
