"""CacheFlow cost models and analysis (paper §3.1–§3.2).

T_comp(n): recomputing n prefix tokens — quadratic attention term + linear
param term + fixed per-chunk overhead (kernel launches, weight streaming).
T_io(n): loading n tokens' KV — linear in bytes, bounded by channel bandwidth.

Closed forms used throughout:
  optimal split    ℓ* = L·T_io / (T_comp + T_io)                      (Eq. 1)
  optimal time     T* = T_comp·T_io / (T_comp + T_io)   (harmonic mean)
  S-stage speedup  T*_multi = T*/S                                    (Eq. 2)
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.config import HardwareProfile, ModelConfig


@dataclass(frozen=True)
class CostModel:
    """Per-(model, hardware, bandwidth) restoration cost model.

    All times in seconds, token counts in tokens.
    """
    cfg: ModelConfig
    hw: HardwareProfile
    io_bandwidth: float            # bytes/s of the KV channel
    mfu: float = 0.5               # achievable fraction of peak during prefill
    num_chips: int = 1             # chips sharing the recompute (TP group)
    io_channels: int = 1           # parallel I/O channels
    # fraction of restoration-compute throughput a LIVE decode batch eats
    # (continuous batching: recurring decode steps timeshare the same chips
    # as chunk recomputes, so at steady state the compute alternative the
    # §3.3 benefit gate prices is slower than on an idle device).  0.0 keeps
    # the classic idle-device pricing.
    decode_interference: float = 0.0

    # ------------------------------------------------------------------
    def flops_recompute(self, n0: int, n1: int) -> float:
        """FLOPs to recompute tokens [n0, n1) given [0, n0) is already
        restored: linear param term + attention over the growing context."""
        pc = self.cfg.param_counts()
        n_active = pc["active"] - pc["embedding"]
        n = n1 - n0
        f = 2.0 * n_active * n
        # attention: each token t attends to t+1 keys (or window)
        n_attn = len(self.cfg.attention_layers)
        avg_ctx = (n0 + n1) / 2.0
        if self.cfg.attn_window:
            avg_ctx = min(avg_ctx, float(self.cfg.attn_window))
        f += 2.0 * 2.0 * n_attn * self.cfg.num_heads * self.cfg.qk_head_dim * n * avg_ctx
        return f

    def t_comp_range(self, n0: int, n1: int, chunks: int = 1) -> float:
        """Seconds to recompute tokens [n0, n1) in ``chunks`` kernel launches."""
        if n1 <= n0:
            return 0.0
        f = self.flops_recompute(n0, n1)
        return f / (self.hw.peak_flops * self.mfu * self.num_chips) \
            + chunks * self.hw.kernel_overhead_s

    def t_comp(self, n: int, chunk_size: int = 512) -> float:
        import math
        return self.t_comp_range(0, n, chunks=max(1, math.ceil(n / max(1, chunk_size))))

    # ------------------------------------------------------------------
    def bytes_per_token(self) -> int:
        return self.cfg.kv_bytes_per_token()

    def t_io_tokens(self, n: int) -> float:
        """Seconds to load n tokens' KV (all layers) over the channel(s)."""
        return n * self.bytes_per_token() / (self.io_bandwidth * self.io_channels)

    def t_io_layer_tokens(self, n_layers: int, n_tokens: int) -> float:
        n_attn = max(1, len(self.cfg.attention_layers))
        per_layer = self.bytes_per_token() / n_attn
        return n_layers * n_tokens * per_layer / (self.io_bandwidth * self.io_channels)

    # ------------------------------------------------------------------
    # Decode (lifecycle phases beyond restoration)
    # ------------------------------------------------------------------
    def t_decode_step(self, context_lens) -> float:
        """One batched decode step (one token for each request in the
        continuous batch): HBM-bandwidth-bound — the weights stream once
        per step and each request's KV context is read once — plus the
        fixed kernel overhead.  ``context_lens`` are per-request attended
        context lengths (capped by the attention window).  The weight-
        streaming term is paid once per step regardless of batch size, so a
        PARTIAL batch (requests streaming in/out mid-flight) amortizes it
        worse — per-request step cost falls as the continuous batch fills.
        An empty batch costs nothing (no step is issued)."""
        if not context_lens:
            return 0.0
        pc = self.cfg.param_counts()
        param_bytes = 2.0 * (pc["active"] - pc["embedding"])   # bf16 weights
        kv = 0.0
        for n in context_lens:
            if self.cfg.attn_window:
                n = min(n, self.cfg.attn_window)
            kv += n * self.bytes_per_token()
        return (param_bytes + kv) / (self.hw.hbm_bw * self.num_chips) \
            + self.hw.kernel_overhead_s

    # ------------------------------------------------------------------
    # Paper closed forms
    # ------------------------------------------------------------------
    def harmonic_bound(self, n: int) -> float:
        """T* = Tc·Tio/(Tc+Tio) — the two-pointer optimum (Eq. 1)."""
        tc = self.t_comp(n)
        tio = self.t_io_tokens(n)
        if tc + tio == 0:
            return 0.0
        return tc * tio / (tc + tio)

    def optimal_token_split(self, n: int) -> int:
        """Number of tokens to recompute from the front (rest loaded from the
        back). Accounts for the quadratic skew: front tokens are cheaper to
        recompute, so the optimum recomputes MORE than the linear-cost split
        would suggest. Solved by bisection on equal finish times."""
        lo, hi = 0, n
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if self.t_comp(mid) <= self.t_io_tokens(n - mid):
                lo = mid
            else:
                hi = mid
        return lo

    def t_token_wise(self, n: int) -> float:
        """Finish time of the optimal token-wise two-pointer schedule."""
        split = self.optimal_token_split(n)
        return max(self.t_comp(split), self.t_io_tokens(n - split))

    def optimal_layer_split(self, n: int) -> int:
        """Cutover layer ℓ: layers [0,ℓ) recomputed (one forward to layer ℓ),
        layers [ℓ,L) loaded top-down."""
        L = self.cfg.num_layers
        tc_full = self.t_comp(n, chunk_size=n)       # single launch, all layers
        lo, hi = 0, L
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if tc_full * mid / L <= self.t_io_layer_tokens(L - mid, n):
                lo = mid
            else:
                hi = mid
        return lo

    def t_layer_wise(self, n: int) -> float:
        L = self.cfg.num_layers
        ell = self.optimal_layer_split(n)
        tc_full = self.t_comp(n, chunk_size=n)
        return max(tc_full * ell / L, self.t_io_layer_tokens(L - ell, n))

    def crossover_l_delta(self, max_n: int = 65536, step: int = 128) -> int:
        """L_Δ = min{N | T_token(N) <= T_layer(N)} (paper Fig. 3). Largely
        hardware-dependent: token-wise wins once per-chunk fixed overheads
        amortise."""
        n = step
        while n <= max_n:
            if self.t_token_wise(n) <= self.t_layer_wise(n):
                return n
            n += step
        return max_n

    def stage_parallel_bound(self, n: int, stages: int) -> float:
        """Eq. 2: T*/S with boundary activations decoupling stages."""
        return self.harmonic_bound(n) / max(1, stages)

    def boundary_activation_bytes(self, n: int, dtype_bytes: int = 2) -> int:
        """Per stage boundary: n × d_model activations — the price of 3D
        decoupling (vs the stage's KV slice it replaces)."""
        return n * self.cfg.d_model * dtype_bytes
