"""Baseline restoration strategies the paper compares against (§4.1).

Each baseline is expressed in the same plan/scheduler machinery so the
simulator and executor measure all systems identically:

  * vllm     — recomputation-only standard prefill (compute-bound extreme).
  * lmcache  — pure KV loading, no recomputation (I/O-bound extreme).
  * sglang   — HiCache-style storage-tier loading; modeled as load-only with
               layer-granular pipelining (loads stream top-down by layer).
  * cake     — per-request token-dimension hybrid two-pointer, but
               request-centric: FIFO I/O allocation, no batch awareness, no
               stage-parallel restoration.
  * cacheflow— the full system: adaptive token/layer strategy (L_Δ),
               longest-remaining-first batched I/O, stage-parallel 3D.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.plans import RequestPlan, TwoPointerPlan, make_request_plans

BASELINES = ("vllm", "lmcache", "sglang", "cake", "cacheflow", "cacheflow_2d")


def _mode_plan(plan: TwoPointerPlan, mode: str) -> TwoPointerPlan:
    """Restrict a two-pointer plan to compute-only or io-only."""
    if mode == "compute_only":
        plan.io_enabled = False
    elif mode == "io_only":
        plan.comp_enabled = False
    return plan


def make_baseline_plans(system: str, request_id: str, n_tokens: int, *,
                        chunk_size: int, l_delta: int, num_layers: int,
                        stage_bounds: Optional[List[Tuple[int, int]]] = None
                        ) -> List[RequestPlan]:
    if system in ("cacheflow", "cacheflow_2d"):
        bounds = stage_bounds if system == "cacheflow" else None
        return make_request_plans(request_id, n_tokens, chunk_size=chunk_size,
                                  l_delta=l_delta, num_layers=num_layers,
                                  stage_bounds=bounds)
    if system == "cake":
        # token-dimension hybrid, single-request optimal, no stage parallelism
        return make_request_plans(request_id, n_tokens, chunk_size=chunk_size,
                                  l_delta=0, num_layers=num_layers,
                                  stage_bounds=None, strategy="token")
    if system == "vllm":
        plans = make_request_plans(request_id, n_tokens, chunk_size=chunk_size,
                                   l_delta=0, num_layers=num_layers,
                                   strategy="token")
    elif system in ("lmcache", "sglang"):
        strategy = "token" if system == "lmcache" else "layer"
        plans = make_request_plans(request_id, n_tokens, chunk_size=chunk_size,
                                   l_delta=0, num_layers=num_layers,
                                   strategy=strategy)
    else:
        raise ValueError(system)
    mode = "compute_only" if system == "vllm" else "io_only"
    for p in plans:
        _mode_plan(p.plan, mode)
    return plans


def sim_kwargs(system: str) -> dict:
    """Scheduler/simulator settings per system."""
    if system == "cacheflow":
        return dict(io_policy="longest_remaining", stage_parallel=True)
    if system == "cacheflow_2d":
        return dict(io_policy="longest_remaining", stage_parallel=False)
    if system == "cake":
        return dict(io_policy="fifo", stage_parallel=False)
    return dict(io_policy="fifo", stage_parallel=False)


def plans_and_kwargs(system: str, request_id: str, n_tokens: int, *, chunk_size: int,
                     l_delta: int, num_layers: int,
                     stage_bounds: Optional[List[Tuple[int, int]]] = None):
    return (make_baseline_plans(system, request_id, n_tokens, chunk_size=chunk_size,
                                l_delta=l_delta, num_layers=num_layers,
                                stage_bounds=stage_bounds),
            sim_kwargs(system))
