"""Offline profiling for the adaptive strategy crossover L_Δ (paper Fig. 3).

Two modes:
  * analytic — sweep the cost model's T_token(N) / T_layer(N) curves
    (what production deployments would tabulate per hardware SKU);
  * measured — time the real-JAX executor's token-wise vs layer-wise
    restoration on a small model (validates that the crossover exists and is
    content-agnostic; used by tests/benchmarks on CPU).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.cost_model import CostModel
from repro.core.executor import RestorationExecutor


@dataclass
class CrossoverProfile:
    lengths: List[int]
    t_token: List[float]
    t_layer: List[float]
    l_delta: int


def profile_analytic(cost: CostModel, lengths: Optional[List[int]] = None
                     ) -> CrossoverProfile:
    lengths = lengths or [2 ** i for i in range(7, 16)]
    t_tok = [cost.t_token_wise(n) for n in lengths]
    t_lay = [cost.t_layer_wise(n) for n in lengths]
    l_delta = next((n for n, tt, tl in zip(lengths, t_tok, t_lay) if tt <= tl),
                   lengths[-1])
    return CrossoverProfile(lengths, t_tok, t_lay, l_delta)


def profile_measured(executor: RestorationExecutor, make_inputs,
                     lengths: Optional[List[int]] = None, repeats: int = 2
                     ) -> CrossoverProfile:
    """Times real restoration (compute-only wall clock — I/O is a copy on CPU,
    so this measures the compute-path shapes the paper's Fig. 3 is about)."""
    lengths = lengths or [32, 64, 128, 256]
    t_tok, t_lay = [], []
    for n in lengths:
        inputs = make_inputs(n)
        rid = f"prof-{n}"
        executor.remember(rid, inputs)
        for strategy, acc in (("token", t_tok), ("layer", t_lay)):
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                cache = executor.restore(rid, strategy=strategy,
                                         op_order="compute_first")
                jax.block_until_ready(jax.tree.leaves(cache)[0])
                best = min(best, time.perf_counter() - t0)
            acc.append(best)
    l_delta = next((n for n, tt, tl in zip(lengths, t_tok, t_lay) if tt <= tl),
                   lengths[-1])
    return CrossoverProfile(lengths, t_tok, t_lay, l_delta)


def utilization_report(sim_result) -> Dict[str, float]:
    return {"compute_busy": sim_result.compute_busy, "io_busy": sim_result.io_busy}
