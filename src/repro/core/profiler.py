"""Offline profiling for the adaptive strategy crossover L_Δ (paper Fig. 3)
plus the sanitizer's observable counters.

Two profiling modes:
  * analytic — sweep the cost model's T_token(N) / T_layer(N) curves
    (what production deployments would tabulate per hardware SKU);
  * measured — time the real-JAX executor's token-wise vs layer-wise
    restoration on a small model (validates that the crossover exists and is
    content-agnostic; used by tests/benchmarks on CPU).

:class:`SanitizerCounters` is the sanitizer's (``repro.analysis.sanitizer``)
running tally — dispatch/claim/abort/preemption totals and high-water marks
— surfaced by ``launch/serve.py --sanitize`` alongside the datapath
bandwidth observable so a serving run's concurrency health is one JSON blob
away."""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.cost_model import CostModel
from repro.core.executor import RestorationExecutor


@dataclass
class CrossoverProfile:
    lengths: List[int]
    t_token: List[float]
    t_layer: List[float]
    l_delta: int


def profile_analytic(cost: CostModel, lengths: Optional[List[int]] = None
                     ) -> CrossoverProfile:
    lengths = lengths or [2 ** i for i in range(7, 16)]
    t_tok = [cost.t_token_wise(n) for n in lengths]
    t_lay = [cost.t_layer_wise(n) for n in lengths]
    l_delta = next((n for n, tt, tl in zip(lengths, t_tok, t_lay) if tt <= tl),
                   lengths[-1])
    return CrossoverProfile(lengths, t_tok, t_lay, l_delta)


def profile_measured(executor: RestorationExecutor, make_inputs,
                     lengths: Optional[List[int]] = None, repeats: int = 2
                     ) -> CrossoverProfile:
    """Times real restoration (compute-only wall clock — I/O is a copy on CPU,
    so this measures the compute-path shapes the paper's Fig. 3 is about)."""
    lengths = lengths or [32, 64, 128, 256]
    t_tok, t_lay = [], []
    for n in lengths:
        inputs = make_inputs(n)
        rid = f"prof-{n}"
        executor.remember(rid, inputs)
        for strategy, acc in (("token", t_tok), ("layer", t_lay)):
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                cache = executor.restore(rid, strategy=strategy,
                                         op_order="compute_first")
                jax.block_until_ready(jax.tree.leaves(cache)[0])
                best = min(best, time.perf_counter() - t0)
            acc.append(best)
    l_delta = next((n for n, tt, tl in zip(lengths, t_tok, t_lay) if tt <= tl),
                   lengths[-1])
    return CrossoverProfile(lengths, t_tok, t_lay, l_delta)


def utilization_report(sim_result) -> Dict[str, float]:
    return {"compute_busy": sim_result.compute_busy, "io_busy": sim_result.io_busy}


@dataclass
class SanitizerCounters:
    """What the runtime sanitizer saw during one ``EngineCore.run``.

    Pure observability (violations RAISE — a nonzero run of these counters
    is a healthy run, not a buggy one): totals per event class plus the
    high-water marks that size capacity — peak admitted batch and peak
    ``BlockPool`` block refcount (how hot the hottest shared prefix ran)."""
    events: int = 0            # engine events observed
    dispatches: int = 0        # ops placed on a resource (incl. decode steps)
    claims: int = 0            # restoration-unit claims (compute + I/O)
    completions: int = 0       # non-aborted op completions
    aborts: int = 0            # aborted transfers/ops (preempt, fail, race)
    preemptions: int = 0       # restorations suspended under pressure
    admits: int = 0            # admissions (incl. resumes)
    finishes: int = 0          # lifecycle completions
    max_active: int = 0        # admitted-batch high water
    pool_refcount_hw: int = 0  # BlockPool block refcount high water
    cow_checks: int = 0        # CoW copies verified parent-bits-unchanged
    audits: int = 0            # store/pool/placement audits executed

    def as_dict(self) -> Dict[str, int]:
        return asdict(self)
