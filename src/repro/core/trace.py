"""Schedule capture & deterministic replay.

Every engine-core run can be recorded as a :class:`ScheduleTrace` — the
complete sequence of scheduling decisions the event loop made: admissions,
marginal-benefit gate answers, dispatches (resource, op, duration,
bandwidth), completions, aborted transfers, channel failures and request
completions — plus the engine configuration and the request/plan specs
needed to rebuild the run from nothing.  Traces round-trip through JSON
losslessly (floats serialize via ``repr`` and parse back bit-equal).

Replay feeds a captured trace back through the *same* ``EngineCore`` loop
with a :class:`ReplayBackend` that pins every dispatched op's duration (and
every gate answer) to the recorded value.  Because the loop is deterministic
given durations — the event heap breaks ties by push order, the scheduler
sorts candidates on pure plan state — pinning durations reproduces the
original interleaving decision-for-decision.  The backend verifies this as
it goes: any op dispatched out of recorded order raises
:class:`ReplayDivergence` instead of silently drifting.

Replay is legal on either backend:

  * sim replay (no executor) — pure re-derivation; the resulting
    ``EngineResult`` must be bit-identical to the captured one.
  * real replay (``executor=``) — each dispatched op is *executed* on device
    through a ``RestorationExecutor`` while the engine clock follows the
    recorded durations.  Restoration ops are idempotent (loads copy ground
    truth, chunk recomputes are causal and claimed disjointly), so executing
    them under the captured interleaving — including re-executing transfers
    a channel failure aborted — restores every cache bit-exactly.

This turns the schedule into a first-class artifact: a production incident
captured from a ``SimBackend`` (or real) run can be re-executed on the real
backend to reproduce its exact interleaving.

Since schema v2 the capture covers the whole request lifecycle: suffix
prefill ops appear as regular ``dispatch`` events (op kind ``prefill``) and
every batched decode step is a ``decode_step`` event with its participant
list and pinned duration; ``finish`` marks lifecycle completion.  v1
(restoration-only) traces load by upgrade — their lifecycle extents are
zero, so replay reproduces the old restore-and-stop behavior exactly.

Schema v3 adds preemption (DESIGN.md §9): requests carry their SLO class
(``priority``/``deadline``), meta carries the ``preempt`` policy, and
``preempt``/``resume`` events mark restorations suspended under admission
pressure.  Replay does not pin those decisions — they re-derive
deterministically from the pinned durations and recorded priorities, and
the bit-identity check covers ``EngineResult.preemptions``.

Schema v5 covers continuous batching (DESIGN.md §11): meta carries the
``admission`` mode and ``prefetch`` flag, gate events carry the live
``decode_load`` the benefit was priced against, and two new capture points
pin the queued-request prefetch path — ``prefetch_gate`` events record the
tier check that decided whether a queued request's chunks were worth
promoting (the KV store is absent at replay time, so the answer must be
pinned), and prefetch transfers appear as ordinary ``dispatch`` events with
op kind ``prefetch``.  Admissions/retires were already step-granular
(``admit``/``finish`` events); v4 traces upgrade with
admission="continuous"/prefetch=False, which reproduces them exactly.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from repro.core.engine_core import (EngineBackend, EngineCore, EngineRequest,
                                    EngineResult, decode_restore_overlap)
from repro.core.plans import RequestPlan
from repro.core.scheduler import ScheduledOp

#: Schema history:
#:   1 — restoration-only traces (pre-lifecycle): no ``new_len``/
#:       ``decode_len`` on requests, no ``decode_step``/``finish`` events.
#:       Loaded by upgrading: lifecycle extents default to zero, so the
#:       replayed lifecycle collapses to RESTORING -> DONE exactly as the
#:       v1 engine behaved.
#:   2 — full request lifecycle: requests carry ``new_len``/``decode_len``;
#:       ``dispatch`` events may carry ``prefill`` ops; new ``decode_step``
#:       (batched decode, pinned duration) and ``finish`` events.
#:   3 — preemption: requests carry ``priority``/``deadline`` (omitted when
#:       default), meta carries the ``preempt`` policy, and new ``preempt``/
#:       ``resume`` events mark restorations suspended/re-admitted under
#:       admission pressure; the result carries ``preemptions``.  v2 traces
#:       load by upgrading — no priorities and preempt="none" reproduce the
#:       FCFS-only admission exactly, so replay is unchanged.
#:   4 — storage eviction mode: meta carries the engine's ``evict`` flag
#:       (preemption DROPS the partially-restored cache and resets its
#:       plans instead of parking, so the victim restarts from the KV
#:       store).  No new events — ``preempt``/``resume`` cover both modes;
#:       replay re-derives the restart from the flag.  v3 traces upgrade
#:       with evict=False (park mode), reproducing their runs exactly.
#:   5 — continuous batching: meta carries the ``admission`` mode
#:       ("continuous"/"gang") and the ``prefetch`` flag; ``gate`` events
#:       carry ``decode_load`` (live decode batch size the benefit gate
#:       priced against; omitted when 0); new ``prefetch_gate`` events pin
#:       the is-it-below-the-promote-tier answer for queued-request
#:       prefetch, and prefetch transfers are ``dispatch`` events with op
#:       kind ``prefetch``.  v4 traces upgrade with admission="continuous"
#:       and prefetch=False — no prefetch decisions were taken and
#:       decode_load never changed a recorded gate answer, so replay is
#:       unchanged.
TRACE_VERSION = 5

#: The schema version table: every event ``kind`` a trace may legally
#: contain, mapped to the schema version that introduced it.  This is the
#: single registry the tooling checks against — ``analysis/trace_lint``
#: rejects events with unknown kinds (or kinds newer than the trace's own
#: version), and ``analysis/codelint`` statically verifies that every
#: ``kind=`` a :class:`TraceRecorder` method emits is registered here.
#: Adding a recorder method without a registry entry is a lint error by
#: design: an unregistered kind would silently round-trip through JSON but
#: mean nothing to replay or to the linter.
EVENT_KINDS: Dict[str, int] = {
    "admit": 1,
    "gate": 1,
    "dispatch": 1,
    "complete": 1,
    "abort": 1,
    "fail": 1,
    "done": 1,
    "decode_step": 2,
    "finish": 2,
    "preempt": 3,
    "resume": 3,
    "prefetch_gate": 5,
}

#: Fields required on each event kind (beyond ``kind``/``t``) — the shape
#: half of schema validity.  ``dispatch`` additionally carries ``duration``;
#: gates carry their answer.  Optional fields (``bandwidth``,
#: ``decode_load``, ``batch``) are omitted when absent and not listed.
EVENT_REQUIRED_FIELDS: Dict[str, tuple] = {
    "admit": ("request_id",),
    "gate": ("request_id", "stage", "unit", "allowed"),
    "dispatch": ("resource", "op", "duration"),
    "complete": ("resource", "op"),
    "abort": ("resource", "op"),
    "fail": ("channel",),
    "done": ("request_id",),
    "decode_step": ("requests", "duration"),
    "finish": ("request_id",),
    "preempt": ("request_id",),
    "resume": ("request_id",),
    "prefetch_gate": ("request_id", "allowed"),
}


class TraceVersionError(ValueError):
    """The trace's schema version is missing or unsupported."""


class ReplayDivergence(RuntimeError):
    """Replay dispatched an op (or asked a gate question) that does not match
    the captured trace — the schedule drifted from the recording."""


# ---------------------------------------------------------------------------
# Serializable trace
# ---------------------------------------------------------------------------


@dataclass
class TraceEvent:
    """One engine-core decision.  ``kind`` ∈ {admit, gate, dispatch,
    complete, abort, fail, done, decode_step, finish, preempt, resume,
    prefetch_gate}; unused fields stay None (and are dropped from the JSON
    form).  ``done`` marks restoration complete; ``finish`` marks the whole
    lifecycle complete (slot freed); ``preempt``/``resume`` mark a
    restoration suspended under admission pressure / re-admitted to a freed
    slot; ``prefetch_gate`` pins the promote-this-queued-request decision."""
    kind: str
    t: float
    resource: Optional[str] = None       # dispatch/complete/abort: comp{s}|io{c}
    op: Optional[dict] = None            # dispatch/complete/abort
    duration: Optional[float] = None     # dispatch/decode_step: pinned secs
    bandwidth: Optional[float] = None    # dispatch (I/O): dispatch-time bytes/s
    request_id: Optional[str] = None     # admit/done/finish/gate/prefetch_gate
    stage: Optional[int] = None          # gate
    unit: Optional[int] = None           # gate
    allowed: Optional[bool] = None       # gate/prefetch_gate
    channel: Optional[int] = None        # fail
    requests: Optional[List[str]] = None  # decode_step: batched rids (sorted)
    decode_load: Optional[int] = None    # gate: live decode batch size (v5)

    def to_dict(self) -> dict:
        return {k: v for k, v in asdict(self).items() if v is not None}

    @classmethod
    def from_dict(cls, d: dict) -> "TraceEvent":
        return cls(**d)


def op_to_dict(op: ScheduledOp) -> dict:
    return {"kind": op.kind, "request_id": op.request_id, "stage": op.stage,
            "unit": op.unit, "tokens": list(op.tokens),
            "layers": list(op.layers)}


def plan_to_dict(p: RequestPlan) -> dict:
    return {"request_id": p.request_id, "n_tokens": p.n_tokens,
            "chunk_size": p.chunk_size, "strategy": p.strategy,
            "layer_lo": p.layer_lo, "layer_hi": p.layer_hi, "stage": p.stage,
            "comp_enabled": p.plan.comp_enabled,
            "io_enabled": p.plan.io_enabled}


def plan_from_dict(d: dict) -> RequestPlan:
    p = RequestPlan(d["request_id"], d["n_tokens"], d["chunk_size"],
                    d["strategy"], d["layer_lo"], d["layer_hi"],
                    stage=d["stage"])
    p.plan.comp_enabled = d["comp_enabled"]
    p.plan.io_enabled = d["io_enabled"]
    return p


def result_to_dict(res: EngineResult) -> dict:
    return {"restore_finish": dict(res.restore_finish),
            "restore_start": dict(res.restore_start),
            "first_token": dict(res.first_token),
            "finish": dict(res.finish),
            "makespan": res.makespan,
            "compute_busy": res.compute_busy,
            "io_busy": res.io_busy,
            "decode_busy": res.decode_busy,
            "decode_steps": res.decode_steps,
            "ops_log": [list(e) for e in res.ops_log],
            "preemptions": dict(res.preemptions),
            "overlap_decode_restore": res.overlap_decode_restore}


def result_from_dict(d: dict) -> EngineResult:
    # v1 results predate the lifecycle: no first token was produced and the
    # lifecycle finished at restore completion
    ops_log = [tuple(e) for e in d["ops_log"]]
    overlap = d.get("overlap_decode_restore")
    if overlap is None:
        # pre-v5 results: the overlap is a pure function of the ops log, so
        # recompute it — bit-identity against a fresh replay still holds
        overlap = decode_restore_overlap(ops_log)
    return EngineResult(
        restore_finish=dict(d["restore_finish"]),
        restore_start=dict(d["restore_start"]),
        first_token=dict(d.get("first_token") or {}),
        finish=dict(d.get("finish") if d.get("finish") is not None
                    else d["restore_finish"]),
        makespan=d["makespan"], compute_busy=d["compute_busy"],
        io_busy=d["io_busy"],
        decode_busy=d.get("decode_busy", 0.0),
        decode_steps=d.get("decode_steps", 0),
        ops_log=ops_log,
        preemptions=dict(d.get("preemptions") or {}),
        overlap_decode_restore=overlap)


@dataclass
class ScheduleTrace:
    """A complete, replayable recording of one ``EngineCore.run``."""
    meta: dict = field(default_factory=dict)       # engine config + backend name
    requests: List[dict] = field(default_factory=list)
    events: List[TraceEvent] = field(default_factory=list)
    result: Optional[dict] = None                  # result_to_dict(EngineResult)
    version: int = TRACE_VERSION

    # -- views ----------------------------------------------------------
    def dispatches(self) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == "dispatch"]

    def gates(self) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == "gate"]

    def aborts(self) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == "abort"]

    def prefills(self) -> List[TraceEvent]:
        return [e for e in self.events
                if e.kind == "dispatch" and e.op["kind"] == "prefill"]

    def decode_steps(self) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == "decode_step"]

    def captured_result(self) -> Optional[EngineResult]:
        return result_from_dict(self.result) if self.result else None

    def preempts(self) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == "preempt"]

    def resumes(self) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == "resume"]

    def prefetch_gates(self) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == "prefetch_gate"]

    def prefetches(self) -> List[TraceEvent]:
        return [e for e in self.events
                if e.kind == "dispatch" and e.op["kind"] == "prefetch"]

    def rebuild_requests(self) -> List[EngineRequest]:
        """Fresh EngineRequests (pointers at origin) from the recorded specs."""
        return [EngineRequest(r["request_id"], r["n_tokens"], r["arrival"],
                              [plan_from_dict(p) for p in r["plans"]],
                              new_len=r.get("new_len", 0),
                              decode_len=r.get("decode_len", 0),
                              priority=r.get("priority", 0),
                              deadline=r.get("deadline", math.inf))
                for r in self.requests]

    # -- serialization --------------------------------------------------
    def to_dict(self) -> dict:
        return {"version": self.version, "meta": self.meta,
                "requests": self.requests,
                "events": [e.to_dict() for e in self.events],
                "result": self.result}

    @classmethod
    def from_dict(cls, d: dict) -> "ScheduleTrace":
        version = d.get("version")
        if version is None:
            raise TraceVersionError(
                "trace has no schema version; refusing to guess its format")
        if version not in (1, 2, 3, 4, TRACE_VERSION):
            raise TraceVersionError(
                f"unsupported trace schema version {version}; this loader "
                f"reads versions 1-4 (upgraded) and {TRACE_VERSION}")
        # v1 (pre-lifecycle), v2 (pre-preemption), v3 (pre-eviction) and v4
        # (pre-continuous-batching) traces upgrade implicitly:
        # rebuild_requests and result_from_dict default the missing
        # lifecycle extents / priorities / preemption / overlap fields, and
        # missing meta keys replay as preempt="none", evict=False,
        # admission="continuous", prefetch=False — so v1 collapses to
        # RESTORING -> DONE and v2+ keep their exact recorded admission
        fail_at = d["meta"].get("channel_fail_at") or {}
        meta = dict(d["meta"])
        # JSON stringifies int dict keys; coerce them back
        meta["channel_fail_at"] = {int(k): v for k, v in fail_at.items()}
        slow = d["meta"].get("channel_slowdown") or {}
        meta["channel_slowdown"] = {int(k): v for k, v in slow.items()}
        return cls(meta=meta, requests=d["requests"],
                   events=[TraceEvent.from_dict(e) for e in d["events"]],
                   result=d.get("result"), version=TRACE_VERSION)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "ScheduleTrace":
        return cls.from_dict(json.loads(s))

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))

    @classmethod
    def load(cls, path: str) -> "ScheduleTrace":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# Capture
# ---------------------------------------------------------------------------


class TraceRecorder:
    """Collects engine-core callbacks into a :class:`ScheduleTrace`.

    Pass an instance as ``EngineCore.run(requests, trace=recorder)`` (or via
    the simulator / serving-engine facades); after the run ``recorder.trace``
    holds the finished trace."""

    def __init__(self):
        self.trace: Optional[ScheduleTrace] = None

    def begin(self, meta: dict, requests: List[EngineRequest]):
        def req_dict(r: EngineRequest) -> dict:
            d = {"request_id": r.request_id, "n_tokens": r.n_tokens,
                 "arrival": r.arrival,
                 "new_len": r.new_len, "decode_len": r.decode_len,
                 "plans": [plan_to_dict(p) for p in r.plans]}
            if r.priority:
                d["priority"] = r.priority
            if math.isfinite(r.deadline):    # inf is not strict JSON
                d["deadline"] = r.deadline
            return d

        self.trace = ScheduleTrace(meta=meta,
                                   requests=[req_dict(r) for r in requests])

    def _ev(self, **kw):
        self.trace.events.append(TraceEvent(**kw))

    def record_admit(self, t: float, rid: str):
        self._ev(kind="admit", t=t, request_id=rid)

    def record_gate(self, t: float, rid: str, stage: int, unit: int,
                    allowed: bool, decode_load: int = 0):
        self._ev(kind="gate", t=t, request_id=rid, stage=stage, unit=unit,
                 allowed=allowed,
                 decode_load=decode_load if decode_load else None)

    def record_prefetch_gate(self, t: float, rid: str, allowed: bool):
        self._ev(kind="prefetch_gate", t=t, request_id=rid, allowed=allowed)

    def record_dispatch(self, t: float, resource: str, op: ScheduledOp,
                        duration: float, bandwidth: Optional[float]):
        self._ev(kind="dispatch", t=t, resource=resource, op=op_to_dict(op),
                 duration=duration, bandwidth=bandwidth)

    def record_complete(self, t: float, resource: str, op: ScheduledOp):
        self._ev(kind="complete", t=t, resource=resource, op=op_to_dict(op))

    def record_abort(self, t: float, resource: str, op: ScheduledOp):
        self._ev(kind="abort", t=t, resource=resource, op=op_to_dict(op))

    def record_fail(self, t: float, channel: int):
        self._ev(kind="fail", t=t, channel=channel)

    def record_done(self, t: float, rid: str):
        self._ev(kind="done", t=t, request_id=rid)

    def record_decode(self, t: float, rids: List[str], duration: float):
        self._ev(kind="decode_step", t=t, requests=list(rids),
                 duration=duration)

    def record_finish(self, t: float, rid: str):
        self._ev(kind="finish", t=t, request_id=rid)

    def record_preempt(self, t: float, rid: str):
        self._ev(kind="preempt", t=t, request_id=rid)

    def record_resume(self, t: float, rid: str):
        self._ev(kind="resume", t=t, request_id=rid)

    def finish(self, result: EngineResult):
        self.trace.result = result_to_dict(result)


def capture(core: EngineCore, requests: List[EngineRequest]
            ) -> "tuple[EngineResult, ScheduleTrace]":
    """Run ``core`` over ``requests`` while recording; returns both the
    result and the finished trace."""
    rec = TraceRecorder()
    res = core.run(requests, trace=rec)
    return res, rec.trace


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


class ReplayBackend(EngineBackend):
    """Re-executes a captured trace with pinned durations.

    Every ``compute_secs``/``io_secs`` call consumes the next recorded
    dispatch (validating op identity) and returns its recorded duration;
    every ``io_benefit`` call consumes the next recorded gate answer.  With
    an ``executor`` the dispatched ops additionally run on device (real
    replay); without one the replay is purely analytic (sim replay).
    """

    def __init__(self, trace: ScheduleTrace, executor=None, *,
                 verify: bool = False):
        self.trace = trace
        self.executor = executor
        self.verify = verify
        self._dispatches = trace.dispatches()
        self._gates = trace.gates()
        self._decodes = trace.decode_steps()
        self._pgates = trace.prefetch_gates()
        self._di = 0
        self._gi = 0
        self._dci = 0
        self._pgi = 0

    # -- helpers --------------------------------------------------------
    def _pop_dispatch(self, op: ScheduledOp, execute: bool = True) -> float:
        if self._di >= len(self._dispatches):
            raise ReplayDivergence(
                f"replay dispatched {op} past the end of the trace "
                f"({len(self._dispatches)} recorded dispatches)")
        e = self._dispatches[self._di]
        self._di += 1
        rec = e.op
        got = (op.kind, op.request_id, op.stage, op.unit)
        want = (rec["kind"], rec["request_id"], rec["stage"], rec["unit"])
        if got != want:
            raise ReplayDivergence(
                f"replay dispatch #{self._di - 1} diverged: engine issued "
                f"{got}, trace recorded {want}")
        if self.executor is not None and execute:
            self.executor.execute_op(op)
        return e.duration

    # -- EngineBackend --------------------------------------------------
    def admit(self, req: EngineRequest) -> None:
        if self.executor is not None:
            self.executor.begin_restore(req.request_id, plans=req.plans)

    def compute_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        return self._pop_dispatch(op)

    def io_secs(self, op: ScheduledOp, req: EngineRequest,
                bandwidth: Optional[float]) -> float:
        return self._pop_dispatch(op)

    def io_secs_partial(self, op: ScheduledOp, req: EngineRequest,
                        bandwidth: Optional[float], missing: float) -> float:
        # recorded durations already priced the missing fraction at capture
        # time — replay pins them verbatim, no re-scaling
        return self._pop_dispatch(op)

    def prefill_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        return self._pop_dispatch(op)

    def prefetch_secs(self, op: ScheduledOp, req: EngineRequest,
                      bandwidth: Optional[float]) -> float:
        # tier promotion happens inside the KV store, which is absent at
        # replay time — pin the duration but execute nothing on device
        return self._pop_dispatch(op, execute=False)

    def prefetch_gate(self, req: EngineRequest) -> bool:
        if self._pgi >= len(self._pgates):
            raise ReplayDivergence(
                f"replay prefetch-gate query ({req.request_id}) past the "
                f"end of the trace ({len(self._pgates)} recorded)")
        e = self._pgates[self._pgi]
        self._pgi += 1
        if e.request_id != req.request_id:
            raise ReplayDivergence(
                f"replay prefetch gate #{self._pgi - 1} diverged: engine "
                f"asked about {req.request_id}, trace recorded "
                f"{e.request_id}")
        return e.allowed

    def decode_secs(self, reqs: List[EngineRequest]) -> float:
        rids = [r.request_id for r in reqs]
        if self._dci >= len(self._decodes):
            raise ReplayDivergence(
                f"replay issued a decode step over {rids} past the end of "
                f"the trace ({len(self._decodes)} recorded decode steps)")
        e = self._decodes[self._dci]
        self._dci += 1
        if e.requests != rids:
            raise ReplayDivergence(
                f"replay decode step #{self._dci - 1} diverged: engine "
                f"batched {rids}, trace recorded {e.requests}")
        if self.executor is not None:
            self.executor.decode_step_batch(rids)
        return e.duration

    def suspend(self, req: EngineRequest) -> None:
        # real replay must park/unpark exactly as the capture did so that
        # re-executed (previously aborted) ops see a live, unparked cache
        if self.executor is not None:
            self.executor.suspend_restore(req.request_id)

    def evict(self, req: EngineRequest) -> None:
        # eviction-mode capture: the victim's live state was dropped; the
        # replayed restart re-executes every unit onto a fresh cache
        if self.executor is not None:
            self.executor.drop_restore(req.request_id)

    def resume(self, req: EngineRequest) -> None:
        if self.executor is not None:
            if self.executor.is_live(req.request_id):
                self.executor.resume_restore(req.request_id)
            else:
                self.executor.begin_restore(req.request_id, plans=req.plans)

    def io_benefit(self, plan: RequestPlan, unit: int,
                   bandwidth: Optional[float], slowdown: float = 1.0,
                   decode_load: int = 0) -> bool:
        if self._gi >= len(self._gates):
            raise ReplayDivergence(
                f"replay gate query ({plan.request_id}, stage {plan.stage}, "
                f"unit {unit}) past the end of the trace")
        e = self._gates[self._gi]
        self._gi += 1
        if (e.request_id, e.stage, e.unit) != (plan.request_id, plan.stage,
                                               unit):
            raise ReplayDivergence(
                f"replay gate #{self._gi - 1} diverged: engine asked about "
                f"({plan.request_id}, {plan.stage}, {unit}), trace recorded "
                f"({e.request_id}, {e.stage}, {e.unit})")
        return e.allowed

    def restore_done(self, req: EngineRequest) -> None:
        if self.executor is not None:
            self.executor.finalize_restore(req.request_id)
            if self.verify:
                self.executor.verify(req.request_id)

    # -- post-run check -------------------------------------------------
    def assert_exhausted(self):
        """Every recorded decision must have been replayed."""
        if self._di != len(self._dispatches):
            raise ReplayDivergence(
                f"replay consumed {self._di}/{len(self._dispatches)} "
                f"recorded dispatches")
        if self._gi != len(self._gates):
            raise ReplayDivergence(
                f"replay consumed {self._gi}/{len(self._gates)} "
                f"recorded gate answers")
        if self._dci != len(self._decodes):
            raise ReplayDivergence(
                f"replay consumed {self._dci}/{len(self._decodes)} "
                f"recorded decode steps")
        if self._pgi != len(self._pgates):
            raise ReplayDivergence(
                f"replay consumed {self._pgi}/{len(self._pgates)} "
                f"recorded prefetch-gate answers")


def replay_core(trace: ScheduleTrace, backend: EngineBackend,
                *, strict: bool = True) -> EngineCore:
    """EngineCore configured exactly as the captured run — except channel
    slowdowns, which are already folded into the recorded durations, and the
    KV store, whose bandwidths/gates were recorded at capture time."""
    m = trace.meta
    return EngineCore(
        backend, stages=m["stages"], io_channels=m["io_channels"],
        io_policy=m["io_policy"],
        channel_fail_at=dict(m.get("channel_fail_at") or {}),
        stage_parallel=m["stage_parallel"], max_active=m["max_active"],
        preempt=m.get("preempt", "none"), evict=m.get("evict", False),
        admission=m.get("admission", "continuous"),
        prefetch=m.get("prefetch", False),
        strict=strict)


def replay_trace(trace: ScheduleTrace, executor=None, *, verify: bool = False,
                 strict: bool = True, trace_out: Optional[TraceRecorder] = None
                 ) -> EngineResult:
    """Re-run a captured schedule decision-for-decision.

    Without ``executor``: sim replay; the returned ``EngineResult`` is
    bit-identical to ``trace.captured_result()``.  With ``executor``: each
    dispatched op executes on device under the recorded interleaving
    (``verify=True`` additionally checks every restored cache against its
    full-prefill ground truth).  Raises :class:`ReplayDivergence` if the
    re-derived schedule ever departs from the recording.
    """
    backend = ReplayBackend(trace, executor, verify=verify)
    core = replay_core(trace, backend, strict=strict)
    res = core.run(trace.rebuild_requests(), trace=trace_out)
    backend.assert_exhausted()
    return res
