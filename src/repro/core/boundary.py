"""Boundary-activation store — the 3rd dimension of CacheFlow (paper §3.2).

At original prefill time each pipeline stage persists the *input activations*
of its first layer for the prefix tokens (size n × d_model — far smaller than
the stage's KV slice: 2·H·Dh·(L/S)·n).  On restoration every stage fetches
its boundary row and reconstructs its local KV concurrently — no
inter-stage dependency.

For recurrent/hybrid archs the store additionally keeps end-of-chunk
recurrent-state snapshots (RG-LRU h/conv, RWKV wkv/shift): the state analogue
of boundary activations along the *token* axis (DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np


def _nbytes(tree) -> int:
    return sum(int(np.asarray(a).size) * np.asarray(a).dtype.itemsize
               for a in jax.tree.leaves(tree))


@dataclass
class StoredRequest:
    request_id: str
    n_tokens: int
    inputs: object                       # tokens (B,N) or embeddings (B,N,D)
    kv_reference: dict                   # full-prefill cache (ground truth / KV store payload)
    boundaries: Dict[int, object]        # stage -> (B, N, D) input activations
    state_snapshots: Dict[Tuple[int, int], dict] = field(default_factory=dict)
    # (stage, chunk_idx) -> recurrent-state pytree at the END of that chunk
    final_logits: Optional[object] = None


class BoundaryStore:
    """In-memory stand-in for the storage tier holding boundary activations,
    KV payloads and state snapshots. Byte counters feed the cost model."""

    def __init__(self):
        self._store: Dict[str, StoredRequest] = {}
        self.bytes_written = 0
        self.bytes_read = 0

    def put(self, req: StoredRequest):
        self._store[req.request_id] = req
        self.bytes_written += _nbytes(req.kv_reference)
        self.bytes_written += _nbytes(list(req.boundaries.values()))

    def get(self, rid: str) -> StoredRequest:
        req = self._store[rid]
        return req

    def read_boundary(self, rid: str, stage: int):
        b = self._store[rid].boundaries[stage]
        self.bytes_read += _nbytes(b)
        return b

    def boundary_bytes(self, rid: str, stage: int) -> int:
        return _nbytes(self._store[rid].boundaries[stage])

    def kv_slice_bytes(self, rid: str, tokens: Tuple[int, int],
                       layer_frac: float) -> int:
        req = self._store[rid]
        total = _nbytes(req.kv_reference)
        t0, t1 = tokens
        return int(total * (t1 - t0) / max(1, req.n_tokens) * layer_frac)

    def fork(self, src_rid: str, dst_rid: str) -> StoredRequest:
        """Alias ``src``'s stored request under ``dst`` — the fork shares
        every array (inputs, KV reference, boundaries, snapshots) and
        writes ZERO bytes; only the id differs."""
        req = self._store[src_rid]
        clone = replace(req, request_id=dst_rid)
        self._store[dst_rid] = clone
        return clone

    def __contains__(self, rid: str) -> bool:
        return rid in self._store


def stage_bounds(num_layers: int, stages: int) -> List[Tuple[int, int]]:
    """Contiguous layer partition [ℓ_s^start, ℓ_s^end) per stage."""
    base = num_layers // stages
    rem = num_layers % stages
    bounds = []
    lo = 0
    for s in range(stages):
        hi = lo + base + (1 if s < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds
