"""CacheFlow core: the paper's contribution as a composable library.

  cost_model — T_comp/T_io models, harmonic-mean bound (Eq. 1), Eq. 2, L_Δ
  plans      — token-/layer-wise two-pointer claim machines
  scheduler  — batch-aware 3D scheduler (Algorithm 1)
  boundary   — boundary-activation store (3rd dimension, §3.2)
  engine_core— backend-agnostic batched event loop (admission, resources,
               I/O channels, failures, KV-store tiers) with Sim/Real backends
  simulator  — discrete-event facade over the engine core (Fig. 5)
  executor   — real-JAX restoration with bit-exact verification
  trace      — schedule capture (ScheduleTrace) + deterministic replay
               (ReplayBackend) sim↔real
  baselines  — vLLM / LMCache / SGLang / Cake comparators
  profiler   — offline L_Δ crossover profiling (Fig. 3)
"""
from repro.core.cost_model import CostModel  # noqa: F401
from repro.core.plans import RequestPlan, TwoPointerPlan, make_request_plans  # noqa: F401
from repro.core.scheduler import BatchScheduler, ScheduledOp  # noqa: F401
from repro.core.boundary import BoundaryStore, StoredRequest, stage_bounds  # noqa: F401
from repro.core.engine_core import (EngineBackend, EngineCore, EngineRequest,  # noqa: F401
                                    EngineResult, RealBackend, SimBackend,
                                    interleaving_dur_fn)
from repro.core.simulator import RestorationSimulator, SimRequest, SimResult  # noqa: F401
from repro.core.executor import RestorationExecutor  # noqa: F401
from repro.core.trace import (ReplayBackend, ReplayDivergence, ScheduleTrace,  # noqa: F401
                              TraceEvent, TraceRecorder, TraceVersionError,
                              capture, replay_trace)
