"""Batch-aware 3D two-pointer scheduler (paper Algorithm 1), phase-aware.

The scheduler owns the plan state of every active request (per stage) and
answers two questions whenever a resource frees up:

  * ``next_io(stage/channel)``      — which request's I/O pointer advances?
    CacheFlow policy: the request with the LARGEST remaining restoration
    length (highest marginal recomputation saving, §3.3). Baselines: fifo /
    round-robin / shortest-first for the ablations.
  * ``next_compute(stage)``         — which request's compute pointer
    advances? Compute is batched round-robin (every request makes progress,
    Algorithm 1 line 10).

Beyond restoration, the scheduler generates *lifecycle* candidates: once a
request finishes restoring, ``begin_prefill`` registers its suffix-prefill
pipeline (one op per stage, in stage order — the forward pass threads the
pipeline), and ``next_compute`` arbitrates FCFS between restoration chunks
and prefill ops on the same stage compute resource. Batched decode runs on
its own resource and is driven by the engine core directly.

It is deliberately execution-agnostic: the discrete-event simulator and the
real-JAX executor both drive it, so the *same* scheduling decisions are
measured for performance and checked for correctness.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.plans import RequestPlan, TwoPointerPlan

IO_POLICIES = ("longest_remaining", "fifo", "shortest_remaining", "round_robin")


@dataclass
class ScheduledOp:
    kind: str            # "compute" | "load" | "prefill" | "decode"
    request_id: str
    stage: int
    unit: int
    tokens: Tuple[int, int]
    layers: Tuple[int, int]
    # decode steps only: the FULL participant list (arrival order), so
    # synthetic duration functions / per-op hooks see the true batch
    # composition instead of a fabricated batch of one (request_id is the
    # first participant for backward compatibility)
    batch: Tuple[str, ...] = ()


@dataclass
class PrefillPipeline:
    """Suffix-prefill state for one restored request: one op per pipeline
    stage, executed in stage order (stage s consumes stage s-1's boundary
    activations of the *suffix*, so the ops are sequentially dependent)."""
    tokens: Tuple[int, int]                 # (n_prefix, n_prefix + new_len)
    stages: List[Tuple[int, int, int]]      # (stage, layer_lo, layer_hi) asc
    next_idx: int = 0
    inflight: bool = False


@dataclass
class BatchScheduler:
    io_policy: str = "longest_remaining"
    # marginal-benefit gate (§3.3): only spend I/O on a unit if loading it
    # avoids more recomputation time than the transfer costs. None = eager.
    benefit_fn: object = None      # Callable[[RequestPlan, int], bool]
    plans: Dict[Tuple[str, int], RequestPlan] = field(default_factory=dict)
    # O(1) indexes so dispatch stays near O(B log B) at large batch sizes:
    # arrival sequence number per request (sort key + membership), plans
    # bucketed by stage (compute dispatch) and by request (request_done).
    arrival_index: Dict[str, int] = field(default_factory=dict)
    # SLO class per request (continuous arrivals): a higher-priority /
    # tighter-deadline request's transfers jump a congested channel queue —
    # its urgency leads the longest_remaining dispatch key.  Defaults
    # (priority 0, no deadline) reproduce the classic ordering exactly.
    priority: Dict[str, int] = field(default_factory=dict)
    deadline: Dict[str, float] = field(default_factory=dict)
    # requests preempted mid-restoration: claims released, no candidates
    # generated until resume() (engine-core preemption policy drives this)
    suspended: set = field(default_factory=set)
    _by_stage: Dict[int, "Dict[str, RequestPlan]"] = field(default_factory=dict)
    _by_rid: Dict[str, List[RequestPlan]] = field(default_factory=dict)
    _arrival_seq: int = 0
    _rr_io: int = 0
    _rr_comp: Dict[int, int] = field(default_factory=dict)
    # lifecycle state: suffix-prefill pipelines of requests CURRENTLY in the
    # prefill phase (pruned on completion so candidate scans stay bounded by
    # the in-phase population, not the whole batch)
    _prefill: Dict[str, PrefillPipeline] = field(default_factory=dict)
    _prefill_finished: set = field(default_factory=set)
    # O(log B) restoration-head index (ROADMAP open item): a lazy min-heap of
    # (arrival seq, rid) with fully-restored requests skipped on peek, so
    # ``next_io`` no longer rescans arrival_order × stages per dispatch.
    _head_heap: List[Tuple[int, str]] = field(default_factory=list)
    _restored: set = field(default_factory=set)

    # ------------------------------------------------------------------
    def add_request(self, plans: List[RequestPlan], *, priority: int = 0,
                    deadline: float = math.inf):
        rid = plans[0].request_id
        if rid not in self.arrival_index:
            self.arrival_index[rid] = self._arrival_seq
            heapq.heappush(self._head_heap, (self._arrival_seq, rid))
            self._arrival_seq += 1
        if priority:
            self.priority[rid] = priority
        if math.isfinite(deadline):
            self.deadline[rid] = deadline
        self._by_rid[rid] = list(plans)
        for p in plans:
            self.plans[(rid, p.stage)] = p
            self._by_stage.setdefault(p.stage, {})[rid] = p

    def remove_request(self, rid: str):
        # O(stages): every index is a dict/set keyed by rid (the head heap
        # drops its entry lazily on peek)
        self.arrival_index.pop(rid, None)
        self.priority.pop(rid, None)
        self.deadline.pop(rid, None)
        self._restored.discard(rid)
        self.suspended.discard(rid)
        self._prefill.pop(rid, None)
        self._prefill_finished.discard(rid)
        for p in self._by_rid.pop(rid, []):
            self.plans.pop((rid, p.stage), None)
            self._by_stage.get(p.stage, {}).pop(rid, None)

    # ------------------------------------------------------------------
    def _stage_plans(self, stage: int) -> List[RequestPlan]:
        return list(self._by_stage.get(stage, {}).values())

    def stages(self) -> List[int]:
        return sorted(s for s, d in self._by_stage.items() if d)

    def request_done(self, rid: str) -> bool:
        """All stage plans restored (restoration phase complete)."""
        if rid in self._restored:
            return True
        ps = self._by_rid.get(rid, ())
        return bool(ps) and all(p.plan.done for p in ps)

    def all_done(self) -> bool:
        return all(p.plan.done for p in self.plans.values())

    def remaining_restoration(self, rid: str) -> int:
        """Tokens' worth of KV still to restore across every stage plan —
        the request's remaining marginal recompute saving (§3.3).  The
        engine's preemption policy suspends the active request where this is
        SMALLEST (the dual of the largest-remaining dispatch key)."""
        return sum(p.remaining_io_tokens() for p in self._by_rid.get(rid, ()))

    # ------------------------------------------------------------------
    # Preempt / resume (engine-core admission pressure)
    # ------------------------------------------------------------------
    def preempt(self, rid: str, reset: bool = False):
        """Suspend a restoring request: release BOTH pointers' claims on
        every stage plan (the released units become claimable again — the
        plan state machine makes re-execution idempotent) and stop
        generating candidates for it until :meth:`resume`.  Completed units
        are untouched, so resumption continues rather than restarts —
        unless ``reset=True`` (engine-core EVICTION mode): every stage plan
        is rebuilt at its origin, because the partially-restored cache was
        dropped and restoration must restart from the KV store."""
        self.suspended.add(rid)
        for p in self._by_rid.get(rid, ()):
            if reset:
                p.plan = TwoPointerPlan(p.plan.n_units,
                                        comp_enabled=p.plan.comp_enabled,
                                        io_enabled=p.plan.io_enabled)
            else:
                p.plan.release_claims()

    def resume(self, rid: str):
        """Re-admit a suspended request: it competes for resources again
        from exactly the plan state it was suspended with."""
        self.suspended.discard(rid)
        if rid in self.arrival_index and rid not in self._restored:
            # the head heap may have lazily dropped its entry while it was
            # suspended; re-push (duplicates are harmless — lazy skip)
            heapq.heappush(self._head_heap, (self.arrival_index[rid], rid))

    def _restoration_head(self) -> Optional[str]:
        """Oldest admitted request still restoring — O(log B) amortized via
        the lazy heap (entries for restored/removed/suspended requests drop
        on peek; ``resume`` re-pushes its entry)."""
        h = self._head_heap
        while h and (h[0][1] in self._restored
                     or h[0][1] not in self.arrival_index
                     or h[0][1] in self.suspended):
            heapq.heappop(h)
        return h[0][1] if h else None

    # ------------------------------------------------------------------
    # Lifecycle: suffix prefill (phase-aware candidate generation)
    # ------------------------------------------------------------------
    def begin_prefill(self, rid: str, n_tokens: int, new_len: int):
        """Register the restored request's suffix-prefill pipeline: one op
        per stage over tokens [n_tokens, n_tokens + new_len), in stage
        order, competing FCFS with restoration chunks in next_compute."""
        plans = sorted(self._by_rid[rid], key=lambda p: p.stage)
        self._prefill[rid] = PrefillPipeline(
            (n_tokens, n_tokens + new_len),
            [(p.stage, p.layer_lo, p.layer_hi) for p in plans])

    def prefill_done(self, rid: str) -> bool:
        return rid in self._prefill_finished

    def _prefill_candidate(self, stage: int, skip) -> Optional[str]:
        best = None
        for rid, st in self._prefill.items():
            if st.inflight or rid in self.suspended:
                continue
            if st.stages[st.next_idx][0] != stage or (rid, stage) in skip:
                continue
            if best is None or self.arrival_index[rid] < self.arrival_index[best]:
                best = rid
        return best

    def _claim_prefill(self, rid: str) -> ScheduledOp:
        st = self._prefill[rid]
        s, lo, hi = st.stages[st.next_idx]
        st.inflight = True
        return ScheduledOp("prefill", rid, s, st.next_idx, st.tokens, (lo, hi))

    # ------------------------------------------------------------------
    # Algorithm 1 line 6: I/O channel assignment
    # ------------------------------------------------------------------
    def next_io(self, stage: Optional[int] = None,
                skip: "frozenset[Tuple[str, int]]" = frozenset()
                ) -> Optional[ScheduledOp]:
        """``skip``: (request_id, stage) pairs the caller already found
        stage-blocked this dispatch round — excluded so their claims are not
        immediately re-taken."""
        cands = [p for p in self.plans.values()
                 if (stage is None or p.stage == stage)
                 and (p.request_id, p.stage) not in skip
                 and p.request_id not in self.suspended]
        cands = [p for p in cands
                 if p.plan.io_enabled
                 and not p.plan.done and p.plan.io_inflight is None
                 and p.plan.io_next >= p.plan.comp_next
                 and not (p.plan.comp_inflight is not None
                          and p.plan.io_next <= p.plan.comp_inflight)]
        if not cands:
            return None
        if self.io_policy == "longest_remaining":
            # Batch-aware two-pointer priority (§3.3), operationalised for
            # FCFS chunked-prefill compute: (0) a strictly more urgent SLO
            # class (higher priority, then earlier first-token deadline)
            # jumps the channel queue — under continuous arrivals a
            # deadline-tight request must not wait behind a bulk request's
            # long restoration; then (1) the compute-head request's
            # transfers are on the TTFT critical path — serve them first;
            # (2) surplus channel time prefetches the request with the
            # largest remaining restoration (highest marginal recompute
            # saving under quadratic attention), which is what shrinks the
            # tail (paper Fig. 4 P90–P99).
            head = self._restoration_head()
            cands.sort(key=lambda p: (-self.priority.get(p.request_id, 0),
                                      self.deadline.get(p.request_id, math.inf),
                                      p.request_id != head,
                                      -p.remaining_io_tokens(),
                                      self.arrival_index[p.request_id]))
        elif self.io_policy == "shortest_remaining":
            cands.sort(key=lambda p: (p.remaining_io_tokens(),
                                      self.arrival_index[p.request_id]))
        elif self.io_policy == "fifo":
            cands.sort(key=lambda p: self.arrival_index[p.request_id])
        elif self.io_policy == "round_robin":
            self._rr_io += 1
            cands = cands[self._rr_io % len(cands):] + cands[:self._rr_io % len(cands)]
        for p in cands:
            if self.benefit_fn is not None and not self.benefit_fn(p, p.plan.io_next):
                continue
            unit = p.plan.claim_io()
            if unit is None:
                continue
            tokens, layers = p.io_unit_for_claim(unit)
            return ScheduledOp("load", p.request_id, p.stage, unit, tokens, layers)
        return None

    # ------------------------------------------------------------------
    # Algorithm 1 line 10: compute assignment. FCFS by default — chunked
    # prefill of the oldest unfinished request, matching continuous-batching
    # engines (round-robin / processor-sharing inflates mean TTFT).
    # ------------------------------------------------------------------
    compute_policy: str = "fifo"

    def next_compute(self, stage: int = 0,
                     skip: "frozenset[Tuple[str, int]]" = frozenset()
                     ) -> Optional[ScheduledOp]:
        plans = [p for p in self._stage_plans(stage)
                 if (p.request_id, p.stage) not in skip
                 and p.request_id not in self.suspended
                 and p.plan.comp_enabled
                 and not p.plan.done and p.plan.comp_inflight is None
                 and p.plan.comp_next <= p.plan.io_next
                 and not (p.plan.io_inflight is not None
                          and p.plan.comp_next >= p.plan.io_inflight)]
        prefill = self._prefill_candidate(stage, skip)
        if not plans:
            return self._claim_prefill(prefill) if prefill is not None else None
        plans.sort(key=lambda p: self.arrival_index[p.request_id])
        if self.compute_policy == "round_robin":
            p = plans[self._rr_comp.get(stage, 0) % len(plans)]
        else:
            p = plans[0]
        # phase-aware FCFS: a restored request's suffix prefill competes with
        # other requests' restoration chunks on this stage's compute resource
        if prefill is not None and \
                self.arrival_index[prefill] < self.arrival_index[p.request_id]:
            return self._claim_prefill(prefill)
        if self.compute_policy == "round_robin":
            # rotate only when the restoration plan actually gets the slot
            self._rr_comp[stage] = self._rr_comp.get(stage, 0) + 1
        unit = p.plan.claim_compute()
        if unit is None:
            return None
        if p.strategy == "token":
            tokens = p.unit_tokens(unit)
            layers = (p.layer_lo, p.layer_hi)
        else:
            tokens = (0, p.n_tokens)
            layers = p.unit_layers(unit)
        return ScheduledOp("compute", p.request_id, p.stage, unit, tokens, layers)

    # ------------------------------------------------------------------
    def complete(self, op: ScheduledOp) -> Optional[str]:
        """Advance the op's pointer.  Returns the request id iff THIS
        completion finished the request's restoration (all stage plans
        done) — the engine transitions exactly that request's phase instead
        of rescanning the whole active batch per event."""
        if op.kind == "prefill":
            st = self._prefill[op.request_id]
            st.inflight = False
            st.next_idx += 1
            if st.next_idx >= len(st.stages):
                # pipeline finished: prune so it stops costing candidate scans
                del self._prefill[op.request_id]
                self._prefill_finished.add(op.request_id)
            return None
        p = self.plans[(op.request_id, op.stage)]
        if op.kind == "compute":
            p.plan.complete_compute(op.unit)
        else:
            p.plan.complete_io(op.unit)
        # keep the restoration-head index current (O(stages), once per op)
        if p.plan.done and op.request_id not in self._restored \
                and all(q.plan.done for q in self._by_rid[op.request_id]):
            self._restored.add(op.request_id)
            return op.request_id
        return None
