"""Batch-aware 3D two-pointer scheduler (paper Algorithm 1).

The scheduler owns the plan state of every active request (per stage) and
answers two questions whenever a resource frees up:

  * ``next_io(stage/channel)``      — which request's I/O pointer advances?
    CacheFlow policy: the request with the LARGEST remaining restoration
    length (highest marginal recomputation saving, §3.3). Baselines: fifo /
    round-robin / shortest-first for the ablations.
  * ``next_compute(stage)``         — which request's compute pointer
    advances? Compute is batched round-robin (every request makes progress,
    Algorithm 1 line 10).

It is deliberately execution-agnostic: the discrete-event simulator and the
real-JAX executor both drive it, so the *same* scheduling decisions are
measured for performance and checked for correctness.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.plans import RequestPlan

IO_POLICIES = ("longest_remaining", "fifo", "shortest_remaining", "round_robin")


@dataclass
class ScheduledOp:
    kind: str            # "compute" | "load"
    request_id: str
    stage: int
    unit: int
    tokens: Tuple[int, int]
    layers: Tuple[int, int]


@dataclass
class BatchScheduler:
    io_policy: str = "longest_remaining"
    # marginal-benefit gate (§3.3): only spend I/O on a unit if loading it
    # avoids more recomputation time than the transfer costs. None = eager.
    benefit_fn: object = None      # Callable[[RequestPlan, int], bool]
    plans: Dict[Tuple[str, int], RequestPlan] = field(default_factory=dict)
    arrival_order: List[str] = field(default_factory=list)
    # O(1) indexes so dispatch stays near O(B log B) at large batch sizes:
    # arrival sequence number per request (sort key), plans bucketed by
    # stage (compute dispatch) and by request (request_done).
    arrival_index: Dict[str, int] = field(default_factory=dict)
    _by_stage: Dict[int, "Dict[str, RequestPlan]"] = field(default_factory=dict)
    _by_rid: Dict[str, List[RequestPlan]] = field(default_factory=dict)
    _arrival_seq: int = 0
    _rr_io: int = 0
    _rr_comp: Dict[int, int] = field(default_factory=dict)

    # ------------------------------------------------------------------
    def add_request(self, plans: List[RequestPlan]):
        rid = plans[0].request_id
        if rid not in self.arrival_index:
            self.arrival_order.append(rid)
            self.arrival_index[rid] = self._arrival_seq
            self._arrival_seq += 1
        self._by_rid[rid] = list(plans)
        for p in plans:
            self.plans[(rid, p.stage)] = p
            self._by_stage.setdefault(p.stage, {})[rid] = p

    def remove_request(self, rid: str):
        self.arrival_order = [r for r in self.arrival_order if r != rid]
        self.arrival_index.pop(rid, None)
        for p in self._by_rid.pop(rid, []):
            self.plans.pop((rid, p.stage), None)
            self._by_stage.get(p.stage, {}).pop(rid, None)

    # ------------------------------------------------------------------
    def _stage_plans(self, stage: int) -> List[RequestPlan]:
        return list(self._by_stage.get(stage, {}).values())

    def stages(self) -> List[int]:
        return sorted(s for s, d in self._by_stage.items() if d)

    def request_done(self, rid: str) -> bool:
        ps = self._by_rid.get(rid, ())
        return bool(ps) and all(p.plan.done for p in ps)

    def all_done(self) -> bool:
        return all(p.plan.done for p in self.plans.values())

    # ------------------------------------------------------------------
    # Algorithm 1 line 6: I/O channel assignment
    # ------------------------------------------------------------------
    def next_io(self, stage: Optional[int] = None,
                skip: "frozenset[Tuple[str, int]]" = frozenset()
                ) -> Optional[ScheduledOp]:
        """``skip``: (request_id, stage) pairs the caller already found
        stage-blocked this dispatch round — excluded so their claims are not
        immediately re-taken."""
        cands = [p for p in self.plans.values()
                 if (stage is None or p.stage == stage)
                 and (p.request_id, p.stage) not in skip]
        cands = [p for p in cands
                 if p.plan.io_enabled
                 and not p.plan.done and p.plan.io_inflight is None
                 and p.plan.io_next >= p.plan.comp_next
                 and not (p.plan.comp_inflight is not None
                          and p.plan.io_next <= p.plan.comp_inflight)]
        if not cands:
            return None
        if self.io_policy == "longest_remaining":
            # Batch-aware two-pointer priority (§3.3), operationalised for
            # FCFS chunked-prefill compute: (1) the compute-head request's
            # transfers are on the TTFT critical path — serve them first;
            # (2) surplus channel time prefetches the request with the
            # largest remaining restoration (highest marginal recompute
            # saving under quadratic attention), which is what shrinks the
            # tail (paper Fig. 4 P90–P99).
            head = next((r for r in self.arrival_order
                         if not self.request_done(r)), None)
            cands.sort(key=lambda p: (p.request_id != head,
                                      -p.remaining_io_tokens(),
                                      self.arrival_index[p.request_id]))
        elif self.io_policy == "shortest_remaining":
            cands.sort(key=lambda p: (p.remaining_io_tokens(),
                                      self.arrival_index[p.request_id]))
        elif self.io_policy == "fifo":
            cands.sort(key=lambda p: self.arrival_index[p.request_id])
        elif self.io_policy == "round_robin":
            self._rr_io += 1
            cands = cands[self._rr_io % len(cands):] + cands[:self._rr_io % len(cands)]
        for p in cands:
            if self.benefit_fn is not None and not self.benefit_fn(p, p.plan.io_next):
                continue
            unit = p.plan.claim_io()
            if unit is None:
                continue
            tokens, layers = p.io_unit_for_claim(unit)
            return ScheduledOp("load", p.request_id, p.stage, unit, tokens, layers)
        return None

    # ------------------------------------------------------------------
    # Algorithm 1 line 10: compute assignment. FCFS by default — chunked
    # prefill of the oldest unfinished request, matching continuous-batching
    # engines (round-robin / processor-sharing inflates mean TTFT).
    # ------------------------------------------------------------------
    compute_policy: str = "fifo"

    def next_compute(self, stage: int = 0,
                     skip: "frozenset[Tuple[str, int]]" = frozenset()
                     ) -> Optional[ScheduledOp]:
        plans = [p for p in self._stage_plans(stage)
                 if (p.request_id, p.stage) not in skip
                 and p.plan.comp_enabled
                 and not p.plan.done and p.plan.comp_inflight is None
                 and p.plan.comp_next <= p.plan.io_next]
        if not plans:
            return None
        plans.sort(key=lambda p: self.arrival_index[p.request_id])
        if self.compute_policy == "round_robin":
            start = self._rr_comp.get(stage, 0) % len(plans)
            p = plans[start]
            self._rr_comp[stage] = self._rr_comp.get(stage, 0) + 1
        else:
            p = plans[0]
        unit = p.plan.claim_compute()
        if unit is None:
            return None
        if p.strategy == "token":
            tokens = p.unit_tokens(unit)
            layers = (p.layer_lo, p.layer_hi)
        else:
            tokens = (0, p.n_tokens)
            layers = p.unit_layers(unit)
        return ScheduledOp("compute", p.request_id, p.stage, unit, tokens, layers)

    # ------------------------------------------------------------------
    def complete(self, op: ScheduledOp):
        p = self.plans[(op.request_id, op.stage)]
        if op.kind == "compute":
            p.plan.complete_compute(op.unit)
        else:
            p.plan.complete_io(op.unit)
