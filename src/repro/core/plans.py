"""Two-pointer restoration plans (paper §3.1).

A plan is a small state machine over *work units*:

  token-wise:  units are token chunks [c·C, (c+1)·C). The compute pointer
               claims chunks from the front (chunk recompute must be causal);
               the I/O pointer claims chunks from the back. Done when the
               pointers meet — the meeting point self-adapts to the actual
               compute/I-O rates, which is the essence of the design.
  layer-wise:  units are layers. Compute claims layers bottom-up (the forward
               pass produces layer KV as a byproduct); I/O claims top-down.
  3D:          one 2D plan per pipeline stage over its layer range; stages
               are independent given boundary activations (paper §3.2).

The plan only tracks claims/completions — *when* units run is the
scheduler's job. Invariants (property-tested):
  * compute and I/O never claim the same unit,
  * every unit is restored exactly once,
  * done ⇔ all units restored.

Claims are *releasable*: ``release_compute``/``release_io``/
``release_claims`` return an in-flight unit to the claimable pool without
advancing any pointer, so an aborted transfer (channel failure) or a
preempted request reschedules the exact same unit later — completion
counters never move on release, which is what keeps "every unit restored
exactly once" true across abort/preempt/resume cycles.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

Unit = Tuple[str, int]   # ("compute"|"load", index)


@dataclass
class TwoPointerPlan:
    """Two-pointer claim machine over ``n_units`` units.

    Compute claims ascending from 0; I/O claims descending from n_units-1.
    """
    n_units: int
    comp_next: int = 0            # next unit compute would claim
    io_next: int = field(default=-1)
    comp_inflight: Optional[int] = None
    io_inflight: Optional[int] = None
    comp_done: int = 0            # units [0, comp_done) recomputed
    io_done: int = 0              # units [n-io_done, n) loaded
    comp_enabled: bool = True     # False => load-only baseline (LMCache)
    io_enabled: bool = True       # False => recompute-only baseline (vLLM)

    def __post_init__(self):
        if self.io_next < 0:
            self.io_next = self.n_units - 1

    # -- claims ---------------------------------------------------------
    def claim_compute(self) -> Optional[int]:
        if (not self.comp_enabled or self.comp_inflight is not None
                or self.comp_next > self.io_next):
            return None
        # never claim the unit I/O is currently transferring (symmetric to
        # claim_io's guard): when the pointers meet on unit u with the
        # transfer still in flight, claiming u here would restore it twice
        if self.io_inflight is not None and self.comp_next >= self.io_inflight:
            return None
        self.comp_inflight = self.comp_next
        return self.comp_next

    def claim_io(self) -> Optional[int]:
        if (not self.io_enabled or self.io_inflight is not None
                or self.io_next < self.comp_next):
            return None
        # never claim the unit compute is currently working on
        if self.comp_inflight is not None and self.io_next <= self.comp_inflight:
            return None
        self.io_inflight = self.io_next
        return self.io_next

    # -- releases (abort / preempt) -------------------------------------
    def release_compute(self):
        """Return the in-flight compute claim (if any) to the pool.  The
        pointer does not advance: the unit is claimed again verbatim on the
        next ``claim_compute``."""
        self.comp_inflight = None

    def release_io(self):
        """Return the in-flight I/O claim (if any) to the pool (aborted
        transfer / preemption); the unit reschedules idempotently."""
        self.io_inflight = None

    def release_claims(self):
        """Suspend: release BOTH pointers' claims.  Completed units are
        untouched, so a preempted plan resumes exactly where it left off."""
        self.release_compute()
        self.release_io()

    # -- completions ----------------------------------------------------
    def complete_compute(self, unit: int):
        assert self.comp_inflight == unit
        self.comp_inflight = None
        self.comp_next = unit + 1
        self.comp_done += 1

    def complete_io(self, unit: int):
        assert self.io_inflight == unit
        self.io_inflight = None
        self.io_next = unit - 1
        self.io_done += 1

    # -- state ----------------------------------------------------------
    @property
    def done(self) -> bool:
        return (self.comp_done + self.io_done >= self.n_units
                and self.comp_inflight is None and self.io_inflight is None)

    @property
    def remaining_units(self) -> int:
        return self.n_units - self.comp_done - self.io_done

    def restored_units(self) -> List[Tuple[str, int]]:
        out = [("compute", i) for i in range(self.comp_done)]
        out += [("load", self.n_units - 1 - i) for i in range(self.io_done)]
        return out


@dataclass
class RequestPlan:
    """Restoration plan for one request on one stage.

    strategy: "token" | "layer"; for token plans units are chunks of
    ``chunk_size`` tokens across layer range [layer_lo, layer_hi); for layer
    plans units are the layers themselves (over all n_tokens).
    """
    request_id: str
    n_tokens: int                  # cached prefix length to restore (N_c)
    chunk_size: int
    strategy: str
    layer_lo: int
    layer_hi: int
    stage: int = 0
    plan: Optional[TwoPointerPlan] = None

    def __post_init__(self):
        if self.plan is None:
            n = (math.ceil(self.n_tokens / self.chunk_size) if self.strategy == "token"
                 else self.layer_hi - self.layer_lo)
            self.plan = TwoPointerPlan(max(1, n))

    # -- unit -> token/layer ranges --------------------------------------
    def unit_tokens(self, unit: int) -> Tuple[int, int]:
        if self.strategy == "token":
            return (unit * self.chunk_size,
                    min(self.n_tokens, (unit + 1) * self.chunk_size))
        return (0, self.n_tokens)

    def unit_layers(self, unit: int) -> Tuple[int, int]:
        if self.strategy == "token":
            return (self.layer_lo, self.layer_hi)
        return (self.layer_lo + unit, self.layer_lo + unit + 1)

    def io_unit_for_claim(self, unit: int) -> Tuple[Tuple[int, int], Tuple[int, int]]:
        """(token range, layer range) an I/O claim covers. Unit indices map
        directly: token plans claim chunks, layer plans claim layers (the
        I/O pointer simply walks unit indices top-down)."""
        if self.strategy == "token":
            return self.unit_tokens(unit), (self.layer_lo, self.layer_hi)
        return (0, self.n_tokens), self.unit_layers(unit)

    # -- cost hooks (filled by scheduler/simulator via cost model) -------
    def remaining_io_tokens(self) -> int:
        """Tokens' worth of KV still to restore — the paper's priority key
        ("largest remaining length to restore")."""
        if self.strategy == "token":
            return self.plan.remaining_units * self.chunk_size
        frac = self.plan.remaining_units / max(1, self.layer_hi - self.layer_lo)
        return int(self.n_tokens * frac)


def make_request_plans(request_id: str, n_tokens: int, *, chunk_size: int,
                       l_delta: int, num_layers: int,
                       stage_bounds: Optional[List[Tuple[int, int]]] = None,
                       strategy: Optional[str] = None) -> List[RequestPlan]:
    """Algorithm 1 lines 1–4: pick strategy by L_Δ, build per-stage plans.

    stage_bounds: [(layer_lo, layer_hi)] per pipeline stage (3D dimension);
    None => single stage covering all layers.
    """
    if strategy is None:
        strategy = "token" if n_tokens >= l_delta else "layer"
    bounds = stage_bounds or [(0, num_layers)]
    return [RequestPlan(request_id, n_tokens, chunk_size, strategy, lo, hi, stage=s)
            for s, (lo, hi) in enumerate(bounds)]
