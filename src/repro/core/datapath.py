"""The fused restoration data path: real per-channel transfer streams
feeding one dequant-scatter kernel launch per load op.

The engine core schedules restoration as ``(layer-span, token-range)``
I/O units over ``io_channels`` — historically a pure contention model.
This module is the execution substrate behind it:

  * :class:`TransferStream` — one host→device staging queue per channel
    (pinned to a physical mesh device by
    ``distributed.sharding.io_channel_devices``).  ``put`` issues an
    *asynchronous* ``jax.device_put`` and only blocks on the oldest
    in-flight buffer beyond ``depth``: with the default depth of 2, op
    k+1's host→device copy is in flight while op k's dequant-scatter
    kernel still consumes its buffer (double buffering), and the
    backpressure bounds staging memory to ``depth`` op payloads per
    channel.
  * :class:`RestoreDatapath` — executes one load op's data movement.
    The op's chunks (in *stored* encoding, via
    ``ChunkStore.fetch_range_packed``) are grouped into contiguous
    same-residency runs; each transfer run is packed into ONE multi-chunk
    staging buffer per field (int8 bytes + per-chunk scales cross the
    wire — half the fp16 bytes), staged through the channel's stream, and
    scattered into the live cache by ONE fused
    :func:`~repro.kernels.kv_restore.kv_restore_scatter` launch.  Runs
    already HBM-resident copy device-to-device from the pool views.  Each
    transferred chunk then lands its pool block via
    ``ChunkStore.promote_staged`` — built from the bytes already on
    device, so nothing crosses the wire twice.

Invariants the quantized path preserves (tested):

  * the on-device dequant is bit-identical to ``kv_dequantize``'s f32
    multiply + single cast, so fused and legacy restores agree within
    ``quant_tolerance()`` (and bit-exactly for ``quant="none"``);
  * store accounting (``bytes_transferred`` / ``fetches`` / ``io_hits``)
    is byte-identical to the legacy per-chunk ``fetch`` path;
  * staging buffers are zero-padded to whole chunks; padded rows fall
    past the cache's token extent and are clipped by the scatter.

In measured mode (``measure=True``, i.e. ``RealBackend`` without a
duration model) each op blocks on its written cache fields and the wall
seconds + wire bytes are attributed to the op's channel —
``RealBackend.io_secs`` charges the engine clock with the measured
transfer time and per-channel bandwidth becomes an observable.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.kv_restore import kv_restore_scatter

ATTN_FIELDS = ("k", "v", "ckv")


class TransferStream:
    """One host→device staging queue — an engine I/O channel made real."""

    def __init__(self, device=None, *, depth: int = 2):
        self.device = device
        self.depth = max(1, int(depth))
        self._inflight: deque = deque()
        self.puts = 0                  # staged host→device copies issued
        self.bytes_staged = 0          # bytes handed to device_put
        self.secs = 0.0                # measured wall secs (measure mode)
        self.bytes_moved = 0           # wire bytes behind those secs

    def put(self, host: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
        """Stage one op's packed buffers toward this stream's device.
        Asynchronous: returns immediately-usable (lazy) device arrays and
        only synchronizes on the oldest in-flight put beyond ``depth``."""
        while len(self._inflight) >= self.depth:
            jax.block_until_ready(self._inflight.popleft())
        if self.device is not None:
            dev = {k: jax.device_put(v, self.device) for k, v in host.items()}
        else:
            dev = {k: jnp.asarray(v) for k, v in host.items()}
        self._inflight.append(list(dev.values()))
        self.puts += 1
        self.bytes_staged += sum(int(v.nbytes) for v in host.values())
        return dev

    def note(self, secs: float, nbytes: int):
        self.secs += secs
        self.bytes_moved += nbytes

    def bandwidth(self) -> Optional[float]:
        """Measured bytes/sec over everything attributed to this channel
        (None until the first measured transfer)."""
        return self.bytes_moved / self.secs if self.secs > 0 else None

    def sync(self):
        while self._inflight:
            jax.block_until_ready(self._inflight.popleft())


def _split_runs(packed) -> List[list]:
    """Group an op's chunks into maximal contiguous runs of equal
    residency (resident pool views vs. bytes that must cross the wire) —
    one scatter per run keeps the kernel's token range contiguous."""
    runs: List[list] = []
    prev_cat, prev_c1 = None, None
    for item in packed:
        c0, _c1, form = item[0], item[1], item[2]
        cat = "hbm" if form == "hbm" else "xfer"
        if runs and cat == prev_cat and c0 == prev_c1:
            runs[-1].append(item)
        else:
            runs.append([item])
        prev_cat, prev_c1 = cat, item[1]
    return runs


class RestoreDatapath:
    """Per-channel double-buffered fetch→dequant→scatter pipeline."""

    def __init__(self, streams: Optional[Sequence[TransferStream]] = None,
                 *, backend: str = "auto", depth: int = 2,
                 measure: bool = False):
        self.streams = list(streams) if streams else [TransferStream(
            depth=depth)]
        self.backend = backend
        self.measure = measure
        self.kernel_launches = 0       # fused dequant-scatter launches
        self.resident_copies = 0       # device-to-device run scatters
        self.runs = 0
        self.ops = 0
        self.last_op_dispatches = 0    # copy dispatches of the latest op
        self._last_secs: Optional[float] = None

    @classmethod
    def for_channels(cls, io_channels: Optional[int] = None, mesh=None, *,
                     backend: str = "auto", depth: int = 2):
        """One stream per engine I/O channel, pinned round-robin onto the
        mesh's physical devices (every device gets its own fetch queue on
        a real sharded deployment)."""
        from repro.distributed.sharding import io_channel_devices
        devs = io_channel_devices(mesh, io_channels)
        return cls([TransferStream(d, depth=depth) for d in devs],
                   backend=backend)

    def stream_for(self, channel: int) -> TransferStream:
        return self.streams[channel % len(self.streams)]

    def bandwidths(self) -> List[Optional[float]]:
        return [s.bandwidth() for s in self.streams]

    def pop_measured_secs(self) -> Optional[float]:
        secs, self._last_secs = self._last_secs, None
        return secs

    # ------------------------------------------------------------------
    def restore_op(self, cache: dict, packed, *, store, slot_span,
                   channel: int = 0) -> dict:
        """Execute one load op's data movement into the live ``cache``
        (mutated in place and returned).  ``packed`` is the op's
        ``fetch_range_packed`` result; ``slot_span`` the contiguous
        attention-slot range the op's layer span owns."""
        fields = [f for f in ATTN_FIELDS if f in cache]
        s_lo, s_hi = slot_span
        cs = store.chunk_size
        stream = self.stream_for(channel)
        a = cache["kpos"].shape[0]
        s = cache[fields[0]].shape[2]
        assert cache[fields[0]].shape[1] == 1, "datapath assumes B == 1"
        dispatches = 0
        moved = 0
        t_begin = time.perf_counter() if self.measure else 0.0

        for run in _split_runs(packed):
            r0, r1 = run[0][0], run[-1][1]
            form = run[0][2]
            if form == "hbm":
                staged, kpos_dev = self._gather_resident(run, fields)
                scales_dev = None
                self.resident_copies += 1
            else:
                host, nbytes = self._pack_host(run, fields, cs, a)
                dev = stream.put(host)
                dispatches += 1                    # one staged copy per run
                moved += nbytes
                staged = {f: dev[f] for f in fields}
                kpos_dev = dev["kpos"]
                scales_dev = ({f: dev[f + "__s"] for f in fields}
                              if form == "int8" else None)
                self.kernel_launches += 1

            # one fused (dequantizing) scatter per run, all fields in the
            # launch; resident runs are device-local copies and take the
            # jitted oracle (XLA fuses them into one update per field)
            views = [cache[f].reshape(a, s, -1) for f in fields]
            out = kv_restore_scatter(
                views, [staged[f] for f in fields],
                None if scales_dev is None else [scales_dev[f]
                                                 for f in fields],
                t0=r0, slot_lo=s_lo, n_slots=s_hi - s_lo, chunk_size=cs,
                backend="ref" if form == "hbm" else self.backend)
            for f, o in zip(fields, out):
                cache[f] = o.reshape(cache[f].shape)
            dispatches += 1
            # one kpos update per RUN, not per chunk x layer x field —
            # already amortized by the run split
            cache["kpos"] = cache["kpos"].at[s_lo:s_hi, r0:r1].set(  # codelint: allow(at-set-loop)
                kpos_dev[s_lo:s_hi])
            dispatches += 1

            if form != "hbm":
                self._promote_run(run, fields, cache, staged, scales_dev,
                                  kpos_dev, store)
            self.runs += 1

        self.ops += 1
        self.last_op_dispatches = dispatches
        if self.measure:
            jax.block_until_ready([cache[f] for f in fields]
                                  + [cache["kpos"]])
            secs = time.perf_counter() - t_begin
            stream.note(secs, moved)
            self._last_secs = secs
        return cache

    # ------------------------------------------------------------------
    @staticmethod
    def _gather_resident(run, fields):
        """Concatenate a resident run's pool views into (A, T, C) staging
        shapes — device-to-device, nothing crosses the wire."""
        staged = {}
        for f in fields:
            parts = [jnp.asarray(item[3][f]) for item in run]
            cat = parts[0] if len(parts) == 1 else jnp.concatenate(
                parts, axis=2)
            staged[f] = cat.reshape(cat.shape[0], cat.shape[2], -1)
        kpos = (jnp.asarray(run[0][3]["kpos"]) if len(run) == 1
                else jnp.concatenate([jnp.asarray(item[3]["kpos"])
                                      for item in run], axis=1))
        return staged, kpos

    @staticmethod
    def _pack_host(run, fields, cs, a):
        """Pack a transfer run's stored chunk payloads into one staging
        buffer per field: (A, n_chunks·cs, C) with zero-padded tails, plus
        per-chunk per-channel scales (n_chunks, C) on the int8 path and
        the run's kpos rows.  Returns (host dict, wire bytes)."""
        quant = run[0][2] == "int8"
        host = {"kpos": np.concatenate([np.asarray(item[3]["kpos"])
                                        for item in run], axis=1)}
        nbytes = host["kpos"].nbytes
        for f in fields:
            parts, scl = [], []
            for c0, c1, _form, pay, _key in run:
                rep = pay[f]
                arr = np.asarray(rep["q"] if quant else rep)
                assert arr.shape[1] == 1, "datapath assumes B == 1"
                a3 = arr.reshape(a, c1 - c0, -1)
                if c1 - c0 < cs:
                    a3 = np.concatenate(
                        [a3, np.zeros((a, cs - (c1 - c0), a3.shape[2]),
                                      a3.dtype)], axis=1)
                parts.append(a3)
                if quant:
                    sc = np.asarray(rep["scales"], np.float32)
                    scl.append(np.tile(sc, a3.shape[2] // sc.shape[0]))
                    nbytes += sc.nbytes
                nbytes += arr.nbytes
            host[f] = np.concatenate(parts, axis=1)
            if quant:
                host[f + "__s"] = np.stack(scl)
        return host, nbytes

    @staticmethod
    def _promote_run(run, fields, cache, staged, scales_dev, kpos_dev,
                     store):
        """Land each transferred chunk's pool block from the staged device
        bytes (dequantized on device for int8, bit-identically to the
        scatter kernel's math) — the store's HBM promote then consumes
        these instead of a second host→device copy."""
        r0 = run[0][0]
        a = cache["kpos"].shape[0]
        for idx, (c0, c1, _form, _pay, key) in enumerate(run):
            off, n = c0 - r0, c1 - c0
            dev = {"kpos": kpos_dev[:, off:off + n]}
            for f in fields:
                sl = staged[f][:, off:off + n]
                if scales_dev is not None:
                    sl = (sl.astype(jnp.float32)
                          * scales_dev[f][idx]).astype(cache[f].dtype)
                dev[f] = sl.reshape((a, 1, n) + cache[f].shape[3:])
            store.promote_staged(key, dev)
