"""Backend-agnostic batched request-lifecycle engine core.

One event loop drives the paper's ``BatchScheduler`` (Algorithm 1) over a
batch of concurrent requests through their WHOLE serving lifecycle:

    RESTORING -> PREFILL -> DECODE -> DONE

  * RESTORING — the 3D two-pointer restoration of the cached prefix
    (per-stage compute resources + shared I/O channels, §3.3).
  * PREFILL   — one suffix-prefill op per pipeline stage (in stage order),
    competing FCFS with other requests' restoration chunks on the same
    stage compute; its completion is the request's FIRST TOKEN.
  * DECODE    — a recurring batched decode op on a dedicated decode-batch
    resource steps *all* decode-phase requests together, one token per
    step; the last step is the request's FINISH.

The loop owns every scheduling concern:

  * continuous-batching admission (``max_active``) — a slot is held for the
    whole lifecycle and freed at DECODE completion, not restore completion;
    a mid-flight retire refills its slot immediately, so arriving requests
    restore AGAINST the live decode batch (``admission="gang"`` is the
    run-to-completion baseline: the next batch joins only at batch close),
  * queued-request prefetch (``prefetch=True``) — idle channel time
    promotes the admission queue's chunks up a storage tier ahead of
    admission (the queue is a known lookahead window),
  * one compute resource per pipeline stage (chunk recomputes and suffix
    prefills serialize on the stage's chips),
  * ``io_channels`` shared transfer channels (contention = queueing, §3.3),
  * per-channel slowdown / failure injection (failed transfers release their
    claim and are rescheduled — restoration ops are idempotent),
  * ``TieredKVStore`` integration: per-request bandwidth lookup at dispatch
    time, LRU ``touch`` on admission and ``promote`` on restore completion.

What an op *costs* — virtual seconds from a ``CostModel`` or measured wall
seconds of real JAX execution — is delegated to a pluggable backend:

  * ``SimBackend``  — advances virtual time analytically; the discrete-event
    simulator (``RestorationSimulator``) is a thin facade over it.
  * ``RealBackend`` — executes each dispatched op on device through a
    ``RestorationExecutor`` and feeds measured (or synthetic, for
    interleaving tests) durations back into the same loop.

Because both backends run the *identical* admission/dispatch logic, the
simulator measures exactly the schedule whose correctness the real backend
proves — including multi-request interleavings across all phases.

Requests with ``new_len == 0`` and ``decode_len == 0`` are restoration-only:
their lifecycle collapses to RESTORING -> DONE and the loop behaves exactly
as the pre-lifecycle core (``RestorationSimulator`` / ``.restore()``).
"""
from __future__ import annotations

import heapq
import itertools
import math
import os
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import CostModel
from repro.core.plans import RequestPlan
from repro.core.scheduler import BatchScheduler, ScheduledOp


@dataclass
class EngineRequest:
    """A request as the engine core sees it: identity, prefix length,
    arrival time, one RequestPlan per pipeline stage, its lifecycle extent
    — suffix tokens to prefill and output tokens to generate — and its SLO
    class: ``priority`` (higher = more urgent) and ``deadline`` (engine-
    clock instant the first token is wanted by; ``inf`` = best-effort).
    The engine's preemption policy compares these at admission pressure."""
    request_id: str
    n_tokens: int                   # prefix to restore
    arrival: float = 0.0
    plans: List[RequestPlan] = field(default_factory=list)  # one per stage
    new_len: int = 0                # fresh suffix tokens (0 = restore-only)
    decode_len: int = 0             # output tokens (first from prefill)
    priority: int = 0               # SLO class (preempt="priority")
    deadline: float = math.inf      # first-token SLO (preempt="deadline")


@dataclass
class EngineResult:
    restore_finish: Dict[str, float]
    restore_start: Dict[str, float]
    first_token: Dict[str, float]   # suffix prefill done (TTFT reference)
    finish: Dict[str, float]        # lifecycle complete (slot freed here)
    makespan: float
    compute_busy: float             # fraction of makespan, averaged over stages
    io_busy: float                  # fraction, averaged over channels
    decode_busy: float              # decode-batch resource busy fraction
    decode_steps: int               # batched decode steps executed
    ops_log: List[Tuple[float, float, str, str]]  # (start, end, resource, op-desc)
    # rid -> times its restoration was suspended (preempt="priority"|
    # "deadline"); aborted/preempted op time is EXCLUDED from the busy
    # fractions above and tagged ":aborted" in ops_log.
    preemptions: Dict[str, int] = field(default_factory=dict)
    # seconds during which a batched decode step and at least one
    # restoration op (chunk recompute / KV transfer / queued-request
    # prefetch) ran simultaneously — the steady-state decode/restoration
    # overlap continuous batching exists to create.  Derived from ops_log
    # (see :func:`decode_restore_overlap`), so replay stays bit-identical.
    overlap_decode_restore: float = 0.0


def _merge_intervals(intervals):
    out: List[List[float]] = []
    for t0, t1 in sorted(intervals):
        if out and t0 <= out[-1][1]:
            out[-1][1] = max(out[-1][1], t1)
        else:
            out.append([t0, t1])
    return out


def decode_restore_overlap(ops_log) -> float:
    """Seconds during which a batched decode step and at least one
    restoration op — chunk recompute (``:c``), KV transfer (``:l``) or
    queued-request prefetch (``:pf``); suffix prefills and aborted ops
    excluded — were simultaneously in flight.  Zero in any schedule that
    drains the decode batch before restoring the next one (run-to-
    completion); strictly positive at continuous-batching steady state,
    where arriving requests restore against the live decode batch."""
    dec, rest = [], []
    for t0, t1, resource, desc in ops_log:
        if desc.endswith(":aborted"):
            continue
        if resource == "decode":
            dec.append((t0, t1))
            continue
        tag = desc.rsplit(":", 1)[-1]
        if tag == "pf" or (tag[:1] in ("c", "l") and tag[1:].isdigit()):
            rest.append((t0, t1))
    dec, rest = _merge_intervals(dec), _merge_intervals(rest)
    total, i, j = 0.0, 0, 0
    while i < len(dec) and j < len(rest):
        lo = max(dec[i][0], rest[j][0])
        hi = min(dec[i][1], rest[j][1])
        if lo < hi:
            total += hi - lo
        if dec[i][1] <= rest[j][1]:
            i += 1
        else:
            j += 1
    return total


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


class EngineBackend:
    """Execution provider for the engine core.

    ``compute_secs`` / ``io_secs`` return the op's duration on the engine
    clock; a real backend additionally *executes* the op when asked for its
    duration (dispatch time), which is legal because claimed units are
    disjoint and per-plan claims serialize."""

    def admit(self, req: EngineRequest) -> None:
        """Called once when the request enters the active batch."""

    def compute_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        raise NotImplementedError

    def io_secs(self, op: ScheduledOp, req: EngineRequest,
                bandwidth: Optional[float]) -> float:
        raise NotImplementedError

    def io_channel_hint(self, channel: int) -> None:
        """Engine channel about to dispatch I/O ops.  A real backend routes
        the ops onto that channel's physical transfer stream (the fused
        datapath pins one host→device queue per channel); analytic/replay
        backends ignore it."""

    def io_hit_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        """Duration of a load whose chunks are already HBM-resident (dedup
        hit): no interconnect bytes move.  A real backend still executes
        the op (device-local copy into the live cache); the engine clock
        charges nothing."""
        return 0.0

    def io_secs_partial(self, op: ScheduledOp, req: EngineRequest,
                        bandwidth: Optional[float], missing: float) -> float:
        """Duration of a load only ``missing`` (0..1, bytes-weighted) of
        whose blocks actually cross the interconnect — block-granular
        residency: a partially evicted unit re-transfers just its missing
        blocks.  Default prices the transfer pro rata."""
        return self.io_secs(op, req, bandwidth) * missing

    def prefill_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        """Duration of one suffix-prefill stage op (kind == "prefill")."""
        raise NotImplementedError

    def decode_secs(self, reqs: List[EngineRequest]) -> float:
        """Duration of one batched decode step over every decode-phase
        request (sorted by arrival) — one generated token each."""
        raise NotImplementedError

    def io_benefit(self, plan: RequestPlan, unit: int,
                   bandwidth: Optional[float], slowdown: float = 1.0,
                   decode_load: int = 0) -> bool:
        """Marginal-benefit gate (§3.3); default = eager loading.
        ``slowdown`` is the CANDIDATE CHANNEL's duration multiplier — the
        gate must price the transfer at the channel the unit would actually
        ride, not the nominal kvstore/default bandwidth.  ``decode_load``
        is the size of the LIVE decode batch at gate time: at continuous-
        batching steady state the recompute alternative timeshares the
        chips with recurring decode steps, so it must be priced against a
        busy device, not an idle one."""
        return True

    def prefetch_secs(self, op: ScheduledOp, req: EngineRequest,
                      bandwidth: Optional[float]) -> float:
        """Duration of a queued-request prefetch (kind == "prefetch"): the
        admission queue is a known lookahead window, so idle channel time
        promotes a queued request's chunks up a tier — its admission-time
        restoration then starts from the faster tier."""
        raise NotImplementedError

    def prefetch_gate(self, req: EngineRequest) -> bool:
        """Replay hook: should this queued request be prefetched?  Live
        runs never ask — the engine consults its KV store directly (and
        records the answer); only a store-less replay delegates here."""
        return False

    def suspend(self, req: EngineRequest) -> None:
        """Called when the request's restoration is preempted: its
        partially-restored cache parks (NOT finalized) until resume."""

    def evict(self, req: EngineRequest) -> None:
        """Eviction-mode preemption (host memory tight): the partially-
        restored cache is DROPPED, not parked — restoration restarts from
        the KV store when the request is re-admitted."""

    def resume(self, req: EngineRequest) -> None:
        """Called when a preempted request re-enters the active batch."""

    def restore_done(self, req: EngineRequest) -> None:
        """Called once when every stage plan of the request is restored
        (before suffix prefill touches the cache)."""

    def request_done(self, req: EngineRequest) -> None:
        """Called once when the request's whole lifecycle completes."""


class SimBackend(EngineBackend):
    """Analytic durations from the CacheFlow cost model (virtual time)."""

    def __init__(self, cost: CostModel,
                 bw_override: Optional[Dict[str, float]] = None,
                 benefit_gate: bool = True):
        self.cost = cost
        self.bw_override = bw_override or {}
        self.benefit_gate = benefit_gate

    def _bw(self, rid: str, bandwidth: Optional[float]) -> float:
        if bandwidth is not None:
            return bandwidth
        return self.bw_override.get(rid, self.cost.io_bandwidth)

    def compute_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        lo, hi = op.layers
        frac = (hi - lo) / self.cost.cfg.num_layers
        t0, t1 = op.tokens
        f = self.cost.flops_recompute(t0, t1) * frac
        return f / (self.cost.hw.peak_flops * self.cost.mfu * self.cost.num_chips) \
            + self.cost.hw.kernel_overhead_s

    def io_secs(self, op: ScheduledOp, req: EngineRequest,
                bandwidth: Optional[float]) -> float:
        t0, t1 = op.tokens
        lo, hi = op.layers
        frac = (hi - lo) / self.cost.cfg.num_layers
        bytes_ = (t1 - t0) * self.cost.bytes_per_token() * frac
        return bytes_ / self._bw(op.request_id, bandwidth)

    def prefill_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        # same compute roofline as a restoration chunk: the suffix tokens
        # attend to the (restored) prefix, scaled to the stage's layer slice
        return self.compute_secs(op, req)

    def decode_secs(self, reqs: List[EngineRequest]) -> float:
        return self.cost.t_decode_step(
            [r.n_tokens + r.new_len for r in reqs])

    def prefetch_secs(self, op: ScheduledOp, req: EngineRequest,
                      bandwidth: Optional[float]) -> float:
        """Whole-prefix payload at the CURRENT tier's bandwidth (the store
        reports the queued request's tier at dispatch time; promotion to
        the faster tier happens when the transfer completes)."""
        t0, t1 = op.tokens
        return (t1 - t0) * self.cost.bytes_per_token() \
            / self._bw(op.request_id, bandwidth)

    def io_benefit(self, plan: RequestPlan, unit: int,
                   bandwidth: Optional[float], slowdown: float = 1.0,
                   decode_load: int = 0) -> bool:
        """Spend a channel on this unit only if the transfer finishes before
        compute alone could have covered the remaining span through it —
        otherwise loading delays completion (the channel pins the unit).
        The transfer is priced at the candidate channel's EFFECTIVE
        bandwidth (nominal / slowdown): a degraded channel must not pass a
        gate its real transfer time would fail.  With a LIVE decode batch
        (``decode_load`` > 0) the recompute alternative is priced against a
        busy device: recurring decode steps eat ``cost.decode_interference``
        of the restoration-compute throughput, so transfers that would lose
        to an idle device's recompute can still win at steady state."""
        if not self.benefit_gate:
            return True
        if not plan.plan.comp_enabled:
            return True               # load-only baselines: I/O is all they have
        tokens, layers = plan.io_unit_for_claim(unit)
        lo, hi = layers
        frac = (hi - lo) / self.cost.cfg.num_layers
        bw = self._bw(plan.request_id, bandwidth) / max(slowdown, 1e-12)
        t0, t1 = tokens
        io_secs = (t1 - t0) * self.cost.bytes_per_token() * frac / bw
        if plan.strategy == "token":
            span0 = plan.plan.comp_next * plan.chunk_size
            span1 = min(plan.n_tokens, (unit + 1) * plan.chunk_size)
            n_chunks = unit - plan.plan.comp_next + 1
            comp_secs = (self.cost.flops_recompute(span0, span1) * frac
                         / (self.cost.hw.peak_flops * self.cost.mfu
                            * self.cost.num_chips)
                         + n_chunks * self.cost.hw.kernel_overhead_s)
        else:
            n_layers = unit - plan.plan.comp_next + 1
            full = self.cost.flops_recompute(0, plan.n_tokens) / self.cost.cfg.num_layers
            comp_secs = (full * n_layers
                         / (self.cost.hw.peak_flops * self.cost.mfu
                            * self.cost.num_chips)
                         + self.cost.hw.kernel_overhead_s)
        if decode_load > 0 and self.cost.decode_interference > 0.0:
            comp_secs /= 1.0 - min(self.cost.decode_interference, 0.999)
        return io_secs < comp_secs


class RealBackend(EngineBackend):
    """Executes dispatched ops on device through a RestorationExecutor.

    Durations on the engine clock are measured wall seconds by default;
    ``dur_fn(op) -> secs`` overrides them (e.g. rng-drawn durations to
    property-test that *any* legal multi-request interleaving restores every
    cache correctly — the completion order, and hence all subsequent claims,
    follows the durations)."""

    def __init__(self, executor, *, dur_fn: Optional[Callable[[ScheduledOp], float]] = None,
                 verify: bool = False, verify_atol: Optional[float] = None):
        self.executor = executor
        self.dur_fn = dur_fn
        self.verify = verify
        # None = executor default; a quantized chunk store needs its
        # documented int8 tolerance on top of the recompute atol
        self.verify_atol = verify_atol
        # measured mode: the fused datapath blocks per load op and reports
        # the transfer wall seconds + per-channel bandwidth (io_secs below
        # charges those); synthetic durations keep it fully asynchronous
        dp = getattr(executor, "datapath", None)
        if dp is not None:
            dp.measure = dur_fn is None

    def admit(self, req: EngineRequest) -> None:
        self.executor.begin_restore(req.request_id, plans=req.plans)

    def _run_op(self, op: ScheduledOp) -> float:
        if self.dur_fn is not None:
            # synthetic schedule durations: no measurement needed, so let op
            # results chain asynchronously instead of syncing the whole cache
            self.executor.execute_op(op)
            return max(1e-12, float(self.dur_fn(op)))
        import jax
        t0 = time.perf_counter()
        self.executor.execute_op(op)
        jax.block_until_ready(
            jax.tree.leaves(self.executor.live_cache(op.request_id)))
        return max(1e-12, time.perf_counter() - t0)

    def compute_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        return self._run_op(op)

    def io_channel_hint(self, channel: int) -> None:
        # load ops dispatched next ride this engine channel's physical
        # transfer stream (one host→device queue per channel)
        self.executor.io_channel = channel

    def io_secs(self, op: ScheduledOp, req: EngineRequest,
                bandwidth: Optional[float]) -> float:
        wall = self._run_op(op)
        if self.dur_fn is None:
            # measured mode: charge the channel the datapath's measured
            # transfer seconds for THIS op (staging + dequant-scatter),
            # not the whole-cache sync wall time, so per-channel bandwidth
            # feeds back into the engine clock
            dp = getattr(self.executor, "datapath", None)
            secs = dp.pop_measured_secs() if dp is not None else None
            if secs is not None:
                return max(1e-12, secs)
        return wall

    def prefill_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        return self._run_op(op)

    def decode_secs(self, reqs: List[EngineRequest]) -> float:
        rids = [r.request_id for r in reqs]
        if self.dur_fn is not None:
            self.executor.decode_step_batch(rids)
            # the op carries the FULL participant list: a synthetic duration
            # may depend on batch composition (CostModel.t_decode_step does)
            op = ScheduledOp("decode", rids[0], -1, 0, (0, len(rids)), (0, 0),
                             batch=tuple(rids))
            return max(1e-12, float(self.dur_fn(op)))
        import jax
        t0 = time.perf_counter()
        self.executor.decode_step_batch(rids)
        jax.block_until_ready(
            [jax.tree.leaves(self.executor.live_cache(r)) for r in rids])
        return max(1e-12, time.perf_counter() - t0)

    def io_hit_secs(self, op: ScheduledOp, req: EngineRequest) -> float:
        # resident chunks: the load still executes (HBM-local copy into the
        # live cache) but occupies no transfer-channel time on the clock
        self.executor.execute_op(op)
        dp = getattr(self.executor, "datapath", None)
        if dp is not None:
            dp.pop_measured_secs()     # device-local: nothing to charge
        return 0.0

    def io_secs_partial(self, op: ScheduledOp, req: EngineRequest,
                        bandwidth: Optional[float], missing: float) -> float:
        # the measured wall time already reflects only the missing blocks
        # moving (resident blocks fetch as device-local hits inside the
        # store) — no pro-rata scaling on top
        return self.io_secs(op, req, bandwidth)

    def prefetch_secs(self, op: ScheduledOp, req: EngineRequest,
                      bandwidth: Optional[float]) -> float:
        # the byte movement happens at completion (the engine promotes the
        # queued request through the chunk store); synthetic durations shape
        # the schedule for interleaving tests, measured mode charges the
        # host-side copy as near-instant background work
        if self.dur_fn is not None:
            return max(1e-12, float(self.dur_fn(op)))
        return 1e-9

    def suspend(self, req: EngineRequest) -> None:
        # park the partially-restored cache off-device; finalize_restore
        # (recurrent-state fix-up) must NOT run — restoration is incomplete
        self.executor.suspend_restore(req.request_id)

    def evict(self, req: EngineRequest) -> None:
        self.executor.drop_restore(req.request_id)

    def resume(self, req: EngineRequest) -> None:
        if self.executor.is_live(req.request_id):
            self.executor.resume_restore(req.request_id)
        else:
            # eviction-mode preemption dropped the live state: restoration
            # restarts on a fresh cache (plans were reset with it)
            self.executor.begin_restore(req.request_id, plans=req.plans)

    def restore_done(self, req: EngineRequest) -> None:
        # verify BEFORE prefill/decode append to the restored cache
        self.executor.finalize_restore(req.request_id)
        if self.verify:
            if self.verify_atol is not None:
                self.executor.verify(req.request_id, atol=self.verify_atol)
            else:
                self.executor.verify(req.request_id)


# ---------------------------------------------------------------------------
# Event loop
# ---------------------------------------------------------------------------


class EngineCore:
    """The single scheduling loop shared by simulated and real serving.

    stage_parallel=False models the paper's Fig. 7 ablation: stages restore
    sequentially (stage s waits for s-1) instead of concurrently via boundary
    activations.  max_active is the continuous-batching admission cap
    (0 = unlimited).  kvstore, when given, supplies per-request I/O bandwidth
    at dispatch time and gets ``touch``/``promote`` callbacks as requests are
    admitted / finish restoring.

    preempt is the admission-pressure policy when ``max_active`` is full:

      * "none"     — FCFS queueing (classic behavior): arrivals wait.
      * "priority" — an arrival with strictly higher ``priority`` than some
        still-RESTORING active request suspends the eligible victim with the
        SMALLEST remaining restoration (least marginal recompute saving —
        the dual of the §3.3 dispatch key) and takes its slot.
      * "deadline" — same, but eligibility is an earlier first-token
        ``deadline`` than the victim's (EDF).

    Suspension releases both pointers' claims (in-flight ops abort; their
    time is excluded from utilization) and parks the partially-restored
    cache; a freed slot re-admits the most urgent of {suspended, queued}
    and a resumed request continues from its completed units — restored
    exactly once, never restarted.  Only RESTORING-phase requests are
    preemptible: prefill/decode work is never rescinded.

    evict=True switches preemption to EVICTION mode (host memory tight):
    the victim's partially-restored cache is dropped instead of parked and
    its plans reset, so a re-admitted victim restarts restoration from the
    KV store — completed work is sacrificed to free memory.

    A kvstore exposing ``io_resident(rid, tokens, layers)`` additionally
    gates transfers on chunk residency: an I/O unit whose chunks already
    sit in device HBM (a dedup hit — another request restored the shared
    prefix, or the payload never left HBM) dispatches at ZERO channel cost
    (real backends still execute the device-local copy), and the benefit
    gate passes it unconditionally.

    admission picks the batching discipline:

      * "continuous" (default) — requests stream into and out of the batch
        every step: an arrival takes any free slot immediately, a slot freed
        by a mid-flight retire (DECODE completion) is refilled on the spot,
        so queued/arriving requests restore AGAINST the live decode batch
        on the shared compute/I/O resources.  The benefit gate prices the
        recompute alternative at the live decode load (``decode_load``).
      * "gang" — the run-to-completion baseline: arrivals only join at
        batch close.  The next gang (up to ``max_active``) is admitted when
        the current one fully drains, so cross-batch decode/restoration
        overlap is structurally zero.  Incompatible with preemption.

    prefetch=True uses idle channel time on the admission queue (a known
    lookahead window): a queued request whose prefix sits below
    ``promote_tier`` gets its chunks promoted up BEFORE admission, so
    admission-time restoration starts from the faster tier.  Each queued
    request is considered once (FCFS); the decision is recorded in traces
    (``prefetch_gate``) so replay re-derives it without the store."""

    PREEMPT_POLICIES = ("none", "priority", "deadline")
    ADMISSION_MODES = ("continuous", "gang")

    def __init__(self, backend: EngineBackend, *, stages: int = 1,
                 io_channels: int = 1, io_policy: str = "longest_remaining",
                 channel_slowdown: Optional[Dict[int, float]] = None,
                 channel_fail_at: Optional[Dict[int, float]] = None,
                 stage_parallel: bool = True, max_active: int = 0,
                 kvstore=None, promote_tier: str = "host",
                 preempt: str = "none", evict: bool = False,
                 admission: str = "continuous", prefetch: bool = False,
                 strict: bool = False, sanitize: Optional[bool] = None,
                 telemetry=None):
        if preempt not in self.PREEMPT_POLICIES:
            raise ValueError(f"unknown preempt policy {preempt!r}; "
                             f"known: {self.PREEMPT_POLICIES}")
        if admission not in self.ADMISSION_MODES:
            raise ValueError(f"unknown admission mode {admission!r}; "
                             f"known: {self.ADMISSION_MODES}")
        if admission == "gang" and preempt != "none":
            raise ValueError(
                "admission='gang' is the run-to-completion baseline — a "
                "closed batch has no admission pressure to preempt for; "
                "use admission='continuous' with preempt=...")
        self.backend = backend
        self.stages = stages
        self.io_channels = io_channels
        self.io_policy = io_policy
        self.slow = channel_slowdown or {}
        self.fail_at = channel_fail_at or {}
        self.stage_parallel = stage_parallel
        self.max_active = max_active
        self.kvstore = kvstore
        self.promote_tier = promote_tier
        self.preempt = preempt
        self.evict = evict
        self.admission = admission
        self.prefetch = prefetch
        self.strict = strict
        # opt-in runtime invariant sanitizer (repro.analysis.sanitizer).
        # None defers to the CACHEFLOW_SANITIZE env var; every hook in the
        # loop is behind an `if san is not None` guard, so the default-off
        # path adds zero work (measured: benchmarks/restore_datapath.py).
        if sanitize is None:
            sanitize = os.environ.get(
                "CACHEFLOW_SANITIZE", "0").lower() not in ("", "0", "false")
        self.sanitize = bool(sanitize)
        # the sanitizer of the most recent run (its counters are the serve
        # observable); None when sanitizing is off
        self.last_sanitizer = None
        # opt-in telemetry (repro.obs.telemetry), same convention as the
        # sanitizer: None defers to CACHEFLOW_TELEMETRY, True builds a fresh
        # Telemetry per run, or pass a prebuilt Telemetry instance.  Hooks
        # are pure observers behind `if tel is not None` guards, so the
        # off path costs nothing and the on path is bit-identical on
        # EngineResult/ops_log (tests/test_obs.py).
        if telemetry is None:
            telemetry = os.environ.get(
                "CACHEFLOW_TELEMETRY", "0").lower() not in ("", "0", "false")
        self.telemetry = telemetry
        # the Telemetry of the most recent run (its snapshot is the serve
        # observable); None when telemetry is off
        self.last_telemetry = None

    def _bandwidth(self, rid: str) -> Optional[float]:
        if self.kvstore is None:
            return None
        return self.kvstore.bandwidth_for(rid)

    def _resident(self, rid: str, tokens, layers) -> bool:
        """Chunk-residency consult for the I/O pointer: True iff the whole
        unit is already device-resident and the transfer can be skipped."""
        ks = self.kvstore
        return (ks is not None and hasattr(ks, "io_resident")
                and ks.io_resident(rid, tokens, layers))

    def _missing_fraction(self, rid: str, tokens, layers) -> float:
        """Block-granular residency: the bytes-weighted fraction of the
        unit NOT on device.  Stores without block granularity (the sim
        store's whole-request placement) transfer the full unit."""
        ks = self.kvstore
        if ks is None or not hasattr(ks, "missing_fraction"):
            return 1.0
        return max(0.0, min(1.0, ks.missing_fraction(rid, tokens, layers)))

    # ------------------------------------------------------------------
    def run(self, requests: List[EngineRequest],
            trace: Optional["TraceRecorder"] = None) -> EngineResult:
        """Drive the batch to completion.  ``trace``, when given, is a
        ``repro.core.trace.TraceRecorder`` that captures every scheduling
        decision as a replayable ``ScheduleTrace``."""
        empty = [r.request_id for r in requests if not r.plans]
        if empty:
            if self.strict:
                raise ValueError(
                    f"requests with zero plans cannot be scheduled: {empty}")
            requests = [r for r in requests if r.plans]

        now = 0.0
        san = None
        if self.sanitize:
            # lazy import: the analysis package never loads on the default
            # (sanitize=False) path
            from repro.analysis.sanitizer import EngineSanitizer
            san = EngineSanitizer(self)
        self.last_sanitizer = san
        tel = None
        if self.telemetry:
            # lazy import, same as the sanitizer: repro.obs never loads on
            # the default (telemetry off) path
            from repro.obs.telemetry import Telemetry
            tel = self.telemetry if isinstance(self.telemetry, Telemetry) \
                else Telemetry()
            tel.begin(self)
        self.last_telemetry = tel
        # the candidate channel's duration multiplier, set by the dispatch
        # loop before each next_io() pass so the benefit gate prices the
        # transfer at the channel it would actually ride (a 10x-degraded
        # channel must not pass a full-bandwidth gate)
        gate_slowdown = [1.0]

        def benefit(p: RequestPlan, u: int) -> bool:
            tokens, layers = p.io_unit_for_claim(u)
            if self._resident(p.request_id, tokens, layers):
                ok = True               # resident chunks transfer for free
            else:
                # priced against the LIVE decode batch, not an idle device:
                # at steady state recompute timeshares with decode steps
                ok = self.backend.io_benefit(p, u,
                                             self._bandwidth(p.request_id),
                                             slowdown=gate_slowdown[0],
                                             decode_load=len(decoding))
            if tel is not None:
                tel.on_gate(now, p.request_id, ok)
            if trace is not None:
                trace.record_gate(now, p.request_id, p.stage, u, ok,
                                  decode_load=len(decoding))
            return ok

        sched = BatchScheduler(io_policy=self.io_policy, benefit_fn=benefit)
        if trace is not None:
            trace.begin(self._trace_meta(), requests)
        counter = itertools.count()
        events: List[Tuple[float, int, str, object]] = []
        for r in requests:
            heapq.heappush(events, (r.arrival, next(counter), "arrive", r))
        for c, t in self.fail_at.items():
            heapq.heappush(events, (t, next(counter), "fail", c))

        comp_free = {s: True for s in range(self.stages)}
        io_free = {c: True for c in range(self.io_channels)}
        decode_free = True
        failed = set()
        busy_comp = {s: 0.0 for s in range(self.stages)}
        busy_io = {c: 0.0 for c in range(self.io_channels)}
        busy_decode = 0.0
        decode_steps = 0
        restore_finish: Dict[str, float] = {}
        restore_start: Dict[str, float] = {}
        first_token: Dict[str, float] = {}
        finish: Dict[str, float] = {}
        decoding: Dict[str, int] = {}   # rid -> decode steps remaining
        ops_log: List[Tuple[float, float, str, str]] = []
        reqs: Dict[str, EngineRequest] = {}
        pending: "deque[EngineRequest]" = deque()
        active: set = set()
        # preemption state: suspended requests (insertion-ordered), per-rid
        # preempt counts, the ops currently occupying a resource (rid ->
        # [op, resource, dur, ops_log index]) and the identities of
        # dispatched ops whose completion must be treated as an abort
        # (claim already released; resource frees, pointers do not move)
        suspended: Dict[str, EngineRequest] = {}
        preemptions: Dict[str, int] = {}
        outstanding: Dict[str, List[list]] = {}
        aborted_ids: set = set()
        # queued-request prefetch (admission-queue lookahead): rid ->
        # "done" | "resident" (already at/above promote_tier) | an inflight
        # record [c, op, dur, log_idx].  Each queued request is gated at
        # most once, so trace size stays bounded and replay re-derives the
        # same query sequence.  An inflight prefetch whose target is
        # admitted is ABORTED (channel freed, elapsed time becomes waste):
        # the half-done promotion can't serve restoration, and letting the
        # background transfer pin the channel would starve the foreground
        # loads it was meant to accelerate.
        prefetch_state: Dict[str, object] = {}
        if san is not None:
            san.bind(ops_log=ops_log, busy_comp=busy_comp, busy_io=busy_io)

        def stage_unblocked(op_stage: int, rid: str) -> bool:
            if self.stage_parallel:
                return True
            # sequential ablation: stage s may start only after stage s-1 done
            for s in range(op_stage):
                p = sched.plans.get((rid, s))
                if p is not None and not p.plan.done:
                    return False
            return True

        def try_prefetch(c: int) -> bool:
            """Idle channel + a known lookahead window (the admission
            queue): promote the oldest queued request still below
            ``promote_tier`` so its restoration starts from the faster
            tier.  Returns True iff a prefetch was dispatched on ``c``."""
            if not self.prefetch:
                return False
            for r in pending:
                rid = r.request_id
                if rid in prefetch_state:
                    continue
                if self.kvstore is not None and hasattr(self.kvstore, "tier_of"):
                    tier = self.kvstore.tier_of(rid)
                    ok = tier is not None \
                        and tier not in ("hbm", self.promote_tier)
                else:
                    # store-less replay: the recorded answer stands
                    ok = self.backend.prefetch_gate(r)
                if tel is not None:
                    tel.on_prefetch_gate(now, rid, ok)
                if trace is not None:
                    trace.record_prefetch_gate(now, rid, ok)
                if not ok:
                    prefetch_state[rid] = "resident"
                    continue
                op = ScheduledOp("prefetch", rid, -1, 0, (0, r.n_tokens),
                                 (0, 0))
                bw = self._bandwidth(rid)
                dur = self.backend.prefetch_secs(op, r, bw) \
                    * self.slow.get(c, 1.0)
                if san is not None:
                    san.on_dispatch(now, f"io{c}", op, dur)
                if tel is not None:
                    tel.on_dispatch(now, f"io{c}", op, dur)
                io_free[c] = False
                busy_io[c] += dur
                log_idx = len(ops_log)
                prefetch_state[rid] = [c, op, dur, log_idx]
                ops_log.append((now, now + dur, f"io{c}", f"{rid}:pf"))
                if trace is not None:
                    trace.record_dispatch(now, f"io{c}", op, dur, bw)
                heapq.heappush(events, (now + dur, next(counter),
                                        "prefetch_done", (c, op, dur, log_idx)))
                return True
            return False

        def dispatch():
            nonlocal decode_free, busy_decode, decode_steps
            # compute per stage.  A stage-blocked head request (sequential
            # ablation) is SKIPPED, not a reason to stop: other requests'
            # runnable ops on this stage must still dispatch.  Candidates are
            # phase-aware: restoration chunks and suffix-prefill ops compete
            # FCFS for the same stage compute (see BatchScheduler).
            for s in range(self.stages):
                blocked: set = set()
                while comp_free[s]:
                    op = sched.next_compute(stage=s, skip=blocked)
                    if op is None:
                        break
                    if op.kind == "compute" and \
                            not stage_unblocked(op.stage, op.request_id):
                        # release the claim; retry when upstream finishes
                        sched.plans[(op.request_id, op.stage)].plan.release_compute()
                        blocked.add((op.request_id, op.stage))
                        continue
                    r = reqs[op.request_id]
                    if op.kind == "prefill":
                        dur = self.backend.prefill_secs(op, r)
                        desc = f"{op.request_id}:p{op.unit}"
                    else:
                        restore_start.setdefault(op.request_id, now)
                        dur = self.backend.compute_secs(op, r)
                        desc = f"{op.request_id}:c{op.unit}"
                    if san is not None:
                        san.on_dispatch(now, f"comp{s}", op, dur)
                    if tel is not None:
                        tel.on_dispatch(now, f"comp{s}", op, dur)
                    comp_free[s] = False
                    busy_comp[s] += dur
                    log_idx = len(ops_log)
                    ops_log.append((now, now + dur, f"comp{s}", desc))
                    outstanding.setdefault(op.request_id, []).append(
                        [op, f"comp{s}", dur, log_idx])
                    if trace is not None:
                        trace.record_dispatch(now, f"comp{s}", op, dur, None)
                    heapq.heappush(events, (now + dur, next(counter),
                                            "comp_done", (s, op, dur)))
            # shared I/O channels (stage blockage is channel-independent, so
            # one skip set covers the whole pass)
            io_blocked: set = set()
            for c in range(self.io_channels):
                gate_slowdown[0] = self.slow.get(c, 1.0)
                self.backend.io_channel_hint(c)
                while io_free[c] and c not in failed:
                    op = sched.next_io(skip=io_blocked)
                    if op is None:
                        # no restoration transfer wants the channel: spend
                        # the idle time prefetching for the admission queue
                        try_prefetch(c)
                        break
                    if not stage_unblocked(op.stage, op.request_id):
                        sched.plans[(op.request_id, op.stage)].plan.release_io()
                        io_blocked.add((op.request_id, op.stage))
                        continue
                    r = reqs[op.request_id]
                    bw = self._bandwidth(op.request_id)
                    if self._resident(op.request_id, op.tokens, op.layers):
                        # dedup/HBM hit: the unit's chunks are already on
                        # device — no interconnect transfer, zero channel
                        # time (the channel frees at this same instant)
                        dur = self.backend.io_hit_secs(op, r)
                        if hasattr(self.kvstore, "note_io_hit"):
                            self.kvstore.note_io_hit(op.request_id,
                                                     op.tokens, op.layers)
                    else:
                        # block-granular pricing: only the unit's missing
                        # blocks ride the interconnect (partial eviction
                        # does not re-transfer the resident remainder)
                        frac = self._missing_fraction(op.request_id,
                                                      op.tokens, op.layers)
                        dur = self.backend.io_secs_partial(op, r, bw, frac) \
                            * self.slow.get(c, 1.0)
                    restore_start.setdefault(op.request_id, now)
                    if san is not None:
                        san.on_dispatch(now, f"io{c}", op, dur)
                    if tel is not None:
                        tel.on_dispatch(now, f"io{c}", op, dur)
                    io_free[c] = False
                    busy_io[c] += dur
                    log_idx = len(ops_log)
                    ops_log.append((now, now + dur, f"io{c}",
                                    f"{op.request_id}:l{op.unit}"))
                    outstanding.setdefault(op.request_id, []).append(
                        [op, f"io{c}", dur, log_idx])
                    if trace is not None:
                        trace.record_dispatch(now, f"io{c}", op, dur, bw)
                    heapq.heappush(events, (now + dur, next(counter),
                                            "io_done", (c, op, dur)))
            gate_slowdown[0] = 1.0
            # the decode-batch resource: one recurring step over EVERY
            # decode-phase request (continuous batching), one token each
            if decode_free and decoding:
                rids = sorted(decoding, key=lambda rid: sched.arrival_index[rid])
                dur = self.backend.decode_secs([reqs[rid] for rid in rids])
                if san is not None:
                    san.on_decode_dispatch(now, dur, rids)
                if tel is not None:
                    tel.on_decode_dispatch(now, dur, rids)
                decode_free = False
                busy_decode += dur
                decode_steps += 1
                ops_log.append((now, now + dur, "decode", ",".join(rids)))
                if trace is not None:
                    trace.record_decode(now, rids, dur)
                heapq.heappush(events, (now + dur, next(counter), "decode_done", rids))

        def admit(r: EngineRequest):
            st = prefetch_state.get(r.request_id)
            if isinstance(st, list):
                # the prefetch lost the race with admission: cancel it so
                # the channel serves this request's restoration instead
                c, op, dur, log_idx = st
                del prefetch_state[r.request_id]
                aborted_ids.add(id(op))
                io_free[c] = True
                busy_io[c] -= dur
                if san is not None:
                    san.on_abort(now, f"io{c}", op, rolled_back=dur)
                if tel is not None:
                    tel.on_abort(now, f"io{c}", op)
                t0, _, rn, desc = ops_log[log_idx]
                ops_log[log_idx] = (t0, now, rn, desc + ":aborted")
                if trace is not None:
                    trace.record_abort(now, f"io{c}", op)
            reqs[r.request_id] = r
            if san is not None:
                san.on_admit(now, r)
            active.add(r.request_id)
            if tel is not None:
                tel.on_admit(now, r.request_id, queued=len(pending),
                             active=len(active))
            sched.add_request(r.plans, priority=r.priority,
                              deadline=r.deadline)
            self.backend.admit(r)
            if trace is not None:
                trace.record_admit(now, r.request_id)
            if self.kvstore is not None:
                self.kvstore.touch(r.request_id)

        def urgency(r: EngineRequest):
            """Admission order under a preemption policy: most urgent first."""
            if self.preempt == "deadline":
                return (r.deadline, r.arrival)
            return (-r.priority, r.arrival)

        def suspend(vid: str):
            """Preempt a RESTORING request: abort its in-flight ops (their
            time becomes waste, not utilization), release every claim, park
            the cache — or DROP it (plans reset) in eviction mode — and
            free the admission slot."""
            active.discard(vid)
            suspended[vid] = reqs[vid]
            preemptions[vid] = preemptions.get(vid, 0) + 1
            recs = outstanding.pop(vid, [])
            if san is not None:
                san.on_suspend(now, vid, recs, self.evict)
            if tel is not None:
                tel.on_preempt(now, vid, evict=self.evict,
                               aborted_ops=len(recs))
            for op, resource, dur, log_idx in recs:
                # the resource stays physically occupied until the op's
                # completion event fires; completion then frees it WITHOUT
                # advancing pointers (the claim is released right here)
                aborted_ids.add(id(op))
                if resource.startswith("io"):
                    busy_io[int(resource[2:])] -= dur
                else:
                    busy_comp[int(resource[4:])] -= dur
                t0, t1, rn, desc = ops_log[log_idx]
                ops_log[log_idx] = (t0, t1, rn, desc + ":aborted")
            sched.preempt(vid, reset=self.evict)
            if self.evict:
                self.backend.evict(reqs[vid])
            else:
                self.backend.suspend(reqs[vid])
            if trace is not None:
                trace.record_preempt(now, vid)

        def resume(rid: str):
            """Re-admit a suspended request with all completed units intact."""
            r = suspended.pop(rid)
            if san is not None:
                san.on_resume(now, rid)
            if tel is not None:
                tel.on_resume(now, rid)
            active.add(rid)
            sched.resume(rid)
            self.backend.resume(r)
            if trace is not None:
                trace.record_resume(now, rid)
            if self.kvstore is not None:
                self.kvstore.touch(rid)

        def try_preempt(r: EngineRequest) -> bool:
            """Admission pressure: can arrival ``r`` take a slot by
            suspending a strictly less urgent, still-RESTORING request?
            Victim = eligible request with the smallest remaining
            restoration benefit (least recompute saving lost by pausing)."""
            victims = []
            for vid in active:
                if vid in restore_finish:
                    continue          # prefill/decode work is never rescinded
                v = reqs[vid]
                if self.preempt == "priority" and r.priority <= v.priority:
                    continue
                if self.preempt == "deadline" and r.deadline >= v.deadline:
                    continue
                victims.append((sched.remaining_restoration(vid),
                                -sched.arrival_index[vid], vid))
            if not victims:
                return False
            suspend(min(victims)[2])
            return True

        def refill():
            """A slot freed: re-admit the most urgent of {suspended, queued}.
            preempt="none" keeps the classic FCFS deque behavior.  Gang
            (run-to-completion) admission instead waits for batch close:
            the next gang joins only once the active set fully drains."""
            if self.admission == "gang":
                if active:
                    return
                while pending and (not self.max_active
                                   or len(active) < self.max_active):
                    admit(pending.popleft())
                return
            while pending or suspended:
                if self.max_active and len(active) >= self.max_active:
                    return
                if self.preempt == "none":
                    if not pending:
                        return
                    admit(pending.popleft())
                    continue
                best_s = min(suspended.values(), key=urgency, default=None)
                best_p = min(pending, key=urgency, default=None)
                if best_s is not None and (
                        best_p is None or urgency(best_s) <= urgency(best_p)):
                    resume(best_s.request_id)
                else:
                    pending.remove(best_p)
                    admit(best_p)

        def finish_request(rid: str):
            """Lifecycle complete: free the admission slot (continuous
            batching frees capacity at DECODE completion, not restore)."""
            finish[rid] = now
            if san is not None:
                san.on_finish(now, rid)
            active.discard(rid)
            if tel is not None:
                tel.on_finish(now, rid, queued=len(pending),
                              active=len(active))
            self.backend.request_done(reqs[rid])
            if trace is not None:
                trace.record_finish(now, rid)
            refill()

        def enter_decode(rid: str):
            """Transition out of PREFILL (or RESTORING when new_len == 0):
            queue the remaining output tokens for batched decode."""
            r = reqs[rid]
            steps = r.decode_len - (1 if r.new_len > 0 else 0)
            if steps > 0:
                decoding[rid] = steps
            else:
                finish_request(rid)

        def on_restored(rid: str):
            r = reqs[rid]
            restore_finish[rid] = now
            if san is not None:
                san.on_restore_done(now, rid)
            if tel is not None:
                tel.on_restore_done(now, rid)
            self.backend.restore_done(r)
            if trace is not None:
                trace.record_done(now, rid)
            if self.kvstore is not None:
                # restored KV is hot again: refresh LRU + pull it up
                self.kvstore.touch(rid)
                self.kvstore.promote(rid, self.promote_tier)
            if r.new_len > 0:
                sched.begin_prefill(rid, r.n_tokens, r.new_len)
            else:
                enter_decode(rid)

        def unregister(rid: str, op) -> Optional[list]:
            recs = outstanding.get(rid, ())
            for i, rec in enumerate(recs):
                if rec[0] is op:
                    del recs[i]
                    return rec
            return None

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if san is not None:
                san.on_event(now, kind)
            if kind == "arrive":
                r: EngineRequest = payload
                if tel is not None:
                    tel.on_arrive(now, r.request_id, queued=len(pending),
                                  active=len(active))
                if self.admission == "gang":
                    # run-to-completion baseline: arrivals only ever join
                    # at batch close, never a live batch
                    pending.append(r)
                    refill()
                elif self.max_active and len(active) >= self.max_active:
                    if self.preempt != "none" and try_preempt(r):
                        admit(r)
                    else:
                        pending.append(r)
                else:
                    admit(r)
            elif kind == "comp_done":
                s, op, dur = payload
                comp_free[s] = True
                if id(op) in aborted_ids:
                    # op of a preempted request: the kernel's time is already
                    # rolled back and the claim released; just free the stage
                    aborted_ids.discard(id(op))
                    if san is not None:
                        san.on_abort(now, f"comp{s}", op)
                    if trace is not None:
                        trace.record_abort(now, f"comp{s}", op)
                else:
                    unregister(op.request_id, op)
                    if san is not None:
                        san.on_complete(now, f"comp{s}", op)
                    restored = sched.complete(op)
                    if trace is not None:
                        trace.record_complete(now, f"comp{s}", op)
                    if op.kind == "prefill" and sched.prefill_done(op.request_id):
                        # last pipeline stage of the suffix done -> first token
                        first_token[op.request_id] = now
                        if tel is not None:
                            tel.on_first_token(now, op.request_id)
                        enter_decode(op.request_id)
                    elif restored is not None:
                        on_restored(restored)
            elif kind == "io_done":
                c, op, dur = payload
                io_free[c] = True
                if id(op) in aborted_ids:
                    aborted_ids.discard(id(op))
                    if san is not None:
                        san.on_abort(now, f"io{c}", op)
                    if trace is not None:
                        trace.record_abort(now, f"io{c}", op)
                elif c in failed:
                    # transfer died with its channel: release the claim (it
                    # reschedules), do NOT count the dead time as useful I/O
                    rec = unregister(op.request_id, op)
                    p = sched.plans[(op.request_id, op.stage)]
                    p.plan.release_io()
                    busy_io[c] -= dur
                    if san is not None:
                        san.on_abort(now, f"io{c}", op, rolled_back=dur,
                                     release_claim=True)
                    if tel is not None:
                        tel.on_abort(now, f"io{c}", op)
                    if rec is not None:
                        t0, t1, rn, desc = ops_log[rec[3]]
                        ops_log[rec[3]] = (t0, t1, rn, desc + ":aborted")
                    if trace is not None:
                        trace.record_abort(now, f"io{c}", op)
                else:
                    unregister(op.request_id, op)
                    if san is not None:
                        san.on_complete(now, f"io{c}", op)
                    restored = sched.complete(op)
                    if trace is not None:
                        trace.record_complete(now, f"io{c}", op)
                    if restored is not None:
                        on_restored(restored)
            elif kind == "fail":
                failed.add(payload)
                if trace is not None:
                    trace.record_fail(now, payload)
            elif kind == "decode_done":
                decode_free = True
                if san is not None:
                    san.on_decode_done(now)
                for rid in payload:
                    decoding[rid] -= 1
                    # decode-only lifecycles (new_len == 0): the first
                    # generated token IS the first token
                    if tel is not None and rid not in first_token:
                        tel.on_first_token(now, rid)
                    first_token.setdefault(rid, now)
                    if decoding[rid] <= 0:
                        del decoding[rid]
                        finish_request(rid)
            elif kind == "prefetch_done":
                c, op, dur, log_idx = payload
                rid = op.request_id
                if id(op) in aborted_ids:
                    # cancelled at admission: the channel was freed (and
                    # possibly re-dispatched) back then — nothing to do
                    aborted_ids.discard(id(op))
                    dispatch()
                    continue
                io_free[c] = True
                if c in failed:
                    # the channel died mid-prefetch: background work, so
                    # just roll the time back and allow a retry elsewhere
                    busy_io[c] -= dur
                    if san is not None:
                        san.on_abort(now, f"io{c}", op, rolled_back=dur)
                    if tel is not None:
                        tel.on_abort(now, f"io{c}", op)
                    t0, t1, rn, desc = ops_log[log_idx]
                    ops_log[log_idx] = (t0, t1, rn, desc + ":aborted")
                    prefetch_state.pop(rid, None)
                    if trace is not None:
                        trace.record_abort(now, f"io{c}", op)
                else:
                    prefetch_state[rid] = "done"
                    if san is not None:
                        san.on_complete(now, f"io{c}", op)
                    if self.kvstore is not None:
                        self.kvstore.promote(rid, self.promote_tier)
                    if trace is not None:
                        trace.record_complete(now, f"io{c}", op)
            dispatch()

        if self.strict and (pending or active or suspended):
            unfinished = sorted(active) + sorted(suspended) \
                + [r.request_id for r in pending]
            raise RuntimeError(
                f"engine core stalled before completion: {unfinished}")

        if san is not None:
            san.on_run_end(active=active, pending=pending,
                           suspended=suspended)
            if trace is not None and trace.trace is not None:
                for ev in trace.trace.events:
                    san.on_trace_event(ev)

        makespan = max(finish.values(), default=0.0) or 1e-12
        result = EngineResult(
            restore_finish=restore_finish,
            restore_start=restore_start,
            first_token=first_token,
            finish=finish,
            makespan=makespan,
            compute_busy=sum(busy_comp.values()) / (max(1, self.stages) * makespan),
            io_busy=sum(busy_io.values()) / (max(1, self.io_channels) * makespan),
            decode_busy=busy_decode / makespan,
            decode_steps=decode_steps,
            ops_log=ops_log,
            preemptions=preemptions,
            overlap_decode_restore=decode_restore_overlap(ops_log),
        )
        if tel is not None:
            tel.on_run_end(result)
        if trace is not None:
            trace.finish(result)
        return result

    def _trace_meta(self) -> dict:
        """Engine configuration a replay needs to rebuild this core.
        ``channel_slowdown`` is recorded for provenance only — replayed
        durations already include it."""
        return {
            "backend": type(self.backend).__name__,
            "stages": self.stages,
            "io_channels": self.io_channels,
            "io_policy": self.io_policy,
            "channel_slowdown": dict(self.slow),
            "channel_fail_at": dict(self.fail_at),
            "stage_parallel": self.stage_parallel,
            "max_active": self.max_active,
            "promote_tier": self.promote_tier,
            "preempt": self.preempt,
            "evict": self.evict,
            "admission": self.admission,
            "prefetch": self.prefetch,
        }


def interleaving_dur_fn(op_order: str,
                        rng: Optional[np.random.Generator] = None
                        ) -> Optional[Callable[[ScheduledOp], float]]:
    """Map the executor's historical ``op_order`` knob onto schedule
    durations for a RealBackend: the engine clock orders completions by
    duration, so biasing one op kind fast makes that pointer race ahead.
    Returns None for "measured" (use real wall timings)."""
    if op_order == "measured":
        return None
    rng = rng or np.random.default_rng(0)
    if op_order == "io_first":
        return lambda op: 1e-6 if op.kind == "load" else 1.0
    if op_order == "compute_first":
        return lambda op: 1e-6 if op.kind == "compute" else 1.0
    if op_order in ("random", "alternate"):
        return lambda op: float(rng.uniform(0.5, 1.5))
    raise ValueError(f"unknown op_order: {op_order}")
