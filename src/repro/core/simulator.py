"""Discrete-event simulator for batched, multi-stage KV restoration.

Thin facade over the shared :mod:`repro.core.engine_core` event loop with a
``SimBackend``: the *same* admission/dispatch logic that drives real JAX
execution is driven here against the analytic cost model, so per-request
restore-finish times and resource busy fractions (the paper's Fig. 5
utilization numbers) are measured for exactly the schedule the real backend
proves correct.

Straggler/failure studies plug in via ``channel_slowdown`` /
``channel_fail_at``; tier-aware bandwidth via ``bw_override`` (static) or a
``kvstore`` (dispatch-time lookup + LRU touch/promote).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.cost_model import CostModel
from repro.core.engine_core import (EngineCore, EngineRequest, EngineResult,
                                    SimBackend)

# Historical names: simulation call sites construct SimRequest/SimResult,
# which are literally the engine core's request/result types.
SimRequest = EngineRequest
SimResult = EngineResult


class RestorationSimulator:
    def __init__(self, cost: CostModel, *, stages: int = 1, io_channels: int = 1,
                 io_policy: str = "longest_remaining",
                 channel_slowdown: Optional[Dict[int, float]] = None,
                 channel_fail_at: Optional[Dict[int, float]] = None,
                 stage_parallel: bool = True,
                 bw_override: Optional[Dict[str, float]] = None,
                 max_active: int = 0, kvstore=None):
        """stage_parallel=False models the paper's Fig. 7 ablation: stages
        restore sequentially (stage s waits for s-1) instead of concurrently
        via boundary activations.

        bw_override: per-request I/O bandwidth (bytes/s) — the KV-store tier
        the payload lives in.  max_active: continuous-batching admission cap
        (0 = unlimited)."""
        self.cost = cost
        self.backend = SimBackend(cost, bw_override=bw_override)
        self.core = EngineCore(
            self.backend, stages=stages, io_channels=io_channels,
            io_policy=io_policy, channel_slowdown=channel_slowdown,
            channel_fail_at=channel_fail_at, stage_parallel=stage_parallel,
            max_active=max_active, kvstore=kvstore)

    def run(self, requests: List[SimRequest], trace=None) -> SimResult:
        """``trace``: optional ``TraceRecorder`` capturing the schedule for
        deterministic replay (see :mod:`repro.core.trace`)."""
        return self.core.run(requests, trace=trace)
