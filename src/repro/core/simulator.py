"""Discrete-event simulator for batched, multi-stage KV restoration.

Executes the *real* BatchScheduler (Algorithm 1) against a timing model:
  * one compute resource per pipeline stage (chunk recomputes serialize on
    the stage's chips — GPU/TPU kernels are exclusive),
  * ``io_channels`` shared transfer channels (contention = queueing, which is
    how concurrent loads slow each other down, paper §3.3),
  * optional per-channel slowdown / failure injection for straggler and
    fault-tolerance studies (failed transfers release their claim and are
    rescheduled — restoration ops are idempotent).

Outputs per-request restore-finish times and resource busy fractions (the
paper's Fig. 5 utilization numbers).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.cost_model import CostModel
from repro.core.plans import RequestPlan
from repro.core.scheduler import BatchScheduler, ScheduledOp


@dataclass
class SimRequest:
    request_id: str
    n_tokens: int                   # prefix to restore
    arrival: float = 0.0
    plans: List[RequestPlan] = None # one per stage


@dataclass
class SimResult:
    restore_finish: Dict[str, float]
    restore_start: Dict[str, float]
    makespan: float
    compute_busy: float             # fraction of makespan, averaged over stages
    io_busy: float                  # fraction, averaged over channels
    ops_log: List[Tuple[float, float, str, str]]  # (start, end, resource, op-desc)


class RestorationSimulator:
    def __init__(self, cost: CostModel, *, stages: int = 1, io_channels: int = 1,
                 io_policy: str = "longest_remaining",
                 channel_slowdown: Optional[Dict[int, float]] = None,
                 channel_fail_at: Optional[Dict[int, float]] = None,
                 stage_parallel: bool = True,
                 bw_override: Optional[Dict[str, float]] = None,
                 max_active: int = 0):
        """stage_parallel=False models the paper's Fig. 7 ablation: stages
        restore sequentially (stage s waits for s-1) instead of concurrently
        via boundary activations.

        bw_override: per-request I/O bandwidth (bytes/s) — the KV-store tier
        the payload lives in.  max_active: continuous-batching admission cap
        (0 = unlimited)."""
        self.cost = cost
        self.stages = stages
        self.io_channels = io_channels
        self.io_policy = io_policy
        self.slow = channel_slowdown or {}
        self.fail_at = channel_fail_at or {}
        self.stage_parallel = stage_parallel
        self.bw_override = bw_override or {}
        self.max_active = max_active

    # -- durations -------------------------------------------------------
    def _compute_secs(self, op: ScheduledOp, n_tokens: int) -> float:
        lo, hi = op.layers
        frac = (hi - lo) / self.cost.cfg.num_layers
        t0, t1 = op.tokens
        f = self.cost.flops_recompute(t0, t1) * frac
        return f / (self.cost.hw.peak_flops * self.cost.mfu * self.cost.num_chips) \
            + self.cost.hw.kernel_overhead_s

    def _io_secs(self, op: ScheduledOp, channel: int) -> float:
        t0, t1 = op.tokens
        lo, hi = op.layers
        frac = (hi - lo) / self.cost.cfg.num_layers
        bytes_ = (t1 - t0) * self.cost.bytes_per_token() * frac
        bw = self.bw_override.get(op.request_id, self.cost.io_bandwidth)
        return bytes_ / bw * self.slow.get(channel, 1.0)

    # -- marginal-benefit gate (§3.3) --------------------------------------
    def _io_benefit(self, plan: RequestPlan, unit: int) -> bool:
        """Spend a channel on this unit only if the transfer finishes before
        compute alone could have covered the remaining span through it —
        otherwise loading delays completion (the channel pins the unit)."""
        if not plan.plan.comp_enabled:
            return True               # load-only baselines: I/O is all they have
        tokens, layers = plan.io_unit_for_claim(unit)
        lo, hi = layers
        frac = (hi - lo) / self.cost.cfg.num_layers
        bw = self.bw_override.get(plan.request_id, self.cost.io_bandwidth)
        t0, t1 = tokens
        io_secs = (t1 - t0) * self.cost.bytes_per_token() * frac / bw
        if plan.strategy == "token":
            span0 = plan.plan.comp_next * plan.chunk_size
            span1 = min(plan.n_tokens, (unit + 1) * plan.chunk_size)
            n_chunks = unit - plan.plan.comp_next + 1
            comp_secs = (self.cost.flops_recompute(span0, span1) * frac
                         / (self.cost.hw.peak_flops * self.cost.mfu
                            * self.cost.num_chips)
                         + n_chunks * self.cost.hw.kernel_overhead_s)
        else:
            n_layers = unit - plan.plan.comp_next + 1
            full = self.cost.flops_recompute(0, plan.n_tokens) / self.cost.cfg.num_layers
            comp_secs = (full * n_layers
                         / (self.cost.hw.peak_flops * self.cost.mfu
                            * self.cost.num_chips)
                         + self.cost.hw.kernel_overhead_s)
        return io_secs < comp_secs

    # -- main loop --------------------------------------------------------
    def run(self, requests: List[SimRequest]) -> SimResult:
        sched = BatchScheduler(io_policy=self.io_policy,
                               benefit_fn=self._io_benefit)
        counter = itertools.count()
        events: List[Tuple[float, int, str, object]] = []
        for r in requests:
            heapq.heappush(events, (r.arrival, next(counter), "arrive", r))

        comp_free = {s: True for s in range(self.stages)}
        io_free = {c: True for c in range(self.io_channels)}
        failed = set()
        busy_comp = {s: 0.0 for s in range(self.stages)}
        busy_io = {c: 0.0 for c in range(self.io_channels)}
        restore_finish: Dict[str, float] = {}
        restore_start: Dict[str, float] = {}
        ops_log: List[Tuple[float, float, str, str]] = []
        reqs: Dict[str, SimRequest] = {}
        now = 0.0
        for c, t in self.fail_at.items():
            heapq.heappush(events, (t, next(counter), "fail", c))

        def stage_unblocked(op_stage: int, rid: str) -> bool:
            if self.stage_parallel:
                return True
            # sequential ablation: stage s may start only after stage s-1 done
            for s in range(op_stage):
                p = sched.plans.get((rid, s))
                if p is not None and not p.plan.done:
                    return False
            return True

        def dispatch():
            # compute per stage
            for s in range(self.stages):
                while comp_free[s]:
                    op = sched.next_compute(stage=s)
                    if op is None:
                        break
                    if not stage_unblocked(op.stage, op.request_id):
                        # release the claim; retry when upstream finishes
                        sched.plans[(op.request_id, op.stage)].plan.comp_inflight = None
                        break
                    r = reqs[op.request_id]
                    restore_start.setdefault(op.request_id, now)
                    dur = self._compute_secs(op, r.n_tokens)
                    comp_free[s] = False
                    busy_comp[s] += dur
                    ops_log.append((now, now + dur, f"comp{s}",
                                    f"{op.request_id}:c{op.unit}"))
                    heapq.heappush(events, (now + dur, next(counter), "comp_done", (s, op)))
            # shared I/O channels
            for c in range(self.io_channels):
                while io_free[c] and c not in failed:
                    op = None
                    for s in range(self.stages):
                        op = sched.next_io(stage=None)
                        break
                    if op is None:
                        break
                    if not stage_unblocked(op.stage, op.request_id):
                        sched.plans[(op.request_id, op.stage)].plan.io_inflight = None
                        break
                    restore_start.setdefault(op.request_id, now)
                    dur = self._io_secs(op, c)
                    io_free[c] = False
                    busy_io[c] += dur
                    ops_log.append((now, now + dur, f"io{c}",
                                    f"{op.request_id}:l{op.unit}"))
                    heapq.heappush(events, (now + dur, next(counter), "io_done", (c, op)))

        pending: List[SimRequest] = []
        active: set = set()

        def admit(r: SimRequest):
            reqs[r.request_id] = r
            active.add(r.request_id)
            sched.add_request(r.plans)

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind == "arrive":
                r: SimRequest = payload
                if self.max_active and len(active) >= self.max_active:
                    pending.append(r)
                else:
                    admit(r)
            elif kind == "comp_done":
                s, op = payload
                comp_free[s] = True
                sched.complete(op)
            elif kind == "io_done":
                c, op = payload
                io_free[c] = True
                if c in failed:
                    # transfer was aborted: release the claim, it reschedules
                    p = sched.plans[(op.request_id, op.stage)]
                    p.plan.io_inflight = None
                else:
                    sched.complete(op)
            elif kind == "fail":
                failed.add(payload)
            # request completions (+ admit queued requests)
            for rid in list(active):
                if rid not in restore_finish and sched.request_done(rid):
                    restore_finish[rid] = now
                    active.discard(rid)
                    while pending and (not self.max_active
                                       or len(active) < self.max_active):
                        admit(pending.pop(0))
            dispatch()

        makespan = max(restore_finish.values(), default=0.0) or 1e-12
        return SimResult(
            restore_finish=restore_finish,
            restore_start=restore_start,
            makespan=makespan,
            compute_busy=sum(busy_comp.values()) / (self.stages * makespan),
            io_busy=sum(busy_io.values()) / (self.io_channels * makespan),
            ops_log=ops_log,
        )
