"""Train a ~0.5B-family reduced LM for a few hundred steps on CPU with the
full production substrate: deterministic sharded data, AdamW + cosine,
remat, async checkpointing, and an injected host failure + restart.

    PYTHONPATH=src python examples/train_small_lm.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import run  # noqa: E402


def main():
    with tempfile.TemporaryDirectory() as ckpt_dir:
        run("qwen1.5-0.5b", reduced=True, steps=200, ckpt_dir=ckpt_dir,
            global_batch=8, seq_len=64, ckpt_every=25,
            fail_at_step=60,           # prove checkpoint/restart works
            peak_lr=3e-3)


if __name__ == "__main__":
    main()
