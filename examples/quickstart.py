"""Quickstart: restore a KV cache with CacheFlow and verify it is exact.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import CostModel, RestorationExecutor  # noqa: E402
from repro.config import HARDWARE, IO_BANDWIDTHS  # noqa: E402
from repro.models import build_model  # noqa: E402


def main():
    # 1. build a small model (reduced Qwen3-8B family)
    cfg = get_config("qwen3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 2. "previous turn": prefill 96 tokens, persist KV + boundary activations
    executor = RestorationExecutor(model, params, chunk_size=16, stages=2)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 96), 0, cfg.vocab_size)
    executor.remember("chat-1", prompt)

    # 3. the request returns: restore with the 3D two-pointer schedule
    executor.restore("chat-1", l_delta=64)          # adaptive token/layer
    errs = executor.verify("chat-1")                 # exact vs full prefill
    print("restoration exact; max per-field error:", max(errs.values()))

    # 4. prefill the new turn on the restored cache -> first token
    new_turn = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, cfg.vocab_size)
    logits = executor.first_token_logits("chat-1", new_turn)
    print("first token:", int(jnp.argmax(logits)))

    # 5. what the paper's analysis says about this tradeoff at scale
    cost = CostModel(get_config("qwen3-8b"), HARDWARE["tpu_v5e"],
                     IO_BANDWIDTHS["10Gbps"], mfu=0.45)
    n = 20_000
    print(f"\nfull-size qwen3-8b, 20k-token prefix @ 10 Gbps:")
    print(f"  recompute-only  : {cost.t_comp(n):.3f}s")
    print(f"  load-only       : {cost.t_io_tokens(n):.3f}s")
    print(f"  two-pointer T*  : {cost.harmonic_bound(n):.3f}s  (Eq. 1)")
    print(f"  + 4 stages (3D) : {cost.stage_parallel_bound(n, 4):.3f}s  (Eq. 2)")
    print(f"  crossover L_d   : {cost.crossover_l_delta()} tokens")


if __name__ == "__main__":
    main()
