"""Agentic pipeline (SWE-Bench-like): many tool calls share one long repo
context.  Shows (1) batch-aware scheduling under contention, (2) the KV-store
tier impact, (3) stage-parallel (3D) restoration ablation.

    PYTHONPATH=src python examples/agentic_restoration.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.config import HARDWARE, IO_BANDWIDTHS  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.serving import SimServingEngine, TieredKVStore, generate  # noqa: E402


def main():
    cfg = get_config("qwen3-30b-a3b")      # the paper's MoE model
    hw = HARDWARE["tpu_v5e"]

    print("SWE-Bench-like agentic workload, 64 requests, v5e target\n")

    # 1. batch-aware I/O vs request-centric (cake) under heavy contention
    print("batch awareness (10 Gbps, 1 shared channel):")
    for system in ("cake", "cacheflow"):
        reqs = generate("swe_bench", 64, seed=11, arrival_rate=8.0)
        eng = SimServingEngine(cfg, hw, io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                               system=system, stages=2, max_batch=16)
        s = eng.run(reqs).stats
        print(f"  {system:10s} mean={s['mean']:.3f}s p99={s['p99']:.3f}s")

    # 2. KV-store tiers: hot contexts in host DRAM vs cold in remote
    print("\nKV-store tiers (hot contexts promoted to host DRAM):")
    for host_cap in (0.0, 200e9):
        store = TieredKVStore(host_cap=host_cap, host_bw=100e9,
                              remote_bw=IO_BANDWIDTHS["10Gbps"])
        reqs = generate("swe_bench", 64, seed=11, arrival_rate=8.0)
        eng = SimServingEngine(cfg, hw, io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                               system="cacheflow", stages=2, max_batch=16,
                               kvstore=store)
        s = eng.run(reqs).stats
        label = "remote-only" if host_cap == 0 else "host-tier   "
        print(f"  {label} mean={s['mean']:.3f}s p99={s['p99']:.3f}s")

    # 3. 3D ablation: concurrent stage restoration via boundary activations
    print("\n3D (stage-parallel) ablation:")
    for system in ("cacheflow_2d", "cacheflow"):
        reqs = generate("swe_bench", 64, seed=11, arrival_rate=8.0)
        eng = SimServingEngine(cfg, hw, io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                               system=system, stages=4, max_batch=16)
        s = eng.run(reqs).stats
        print(f"  {system:14s} mean={s['mean']:.3f}s p99={s['p99']:.3f}s")


if __name__ == "__main__":
    main()
