"""Serve a multi-turn chatbot workload end-to-end, comparing CacheFlow with
the paper's baselines — both in simulation (paper scale) and for real on a
reduced model.

    PYTHONPATH=src python examples/serve_chatbot.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.config import HARDWARE, IO_BANDWIDTHS  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import (RealServingEngine, Request, SimServingEngine,  # noqa: E402
                           generate)


def main():
    # --- paper-scale simulation: Qwen3-8B on H100, 10 Gbps KV channel -----
    cfg = get_config("qwen3-8b")
    print("LMSys-Chat workload, 48 requests, H100 + 10 Gbps (simulated):")
    base_mean = None
    for system in ("vllm", "lmcache", "cake", "cacheflow"):
        reqs = generate("lmsys_chat", 48, seed=7)
        eng = SimServingEngine(cfg, HARDWARE["h100"],
                               io_bandwidth=IO_BANDWIDTHS["10Gbps"],
                               system=system, stages=2, max_batch=8)
        rep = eng.run(reqs)
        s = rep.stats
        print(f"  {system:10s} mean={s['mean']:.3f}s p50={s['p50']:.3f}s "
              f"p90={s['p90']:.3f}s p99={s['p99']:.3f}s "
              f"e2e={s['e2e_mean']:.3f}s tok/s={s['tokens_per_sec']:.0f}")
        if system != "cacheflow":
            base_mean = min(base_mean or 1e9, s["mean"])
        else:
            print(f"  -> TTFT reduction vs best baseline: "
                  f"{1 - s['mean'] / base_mean:.1%} (paper band: 10-62%)")

    # --- real execution on a reduced model --------------------------------
    # The same engine core drives all three turns CONCURRENTLY through the
    # whole lifecycle: restoration (KV verified), suffix prefill competing
    # with the other turns' restoration chunks, and batched greedy decode.
    print("\nReal execution (reduced model, engine-clock times from measured "
          "op durations, KV verified):")
    cfgr = get_config("qwen3-8b").reduced()
    model = build_model(cfgr)
    params = model.init(jax.random.PRNGKey(0))
    eng = RealServingEngine(model, params, system="cacheflow", stages=2,
                            chunk_size=16, max_batch=2)
    reqs = [Request(f"turn-{i}", 0.0, prefix_len=48 + 32 * i, new_len=16,
                    decode_len=4)
            for i in range(3)]
    rep = eng.serve(reqs, verify=True)
    for rid, t in rep.ttfts.items():
        toks = eng.executor.outputs(rid)["tokens"]
        print(f"  {rid}: TTFT {t * 1e3:.1f} ms, e2e {rep.e2e[rid] * 1e3:.1f} ms, "
              f"tokens {toks} (restored KV verified exact)")
    print(f"  busy: compute={rep.compute_busy:.2f} io={rep.io_busy:.2f} "
          f"decode={rep.decode_busy:.2f}")


if __name__ == "__main__":
    main()
